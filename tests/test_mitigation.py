"""§VI-B bandwidth-mitigation path, end to end: gradient compression with
error feedback in the train step (payload telemetry, checkpointable
residual), PS-capacity recalibration by `compression_ratio`, the
controller's detect -> act -> recalibrate loop, the async-PS Session mode,
and the satellite fixes (mitigate_ps golden, restores counter, profiler
step_time)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Session
from repro.configs import RunConfig, get_config
from repro.core.controller import Action, Controller
from repro.core.perf_model.cluster_model import (PSBottleneckModel,
                                                 WorkerSpec, cluster_speed)
from repro.core.profiler import PerformanceProfiler
from repro.core.ps_async import ps_queue_sim
from repro.core.scheduler import plan_launch
from repro.core.trainer import TransientTrainer
from repro.data.pipeline import ShardedLoader, SyntheticTokenSource
from repro.dist.compression import compression_ratio, payload_bytes


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3-1.7b", smoke=True)


def _run(cfg, scheme, steps=10, ckpt_dir=None, interval=0):
    run = RunConfig(total_steps=steps, warmup_steps=1, lr=1e-3, zero1=False,
                    checkpoint_interval=interval,
                    checkpoint_dir=ckpt_dir or tempfile.mkdtemp(),
                    grad_compression=scheme)
    src = SyntheticTokenSource(cfg.vocab_size, 24)
    tr = TransientTrainer(cfg, run, ShardedLoader(src, 8))
    state, _ = tr.restore_or_init()
    return tr, *tr.run_steps(state, steps)


# --------------------------------------------------- compressed train step
def test_compressed_step_reports_payload_bytes(cfg):
    s = Session.from_arch("qwen3-1.7b", total_steps=4, warmup_steps=1,
                          checkpoint_interval=0, lr=1e-3, zero1=False,
                          grad_compression="int8")
    rep = s.train(4, global_batch=4, seq_len=32,
                  checkpoint_dir=tempfile.mkdtemp())
    assert rep.steps_run == 4 and not np.isnan(rep.losses).any()
    steps = s.bus.of_kind("step")
    # payload telemetry is the measured wire size, not a config echo:
    # int8 = 1 byte per gradient value = the live parameter tree's size
    n_values = sum(int(l.size)
                   for l in jax.tree.leaves(s._last_state.params))
    assert all(e.payload["grad_compression"] == "int8" for e in steps)
    assert all(e.payload["payload_bytes"] == n_values for e in steps)


def test_error_feedback_convergence_parity(cfg):
    """Fixed-seed loss trajectories under bf16/int8 stay within tolerance
    of the uncompressed run — the error-feedback guarantee."""
    finals = {}
    for scheme in ("none", "bf16", "int8"):
        _, _, rep = _run(cfg, scheme, steps=10)
        assert not np.isnan(rep.losses).any()
        finals[scheme] = rep.final_loss
    for scheme in ("bf16", "int8"):
        assert finals[scheme] == pytest.approx(finals["none"], rel=0.05)


def test_payload_bytes_helper(cfg):
    tree = {"a": jnp.zeros((3, 4)), "b": jnp.zeros((5,))}
    assert payload_bytes(tree, "none") == 17 * 4
    assert payload_bytes(tree, "bf16") == 17 * 2
    assert payload_bytes(tree, "int8") == 17 * 1
    with pytest.raises(ValueError):
        compression_ratio("int4")


# -------------------------------------------------- residual checkpointing
def test_residual_survives_checkpoint_restore(cfg):
    ckpt = tempfile.mkdtemp()
    tr, state, rep = _run(cfg, "int8", steps=8, ckpt_dir=ckpt, interval=4)
    assert rep.checkpoints == 2
    # a fresh worker (new trainer) restores the same residual tree
    run = tr.run
    tr2 = TransientTrainer(cfg, run,
                           ShardedLoader(SyntheticTokenSource(
                               cfg.vocab_size, 24), 8), holder="worker-9")
    tr2.ckpt.lease.notify_revoked()
    state2, start = tr2.restore_or_init()
    assert start == 8
    saved = jax.tree.leaves(state.residual)
    back = jax.tree.leaves(state2.residual)
    assert len(saved) == len(back) > 0
    assert any(np.abs(np.asarray(a)).max() > 0 for a in back)
    for a, b in zip(saved, back):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_legacy_checkpoint_restores_with_zero_residual(cfg):
    """A checkpoint written before compression was on restores into a
    compressed run with a freshly zeroed residual (no KeyError)."""
    ckpt = tempfile.mkdtemp()
    _run(cfg, "none", steps=4, ckpt_dir=ckpt, interval=4)
    run = RunConfig(total_steps=4, warmup_steps=1, lr=1e-3, zero1=False,
                    checkpoint_interval=4, checkpoint_dir=ckpt,
                    grad_compression="int8")
    tr = TransientTrainer(cfg, run, ShardedLoader(
        SyntheticTokenSource(cfg.vocab_size, 24), 8), holder="worker-9")
    tr.ckpt.lease.notify_revoked()
    state, start = tr.restore_or_init()
    assert start == 4 and tr.restores == 1
    leaves = jax.tree.leaves(state.residual)
    assert leaves and all(np.abs(np.asarray(l)).max() == 0 for l in leaves)


# ------------------------------------------------ capacity recalibration
def test_ps_capacity_scales_with_compression_ratio():
    # net-bound model (few tensors): int8 payload -> 4x capacity
    ps = PSBottleneckModel(1.25e9, 1)
    for scheme in ("bf16", "int8"):
        scaled = PSBottleneckModel(1.25e9, 1, compression=scheme)
        assert scaled.capacity_steps_per_s() == pytest.approx(
            ps.capacity_steps_per_s() / compression_ratio(scheme))
    # RPC-bound model: compression shrinks bytes, not per-tensor RPCs
    rpc = PSBottleneckModel(1.87e6, 1, n_tensors=97)
    rpc8 = PSBottleneckModel(1.87e6, 1, n_tensors=97, compression="int8")
    assert rpc8.capacity_steps_per_s() == pytest.approx(
        rpc.capacity_steps_per_s())


def test_mitigate_ps_preserves_n_tensors_golden():
    """Golden: ResNet-32-like RPC-bound PS (97 tensors, ~41 updates/s per
    PS). Adding a PS must double capacity, not drop the RPC term (which
    silently inflated it to the network-only ~668 updates/s)."""
    ps = PSBottleneckModel(1.87e6, 1, n_tensors=97)
    before = ps.capacity_steps_per_s()
    assert before == pytest.approx(40.9, abs=0.1)
    after = Controller().mitigate_ps(ps)
    assert after.n_tensors == 97
    assert after.rpc_per_tensor == ps.rpc_per_tensor
    assert after.compression == ps.compression
    assert after.capacity_steps_per_s() == pytest.approx(2 * before)


def test_plan_launch_threads_compression_through_ps_cap():
    """A PS-capped plan under int8 predicts a faster run than the same
    plan uncompressed (the §VI-B recalibration reaching §V-C)."""
    kw = dict(n_w=100_000, i_c=4_000, t_c=3.84, hours=[0], seed=0,
              samples=16)
    ps_none = PSBottleneckModel(1.25e9, 1)           # capacity 0.5 steps/s
    ps_int8 = PSBottleneckModel(1.25e9, 1, compression="int8")
    best_none, _ = plan_launch("v100", 4, 10.0, ps=ps_none, **kw)
    best_int8, _ = plan_launch("v100", 4, 10.0, ps=ps_int8, **kw)
    best_flat, _ = plan_launch("v100", 4, 10.0, **kw)  # uncapped baseline
    assert best_int8.expected_time_s < best_none.expected_time_s
    assert best_flat.expected_time_s < best_int8.expected_time_s


def test_session_predict_reflects_compression():
    from repro.core.perf_model.cluster_model import PS_RPC_PER_TENSOR_S
    base = Session.from_arch("qwen3-1.7b")
    comp = Session.from_arch("qwen3-1.7b", grad_compression="int8")
    p0 = base.predict(n_workers=2, gpu="v100")
    p8 = comp.predict(n_workers=2, gpu="v100")
    assert p0.grad_compression == "none" and p8.grad_compression == "int8"
    assert p8.payload_bytes == pytest.approx(p0.payload_bytes / 4)
    # the smoke model is RPC-bound (rpc term > network term), so the
    # ceiling is set by its tensor count and compression can NOT raise it
    assert base.n_tensors() * PS_RPC_PER_TENSOR_S \
        > 2 * base.model_bytes() / 1.25e9
    assert p0.ps_capacity == pytest.approx(
        1.0 / (base.n_tensors() * PS_RPC_PER_TENSOR_S))
    assert p8.ps_capacity == pytest.approx(p0.ps_capacity)
    # a network-bound payload DOES gain the full ratio (unit-level check
    # in test_ps_capacity_scales_with_compression_ratio)


def test_session_plan_accepts_ps_cap():
    s = Session.from_arch("qwen3-1.7b", total_steps=500,
                          checkpoint_interval=100)
    best_uncapped, _ = s.plan(gpu="v100", n_workers=2, hours=[0],
                              samples=16)
    best_capped, _ = s.plan(gpu="v100", n_workers=2, hours=[0],
                            samples=16, n_ps=1)
    # the smoke model's payload is small: the cap may or may not bind,
    # but the capped plan can never be faster than the uncapped one
    assert best_capped.expected_time_s >= best_uncapped.expected_time_s


# --------------------------------------------------- controller mitigation
def _stalled_profiler(measured: float, n: int = 12) -> PerformanceProfiler:
    prof = PerformanceProfiler(window=2, warmup_steps=0, warmup_seconds=0.0)
    t = 0.0
    for s in range(n):
        prof.record(s, t=t)
        t += 1.0 / measured
    return prof


def test_controller_escalates_compression_then_ps():
    ps = PSBottleneckModel(1.25e9, 1)                # capacity 0.5 steps/s
    workers = [WorkerSpec("v100", 2.0)] * 4          # demand 8 steps/s
    ctrl = Controller()
    prof = _stalled_profiler(measured=0.5)
    det = ctrl.check(prof, predicted_speed=8.0, ps_model=ps, workers=workers)
    assert det.bottleneck and det.action is Action.ENABLE_COMPRESSION
    ps = ctrl.mitigate_compression(ps, "int8")
    assert ps.compression == "int8"
    # still saturated (8 > 2.0): the next rung is sparser compression
    det2 = ctrl.check(prof, predicted_speed=8.0, ps_model=ps,
                      workers=workers)
    assert det2.action is Action.ENABLE_COMPRESSION
    ps = ctrl.mitigate_compression(ps, "topk")
    assert ps.compression == "topk"


def test_controller_adds_ps_when_topk_is_not_enough():
    ps = PSBottleneckModel(1.25e9, 1, compression="topk")  # capacity 25
    workers = [WorkerSpec("v100", 10.0)] * 4               # demand 40
    ctrl = Controller()
    prof = _stalled_profiler(measured=20.0)
    # the compression ladder is exhausted: the only lever left is more PS
    det = ctrl.check(prof, predicted_speed=40.0, ps_model=ps,
                     workers=workers)
    assert det.bottleneck and det.action is Action.ADD_PARAMETER_SERVER
    ps = ctrl.mitigate_ps(ps)
    assert (ps.n_ps, ps.compression) == (2, "topk")


def test_synthetic_bottleneck_mitigation_raises_measured_speed():
    """The acceptance scenario: a saturated PS measured by the queueing
    emulation, the controller's mitigation applied, and the re-measured
    cluster speed going up."""
    compute_times = [0.25] * 4                       # demand 16 steps/s
    model_bytes = 1.25e9                             # capacity 0.5 steps/s
    before = ps_queue_sim(compute_times, model_bytes, steps=60)
    ctrl = Controller()
    ps = PSBottleneckModel(model_bytes, 1)
    workers = [WorkerSpec("v100", 1.0 / 0.25)] * 4
    det = ctrl.check(_stalled_profiler(before.cluster_speed, n=24),
                     predicted_speed=cluster_speed(workers),
                     ps_model=ps, workers=workers)
    assert det.bottleneck and det.action is Action.ENABLE_COMPRESSION
    ps = ctrl.mitigate_compression(ps, "int8")
    after = ps_queue_sim(compute_times, model_bytes, steps=60,
                         grad_compression=ps.compression)
    assert after.cluster_speed > 3 * before.cluster_speed
    # second lever, same loop: one more PS doubles it again
    ps = ctrl.mitigate_ps(ps)
    more = ps_queue_sim(compute_times, model_bytes, n_ps=ps.n_ps, steps=60,
                        grad_compression=ps.compression)
    assert more.cluster_speed > 1.5 * after.cluster_speed


def test_trainer_applies_mitigation_mid_run(cfg):
    """End to end: the controller detects PS saturation mid-run, the
    trainer flips the train step to int8 (new residual, payload telemetry
    on later steps) and re-derives its prediction from the recalibrated
    capacity."""
    ps = PSBottleneckModel(5e9, 1)                   # capacity 0.125
    workers = [WorkerSpec("v100", 1e4)] * 4
    run = RunConfig(total_steps=16, warmup_steps=1, checkpoint_interval=0,
                    checkpoint_dir=tempfile.mkdtemp(), lr=1e-3, zero1=False)
    evs = []
    tr = TransientTrainer(cfg, run,
                          ShardedLoader(SyntheticTokenSource(
                              cfg.vocab_size, 24), 8),
                          ps_model=ps, workers=workers, predicted_speed=4e4,
                          on_event=lambda k, p: evs.append((k, p)))
    state, _ = tr.restore_or_init()
    state, rep = tr.run_steps(state, 16, check_every=5)
    assert [m["action"] for m in rep.mitigations] == ["enable_compression"]
    assert tr.run.grad_compression == "int8"
    assert tr.ps_model.capacity_steps_per_s() == pytest.approx(0.5)
    assert tr.predicted_speed == pytest.approx(
        cluster_speed(workers, tr.ps_model))
    mitigated_at = rep.mitigations[0]["step"]
    compressed = [p for k, p in evs
                  if k == "step" and "payload_bytes" in p]
    assert compressed and all(p["step"] > mitigated_at for p in compressed)
    assert jax.tree.leaves(state.residual)           # residual attached
    assert not np.isnan(rep.losses).any()


def test_mitigated_compression_sticks_across_restore(cfg):
    """A mid-run ENABLE_COMPRESSION outlives the process: a restart whose
    config still says "none" resumes compressed with its residual (the
    scheme is run state, recorded in checkpoint metadata)."""
    ckpt = tempfile.mkdtemp()
    _run(cfg, "int8", steps=8, ckpt_dir=ckpt, interval=4)
    run = RunConfig(total_steps=4, warmup_steps=1, lr=1e-3, zero1=False,
                    checkpoint_interval=4, checkpoint_dir=ckpt)
    assert run.grad_compression == "none"
    tr = TransientTrainer(cfg, run, ShardedLoader(
        SyntheticTokenSource(cfg.vocab_size, 24), 8), holder="worker-9")
    tr.ckpt.lease.notify_revoked()
    state, start = tr.restore_or_init()
    assert start == 8
    assert tr.run.grad_compression == "int8"
    leaves = jax.tree.leaves(state.residual)
    assert leaves and any(np.abs(np.asarray(l)).max() > 0 for l in leaves)


def test_mitigation_guard_respects_cap(cfg):
    run = RunConfig(total_steps=16, warmup_steps=1, checkpoint_interval=0,
                    checkpoint_dir=tempfile.mkdtemp(), lr=1e-3, zero1=False)
    tr = TransientTrainer(cfg, run,
                          ShardedLoader(SyntheticTokenSource(
                              cfg.vocab_size, 24), 8),
                          ps_model=PSBottleneckModel(5e9, 1),
                          workers=[WorkerSpec("v100", 1e4)] * 4,
                          predicted_speed=4e4, max_mitigations=0)
    state, _ = tr.restore_or_init()
    state, rep = tr.run_steps(state, 12, check_every=5)
    assert rep.mitigations == []                     # detected but capped
    assert any(d.bottleneck for d in rep.detections)
    assert tr.run.grad_compression == "none"


# ------------------------------------------------------- async-PS mode
def test_session_async_ps_mode_emits_staleness_histogram():
    s = Session.from_arch("qwen3-1.7b", total_steps=10, lr=1e-3,
                          zero1=False)
    rep = s.train(10, global_batch=4, seq_len=32, members=3,
                  mode="async_ps")
    assert rep.steps_run == 10
    assert not np.isnan(rep.losses).any()
    assert len(s.bus.of_kind("async_step")) == 10
    stale = s.bus.of_kind("staleness")
    assert len(stale) == 1
    payload = stale[0].payload
    assert sum(payload["hist"].values()) == 10
    assert max(payload["hist"]) >= 1                 # staleness occurred
    assert set(payload["worker_updates"]) == {0, 1, 2}
    assert set(payload["worker_step_time"]) == {0, 1, 2}
    assert all(t > 0 for t in payload["worker_step_time"].values())
    with pytest.raises(ValueError):
        s.train(2, mode="definitely-not-a-mode")
    # serve() after an async train uses the trained weights, like sync
    assert s._last_state is not None
    assert jax.tree.leaves(s._last_state.params)
    # sync-only arguments are rejected loudly, not silently dropped
    with pytest.raises(ValueError, match="checkpoint_dir"):
        s.train(2, mode="async_ps", checkpoint_dir=tempfile.mkdtemp())
    with pytest.raises(ValueError, match="worker_step_times"):
        s.train(2, mode="sync", worker_step_times=[0.1, 0.2])


# ------------------------------------------------------- satellite fixes
def test_restores_counter_reported(cfg):
    ckpt = tempfile.mkdtemp()
    _run(cfg, "none", steps=8, ckpt_dir=ckpt, interval=4)
    run = RunConfig(total_steps=4, warmup_steps=1, lr=1e-3, zero1=False,
                    checkpoint_interval=4, checkpoint_dir=ckpt)
    tr = TransientTrainer(cfg, run, ShardedLoader(
        SyntheticTokenSource(cfg.vocab_size, 24), 8), holder="worker-9")
    tr.ckpt.lease.notify_revoked()
    state, start = tr.restore_or_init()
    assert start == 8
    _, rep = tr.run_steps(state, 2)
    assert rep.restores == 1                         # was always 0


def test_profiler_step_time_distinguishes_stall_from_no_data():
    prof = PerformanceProfiler(window=2, warmup_steps=0, warmup_seconds=0.0)
    assert prof.step_time() is None                  # genuinely no data
    prof.record(5, t=0.0)
    prof.record(5, t=1.0)                            # stalled: 0.0 steps/s
    assert prof.speed() == 0.0
    assert prof.step_time() == float("inf")          # data, not None
    prof.record(6, t=1.5)
    assert prof.step_time() == pytest.approx(1.5 / 1)


# ------------------------------------------------------- perf gate (CI)
def test_bench_regression_gate(tmp_path):
    import importlib.util
    import json
    import pathlib
    spec = importlib.util.spec_from_file_location(
        "check_bench_regression",
        pathlib.Path(__file__).parent.parent / "scripts"
        / "check_bench_regression.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    def write(name, speedup, engine_speedup=12.0, jit_speedup=6.0):
        p = tmp_path / name
        p.write_text(json.dumps({
            "planner_grid": {"speedup": speedup, "batched_s": 0.01},
            "ensemble": {"traj_per_s": 100.0},
            "batched_engine": {"speedup": engine_speedup,
                               "traj_per_s": 50000.0},
            "jit_engine": {"speedup": jit_speedup, "traj_per_s": 50000.0,
                           "devices": 1}}))
        return str(p)

    base = write("base.json", 50.0)
    assert mod.main(["--baseline", base,
                     "--current", write("ok.json", 45.0)]) == 0
    assert mod.main(["--baseline", base,                      # >20% slower
                     "--current", write("bad.json", 30.0)]) == 1
    # the lockstep engine has an absolute floor on top of the relative one
    assert mod.main(["--baseline", base,
                     "--current", write("eng.json", 45.0, 9.0)]) == 1
    assert mod.main(["--baseline", base,
                     "--current", write("eng2.json", 45.0, 10.5),
                     "--min-engine-speedup", "10.0"]) == 0
    # ... and so does the jit engine (default floor 5x)
    assert mod.main(["--baseline", base,
                     "--current", write("jit.json", 45.0,
                                        jit_speedup=4.5)]) == 1
    assert mod.main(["--baseline", base,
                     "--current", write("jit2.json", 45.0,
                                        jit_speedup=5.5),
                     "--min-jit-speedup", "5.0"]) == 0
    # a current file missing an engine metric fails the gate
    (tmp_path / "noeng.json").write_text(json.dumps({
        "planner_grid": {"speedup": 50.0}, "ensemble": {}}))
    assert mod.main(["--baseline", base,
                     "--current", str(tmp_path / "noeng.json")]) == 1
    (tmp_path / "empty.json").write_text("{}")
    assert mod.main(["--baseline", str(tmp_path / "empty.json"),
                     "--current", base]) == 1


# ------------------------------------------------------------------ CLI
def test_cli_mode_and_compression_flags():
    from repro.launch import cli
    p = cli.make_parser("t", "t")
    cli.add_arch_arg(p)
    cli.add_scale_args(p)
    cli.add_batch_args(p)
    cli.add_train_args(p)
    args = p.parse_args(["--steps", "5", "--mode", "async_ps",
                         "--grad-compression", "int8"])
    assert args.mode == "async_ps"
    run = cli.run_config_from_args(args)
    assert run.grad_compression == "int8"
    with pytest.raises(SystemExit):
        p.parse_args(["--grad-compression", "fp4"])
