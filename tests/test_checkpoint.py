"""Checkpointer: roundtrip exactness, atomicity, lease handover, size
accounting (the S_d/S_i/S_m features for §IV)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, WriterLease


@pytest.fixture
def tree():
    k = jax.random.PRNGKey(0)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": jax.random.normal(k, (3, 3, 3)).astype(jnp.bfloat16)},
        "scalar": jnp.float32(3.5),
    }


def test_roundtrip_exact(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), holder="w0")
    sizes = ck.save(7, tree)
    assert sizes is not None and sizes.s_d > 0
    restored, step = ck.restore(tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, dtype=np.float32)
                                      if a.dtype == jnp.bfloat16 else
                                      np.asarray(a),
                                      np.asarray(b, dtype=np.float32)
                                      if np.asarray(b).dtype.name == "bfloat16"
                                      else np.asarray(b))


def test_latest_pointer_and_gc(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), holder="w0", keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.latest_step() == 4
    assert ck.all_steps() == [3, 4]  # gc keeps 2


def test_sizes_grow_with_params(tmp_path):
    ck = Checkpointer(str(tmp_path), holder="w0")
    small = ck.save(1, {"w": jnp.zeros((10, 10))})
    big = ck.save(2, {"w": jnp.zeros((100, 100))})
    assert big.s_d > small.s_d
    assert big.s_i == pytest.approx(small.s_i, rel=0.5)  # index ~ tensor count


def test_lease_blocks_second_writer(tmp_path, tree):
    ck0 = Checkpointer(str(tmp_path), holder="w0")
    ck1 = Checkpointer(str(tmp_path), holder="w1")
    assert ck0.save(1, tree) is not None
    assert ck1.save(2, tree) is None           # w0 holds the lease
    assert ck1.latest_step() == 1


def test_lease_handover_on_revocation(tmp_path, tree):
    """The Fig-11 fix: revocation notification frees the lease immediately;
    a survivor takes over checkpointing with no recomputation window."""
    ck0 = Checkpointer(str(tmp_path), holder="w0")
    ck1 = Checkpointer(str(tmp_path), holder="w1")
    ck0.save(1, tree)
    ck0.lease.notify_revoked()      # transient-TF hook fires on revocation
    assert ck1.save(2, tree) is not None
    assert ck1.latest_step() == 2
    assert ck1.lease.held_by_me()


def test_atomic_commit_never_partial(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), holder="w0")
    ck.save(1, tree)
    # a stale tmp dir from a "crashed" writer must not corrupt restore
    os.makedirs(os.path.join(str(tmp_path), ".tmp_step_2"), exist_ok=True)
    restored, step = ck.restore(tree)
    assert step == 1


def test_restore_resumes_training_state(tmp_path):
    from repro.configs import RunConfig, get_config
    from repro.launch import steps as st
    from repro.models import api
    cfg = get_config("qwen3-1.7b", smoke=True)
    run = RunConfig(zero1=False)
    step_fn, opt = st.make_train_step(cfg, run)
    params, _ = api.init(cfg)
    state = st.TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    batch = api.make_batch(cfg, __import__("repro.configs",
                                           fromlist=["TRAIN_4K"]).TRAIN_4K,
                           batch_override=2, seq_override=16)
    state, _ = jax.jit(step_fn)(state, batch)
    ck = Checkpointer(str(tmp_path), holder="w0")
    ck.save(int(state.step), state)
    restored, s = ck.restore(jax.eval_shape(lambda: state))
    state2 = jax.tree.map(jnp.asarray, restored)
    # continuing from restored state gives identical metrics
    _, m1 = jax.jit(step_fn)(state, batch)
    _, m2 = jax.jit(step_fn)(state2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-6)
