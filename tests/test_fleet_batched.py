"""Lockstep batched fleet engine tests: batched-vs-event parity (exact
revocation/replacement counts under the shared `FleetDraws` streams, KS
agreement on time/cost distributions), censoring, all three providers
including the AWS 2-minute warning window, `grad_compression` in the
simulated PS term, the `score="sim"` planner, and the vectorized
`ps_queue_sim` against a pinned copy of the retired heap loop."""
import heapq
import math

import numpy as np
import pytest

from repro.core.ps_async import ps_queue_sim
from repro.core.perf_model.cluster_model import (PS_NET_BYTES_PER_S,
                                                 PSBottleneckModel)
from repro.core.scheduler import plan_launch
from repro.core.transient.fleet import FleetEnsemble, FleetSim, SimWorker
from repro.core.transient.fleet_batched import FleetDraws, run_batched


def _mk_sim(seed=0, provider="gcp", region="us-central1", gpu="v100",
            sp=15.61, n_workers=4, handover=True, replace=True, i_c=4000,
            t_c=3.84, n_tensors=0, grad_compression="none",
            model_bytes=1.87e6, n_ps=1):
    workers = [SimWorker(i, gpu, region, sp) for i in range(n_workers)]
    return FleetSim(workers, model_gflops=1.54, model_bytes=model_bytes,
                    step_speed_of=lambda g: sp,
                    checkpoint_interval_steps=i_c, checkpoint_time_s=t_c,
                    n_ps=n_ps, seed=seed, handover=handover, replace=replace,
                    price_of={gpu: 0.74}, provider=provider,
                    n_tensors=n_tensors, grad_compression=grad_compression)


def _both(sim_kwargs, run_args):
    a = _mk_sim(**sim_kwargs).run_many(*run_args, engine="batched")
    b = _mk_sim(**sim_kwargs).run_many(*run_args, engine="event")
    return a, b


def _ks_distance(a, b):
    grid = np.sort(np.concatenate([a, b]))
    fa = np.searchsorted(np.sort(a), grid, side="right") / len(a)
    fb = np.searchsorted(np.sort(b), grid, side="right") / len(b)
    return float(np.max(np.abs(fa - fb)))


# ------------------------------------------------- engine parity (exact)
@pytest.mark.parametrize("provider,region,gpu,handover", [
    ("gcp", "us-central1", "v100", True),
    ("gcp", "europe-west1", "k80", False),   # revocation-heavy + stock chief
    ("aws", "us-east-1", "v100", True),
    ("azure", "southeastasia", "v100", False),
])
def test_engines_agree_exactly_on_shared_draws(provider, region, gpu,
                                               handover):
    """Both engines consume the same `FleetDraws` streams, so identical
    pre-drawn lifetimes and replacement chains must give EXACT
    revocation/replacement counts per trajectory; times/costs agree up
    to float association order (the batched stepper walks checkpoint
    pauses in closed form, the event loop incrementally)."""
    kw = dict(seed=3, provider=provider, region=region, gpu=gpu, sp=4.56,
              handover=handover)
    a, b = _both(kw, (400_000, 24, 60.0, 7.0))
    assert [r.revocations for r in a.results] == \
        [r.revocations for r in b.results]
    assert [r.replacements for r in a.results] == \
        [r.replacements for r in b.results]
    np.testing.assert_allclose([r.total_time_s for r in a.results],
                               [r.total_time_s for r in b.results],
                               rtol=1e-9)
    np.testing.assert_allclose([r.monetary_cost for r in a.results],
                               [r.monetary_cost for r in b.results],
                               rtol=1e-9)
    np.testing.assert_allclose([r.checkpoint_time_s for r in a.results],
                               [r.checkpoint_time_s for r in b.results],
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose([r.lost_steps for r in a.results],
                               [r.lost_steps for r in b.results],
                               rtol=1e-6, atol=1e-6)
    assert a.stats.finished == b.stats.finished


def test_engines_agree_distributionally_ks():
    """Beyond per-trajectory equality: the time/cost samples of the two
    engines pass a two-sample KS test at the ~99.9% level (trivially,
    given exactness — this guards a future engine change that keeps
    counts but drifts the continuous laws)."""
    kw = dict(seed=11, region="us-central1", gpu="v100", sp=15.61,
              n_workers=4)
    a, b = _both(kw, (600_000, 96, 80.0, 12.0))
    ta = np.array([r.total_time_s for r in a.results])
    tb = np.array([r.total_time_s for r in b.results])
    ca = np.array([r.monetary_cost for r in a.results])
    cb = np.array([r.monetary_cost for r in b.results])
    n_eff = len(ta) / 2.0
    assert _ks_distance(ta, tb) < 1.95 / math.sqrt(n_eff)
    assert _ks_distance(ca, cb) < 1.95 / math.sqrt(n_eff)


def test_stock_chief_recompute_parity_and_positive():
    """handover=False on a revocation-heavy cell: the stock chief loses
    steps (Fig 11) identically in both engines."""
    kw = dict(seed=1, region="europe-west1", gpu="k80", sp=4.56,
              n_workers=8, handover=False, i_c=1000)
    a, b = _both(kw, (300_000, 32, 80.0, 0.0))
    lost_a = [r.lost_steps for r in a.results]
    np.testing.assert_allclose(lost_a, [r.lost_steps for r in b.results],
                               rtol=1e-6, atol=1e-6)
    assert sum(lost_a) > 0          # the pathology actually exercised
    np.testing.assert_allclose([r.recompute_time_s for r in a.results],
                               [r.recompute_time_s for r in b.results],
                               rtol=1e-6, atol=1e-6)


def test_aws_warning_window_graceful_checkpoint():
    """AWS's 2-minute notice covers T_c, so even stock identity-reuse
    (handover=False) loses no steps — in both engines; GCP's 30 s notice
    is ignored by stock frameworks, so the same setup there loses steps."""
    kw = dict(seed=2, provider="aws", region="us-east-1", gpu="v100",
              sp=4.56, n_workers=6, handover=False, i_c=1000, t_c=60.0)
    a, b = _both(kw, (400_000, 32, 80.0, 9.0))
    assert sum(r.revocations for r in a.results) > 0
    assert all(r.lost_steps == 0 for r in a.results)
    assert all(r.lost_steps == 0 for r in b.results)
    gcp = _mk_sim(seed=2, region="europe-west1", gpu="k80", sp=4.56,
                  n_workers=6, handover=False, i_c=1000, t_c=60.0)
    ens = gcp.run_many(400_000, 32, max_hours=80.0, engine="batched")
    assert sum(r.lost_steps for r in ens.results) > 0


def test_batched_censoring_reported():
    ens = _mk_sim(seed=0).run_many(10_000_000, 8, max_hours=0.5,
                                   engine="batched")
    assert isinstance(ens, FleetEnsemble)
    assert ens.stats.finished == 0
    assert all(r.steps_done < 10_000_000 for r in ens.results)
    # censoring parity with the oracle
    ev = _mk_sim(seed=0).run_many(10_000_000, 8, max_hours=0.5,
                                  engine="event")
    assert [r.steps_done for r in ens.results] == \
        pytest.approx([r.steps_done for r in ev.results], abs=1)


def test_no_replace_freezes_dead_fleet():
    """replace=False: once every worker is revoked the trajectory
    freezes where it stands (the event loop's `sp <= 0 and not q`
    break) — identically in both engines."""
    kw = dict(seed=5, region="europe-west1", gpu="k80", sp=4.56,
              n_workers=2, replace=False)
    a, b = _both(kw, (5_000_000, 24, 100.0, 0.0))
    np.testing.assert_allclose([r.total_time_s for r in a.results],
                               [r.total_time_s for r in b.results],
                               rtol=1e-9)
    assert [r.steps_done for r in a.results] == \
        pytest.approx([r.steps_done for r in b.results], abs=1)
    assert any(r.steps_done < 5_000_000 for r in a.results)


def test_engines_agree_on_finished_for_awkward_step_counts():
    """Float-fuzzed completions: steps accumulates float increments, so
    a finished run can sit an ulp below total_steps — both engines must
    round it up (the event loop used to truncate to total-1 and report
    finished=0 for completed runs)."""
    for total in (12345, 4321, 99991):
        kw = dict(seed=0, sp=3.7, n_workers=4, i_c=997, t_c=1.3)
        a = _mk_sim(**kw).run_many(total, 6, max_hours=1000.0,
                                   engine="batched")
        b = _mk_sim(**kw).run_many(total, 6, max_hours=1000.0,
                                   engine="event")
        assert a.stats.finished == b.stats.finished == 6
        assert [r.steps_done for r in a.results] == \
            [r.steps_done for r in b.results]


def test_run_many_rejects_unknown_engine():
    with pytest.raises(ValueError, match="unknown engine"):
        _mk_sim().run_many(1000, 2, engine="warp")


def test_single_run_unchanged_by_engine_dispatch():
    """`run()` keeps its historic sequential streams bit-for-bit: the
    engine dispatch and shared draws only apply to `run_many`."""
    a = _mk_sim(seed=2).run(200_000, max_hours=100.0)
    _ = _mk_sim(seed=2).run_many(200_000, 4, max_hours=100.0)
    b = _mk_sim(seed=2).run(200_000, max_hours=100.0)
    assert a.total_time_s == b.total_time_s
    assert a.revocations == b.revocations


# ------------------------------------------- grad_compression in the sim
def test_sim_ps_term_sees_grad_compression():
    """A PS-bound fleet (big payload, 1 PS) speeds up under int8
    compression exactly as `PSBottleneckModel` predicts — the simulator
    no longer ignores the scheme the §VI-B predictor applies."""
    kw = dict(model_bytes=4.0e8, n_workers=8, sp=15.61, i_c=100_000,
              seed=4)
    plain = _mk_sim(**kw)
    comp = _mk_sim(grad_compression="int8", **kw)
    cap_plain = PSBottleneckModel(4.0e8, 1).capacity_steps_per_s()
    cap_comp = PSBottleneckModel(4.0e8, 1,
                                 compression="int8").capacity_steps_per_s()
    assert cap_comp == pytest.approx(4 * cap_plain)
    e_plain = plain.run_many(50_000, 8, max_hours=200.0)
    e_comp = comp.run_many(50_000, 8, max_hours=200.0)
    # int8 quarters the wire bytes -> 4x the PS ceiling -> ~4x faster
    ratio = e_plain.stats.time_mean_s / e_comp.stats.time_mean_s
    assert ratio > 2.0
    # both engines apply the same compressed capacity
    e_event = comp.run_many(50_000, 8, max_hours=200.0, engine="event")
    np.testing.assert_allclose([r.total_time_s for r in e_comp.results],
                               [r.total_time_s for r in e_event.results],
                               rtol=1e-9)


def test_session_simulate_engine_and_compression(tmp_path):
    from repro.api import Session
    s = Session.from_arch("qwen3-1.7b", total_steps=300,
                          checkpoint_interval=100, zero1=False)
    ens_b = s.simulate(n_workers=2, gpu="v100", steps=300, seed=0,
                       samples=6, engine="batched")
    ens_e = s.simulate(n_workers=2, gpu="v100", steps=300, seed=0,
                       samples=6, engine="event")
    np.testing.assert_allclose(
        [r.total_time_s for r in ens_b.results],
        [r.total_time_s for r in ens_e.results], rtol=1e-9)
    comp = Session.from_arch("qwen3-1.7b", total_steps=300,
                             checkpoint_interval=100, zero1=False,
                             grad_compression="int8")
    ens_c = comp.simulate(n_workers=2, gpu="v100", steps=300, seed=0,
                          samples=6)
    # same model, compressed wire: never slower than uncompressed
    assert ens_c.stats.time_mean_s <= ens_b.stats.time_mean_s + 1e-9


# ------------------------------------------------- sim-scored planner
def test_plan_launch_sim_score_golden_and_fields():
    """us-west1 is by far the most stable K80 region (Table V), so the
    simulation-backed grid must rank it fastest and least-revoked. (The
    realized-$ ranking is allowed to differ from Eq (4)'s: a revoked
    worker accrues no GPU-hours while its replacement spins up, so a
    churny region can be marginally cheaper in $ yet slower — exactly
    the distinction simulation-backed scoring surfaces.) Every
    sim-scored plan carries ordered percentiles and its censoring
    count."""
    best, plans = plan_launch("k80", 4, 4.56, n_w=400_000, i_c=4000,
                              t_c=3.84, hours=[0, 12], seed=0,
                              samples=96, score="sim")
    fastest = {}
    for p in plans:
        cur = fastest.get(p.region)
        if cur is None or p.expected_time_s < cur.expected_time_s:
            fastest[p.region] = p
    uw = fastest["us-west1"]
    assert all(uw.expected_time_s <= p.expected_time_s + 1e-9
               for p in fastest.values())
    assert all(uw.expected_revocations <= p.expected_revocations + 1e-9
               for p in fastest.values())
    for p in plans:
        assert p.score == "sim"
        assert p.samples == 96
        assert p.time_p50_s <= p.time_p90_s
        assert p.cost_p50 <= p.cost_p90
        assert 0 <= p.finished <= 96
        assert p.expected_cost > 0
    assert best.expected_cost == min(p.expected_cost for p in plans)


def test_plan_launch_sim_engines_agree():
    _, pb = plan_launch("v100", 2, 15.61, n_w=200_000, i_c=4000, t_c=3.84,
                        hours=[6], seed=1, samples=24, score="sim",
                        engine="batched")
    _, pe = plan_launch("v100", 2, 15.61, n_w=200_000, i_c=4000, t_c=3.84,
                        hours=[6], seed=1, samples=24, score="sim",
                        engine="event")
    for a, b in zip(pb, pe):
        assert (a.region, a.launch_hour) == (b.region, b.launch_hour)
        assert a.expected_revocations == b.expected_revocations
        assert a.expected_time_s == pytest.approx(b.expected_time_s,
                                                  rel=1e-9)
        assert a.expected_cost == pytest.approx(b.expected_cost, rel=1e-9)


def test_plan_launch_sim_rejects_bad_score():
    with pytest.raises(ValueError, match="unknown score"):
        plan_launch("v100", 2, 10.0, n_w=1000, i_c=100, t_c=1.0,
                    hours=[0], score="montecarlo")


def test_session_plan_sim_score():
    from repro.api import Session
    s = Session.from_arch("qwen3-1.7b", total_steps=20_000,
                          checkpoint_interval=1000, zero1=False)
    best, plans = s.plan(gpu="v100", n_workers=2, steps=20_000,
                         hours=[0, 12], samples=16, score="sim")
    assert best.score == "sim"
    assert len(plans) == 2 * len({p.region for p in plans})
    assert all(p.time_p90_s >= p.time_p50_s for p in plans)
    # sim scoring always models the Fig 4 PS capacity (1 PS default) —
    # the same configuration simulate() uses, so an explicit n_ps=1
    # changes nothing
    explicit, _ = s.plan(gpu="v100", n_workers=2, steps=20_000,
                         hours=[0, 12], samples=16, score="sim", n_ps=1)
    assert explicit.expected_time_s == best.expected_time_s
    assert explicit.expected_cost == best.expected_cost


def test_cli_plan_forwards_n_ps(capsys):
    """`repro plan --n-ps` must reach Session.plan (it was parsed and
    silently dropped before); the plan parser defaults it to None so
    eq4 planning stays uncapped unless asked."""
    from repro import __main__ as main_mod
    parser = main_mod.build_parser()
    args = parser.parse_args(["plan", "--gpu", "v100", "--workers", "2",
                              "--samples", "8"])
    assert args.n_ps is None
    args = parser.parse_args(["plan", "--gpu", "v100", "--workers", "2",
                              "--samples", "8", "--n-ps", "2"])
    assert args.n_ps == 2
    assert main_mod._cmd_plan(args) == 0
    assert "best:" in capsys.readouterr().out


# ------------------------------------- vectorized ps_queue_sim parity
def _heap_reference(compute_times, model_bytes, n_ps=1,
                    ps_bw=PS_NET_BYTES_PER_S, steps=400, seed=0,
                    n_tensors=0, grad_compression="none"):
    """The retired per-push heap loop, pinned verbatim as the parity
    reference for the array-reduction stepper."""
    n = len(compute_times)
    service = PSBottleneckModel(model_bytes, n_ps, ps_bw,
                                n_tensors=n_tensors,
                                compression=grad_compression
                                ).service_time_s()
    q = []
    rng = np.random.default_rng(seed)
    for w, ct in enumerate(compute_times):
        heapq.heappush(q, (ct * rng.uniform(0.2, 1.0), w))
    ps_free_at = 0.0
    done_steps = np.zeros(n, int)
    finish_t = np.zeros(n, float)
    busy = 0.0
    while q:
        t, w = heapq.heappop(q)
        start = max(t, ps_free_at)
        ps_free_at = start + service
        busy += service
        done_steps[w] += 1
        finish_t[w] = start
        if done_steps[w] < steps:
            heapq.heappush(q, (start + compute_times[w], w))
    eff = {w: finish_t[w] / done_steps[w] for w in range(n)}
    total = float(finish_t.max())
    return eff, float(done_steps.sum()) / total, busy / total


@pytest.mark.parametrize("cts,mb,kw", [
    ([0.082] * 4, 1.87e6, dict(n_tensors=97)),      # unsaturated, uniform
    ([0.082] * 12, 1.87e6, dict(n_tensors=97)),     # saturated plateau
    ([0.05, 0.08, 0.22, 0.3, 0.082], 1.87e6, dict(n_tensors=97)),  # hetero
    ([0.1], 9.8e7, {}),                             # n=1 network-bound
    ([0.02] * 8, 9.8e7, dict(grad_compression="int8")),
    ([0.082] * 6, 1.87e6, dict(n_ps=2, n_tensors=97)),
    ([0.219, 0.219, 0.082, 0.064], 1.87e6, dict(n_tensors=97)),  # §II mix
])
def test_ps_queue_sim_matches_heap_reference(cts, mb, kw):
    for steps in (60, 300):
        res = ps_queue_sim(cts, mb, steps=steps, **kw)
        eff, cs, util = _heap_reference(cts, mb, steps=steps, **kw)
        np.testing.assert_allclose(
            [res.worker_step_time[w] for w in range(len(cts))],
            [eff[w] for w in range(len(cts))], rtol=1e-9)
        assert res.cluster_speed == pytest.approx(cs, rel=1e-9)
        assert res.ps_utilization == pytest.approx(util, rel=1e-9)


def test_ps_queue_sim_rejects_nonpositive_steps():
    """steps <= 0 must fail loudly instead of hanging the array rounds
    (workers would start with nothing to serve and never drain)."""
    with pytest.raises(ValueError, match="at least one step"):
        ps_queue_sim([0.1] * 12, 1.87e6, steps=0)
    with pytest.raises(ValueError, match="at least one step"):
        ps_queue_sim([0.1, 0.2], 1.87e6, steps=-3)


def test_sim_stats_revocations_stderr_matches_planner():
    """SimStats owns the trajectory-sample SEM; the sim-scored planner
    reads it instead of re-deriving it."""
    ens = _mk_sim(seed=1, region="europe-west1", gpu="k80", sp=4.56,
                  n_workers=4).run_many(200_000, 16, max_hours=60.0)
    revs = [float(r.revocations) for r in ens.results]
    expect = float(np.std(revs, ddof=1)) / math.sqrt(len(revs))
    assert ens.stats.revocations_stderr == pytest.approx(expect)
    _, plans = plan_launch("k80", 4, 4.56, n_w=200_000, i_c=4000,
                           t_c=3.84, hours=[0], seed=1, samples=16,
                           score="sim")
    assert all(p.revocation_stderr >= 0.0 for p in plans)


def test_ps_queue_sim_fuzz_against_reference():
    """Random populations/paces: aggregates match the pinned heap loop
    within the documented float-association bound (~0.5% for short
    runs; near-coincident arrivals may serve in either order)."""
    rng = np.random.default_rng(11)
    for _ in range(15):
        nn = int(rng.integers(1, 24))
        r = rng.random()
        cts = ([float(rng.uniform(0.01, 0.4))] * nn if r < 0.4 else
               list(rng.choice([0.219, 0.082, 0.064], nn)) if r < 0.7 else
               list(rng.uniform(0.01, 0.4, nn)))
        mb = float(rng.choice([1.87e6, 5e7, 9.8e7]))
        kw = dict(n_tensors=int(rng.integers(0, 120)),
                  n_ps=int(rng.integers(1, 3)))
        res = ps_queue_sim(cts, mb, steps=120, **kw)
        eff, cs, util = _heap_reference(cts, mb, steps=120, **kw)
        np.testing.assert_allclose(
            [res.worker_step_time[w] for w in range(len(cts))],
            [eff[w] for w in range(len(cts))], rtol=1e-2)
        assert res.cluster_speed == pytest.approx(cs, rel=5e-3)
        assert res.ps_utilization == pytest.approx(util, rel=1e-2)


# ------------------------------------------------ FleetDraws invariants
def test_fleet_draws_deterministic_and_order_independent():
    sim = _mk_sim(seed=9)
    d1 = FleetDraws(sim, 16, 0.0)
    d2 = FleetDraws(sim, 16, 0.0)
    np.testing.assert_array_equal(d1.initial, d2.initial)
    # pool values do not depend on request order
    a = d1.replacement_delay(3, 1, 2)
    b = d2.replacement_delay(0, 0, 1)
    assert d2.replacement_delay(3, 1, 2) == a
    assert d1.replacement_delay(0, 0, 1) == b
    la = d1.join_lifetime(5, 2, 1, 13.25)
    assert d2.join_lifetime(5, 2, 1, 13.25) == la
    # batch and scalar paths agree bit-for-bit
    lb = d2.join_lifetimes_batch(np.array([5]), np.array([2]),
                                 np.array([1]), np.array([13.25]))
    assert float(lb[0]) == la


def test_run_batched_matches_run_many_wrapper():
    sim = _mk_sim(seed=7)
    results = run_batched(sim, 100_000, 6, max_hours=100.0, start_hour=3.0)
    ens = _mk_sim(seed=7).run_many(100_000, 6, max_hours=100.0,
                                   start_hour=3.0, engine="batched")
    assert [r.total_time_s for r in results] == \
        [r.total_time_s for r in ens.results]
    assert [r.revocations for r in results] == \
        [r.revocations for r in ens.results]
