"""Unit + property tests for the paper's modeling stack (§III, §IV, §VI)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.perf_model.checkpoint_model import CkptRow, table4_models
from repro.core.perf_model.cluster_model import (Eq4Inputs, PSBottleneckModel,
                                                 WorkerSpec, cluster_speed,
                                                 expected_revocations,
                                                 predict_total_time)
from repro.core.perf_model.features import c_norm, minmax_apply, minmax_fit
from repro.core.perf_model.regression import (LinearModel, PCA, kfold_mae,
                                              mae, mape, train_test_split)
from repro.core.perf_model.speed_model import (TABLE1_MODELS, TABLE1_SPEED,
                                               calibrate_generators,
                                               synth_dataset, table2_models)
from repro.core.perf_model.svr import SVR, grid_search_svr


# ------------------------------------------------------------------ features
@given(st.lists(st.floats(0.1, 1e3), min_size=2, max_size=30))
def test_minmax_bounds(xs):
    lo, hi = minmax_fit(np.array(xs))
    z = minmax_apply(np.array(xs), lo, hi)
    assert np.all(z >= -1e-12) and np.all(z <= 1 + 1e-12)


# ---------------------------------------------------------------- regression
@given(st.floats(-5, 5), st.floats(-5, 5),
       st.lists(st.floats(-10, 10), min_size=5, max_size=40))
@settings(max_examples=25, deadline=None)
def test_ols_exact_on_linear_data(a, b, xs):
    X = np.array(xs)[:, None]
    if np.ptp(X) < 1e-6:
        return
    y = a * X[:, 0] + b
    m = LinearModel().fit(X, y)
    assert mae(y, m.predict(X)) < 1e-6


def test_pca_recovers_dominant_direction():
    rng = np.random.default_rng(0)
    z = rng.normal(size=(200, 1))
    X = np.concatenate([3 * z, z, 0.01 * rng.normal(size=(200, 1))], axis=1)
    p = PCA(1).fit(X)
    d = p.comps_[0] / np.linalg.norm(p.comps_[0])
    want = np.array([3.0, 1.0, 0.0]) / np.sqrt(10)
    assert abs(abs(d @ want) - 1.0) < 1e-2


def test_kfold_is_deterministic():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(30, 1))
    y = X[:, 0] * 2 + rng.normal(size=30) * 0.1
    fit = lambda Xt, yt: LinearModel().fit(Xt, yt)  # noqa: E731
    assert kfold_mae(fit, X, y) == kfold_mae(fit, X, y)


# ----------------------------------------------------------------------- SVR
def test_svr_rbf_beats_linear_on_nonlinear_data():
    x = np.linspace(0, 1, 30)[:, None]
    y = np.sin(6 * x[:, 0]) + 0.5 * x[:, 0]
    svr = SVR(kernel="rbf", C=50, epsilon=0.01).fit(x, y)
    lin = LinearModel().fit(x, y)
    assert mae(y, svr.predict(x)) < 0.3 * mae(y, lin.predict(x))


def test_svr_respects_box_constraint_and_eps_tube():
    x = np.linspace(0, 1, 20)[:, None]
    y = 2 * x[:, 0]
    m = SVR(kernel="rbf", C=10.0, epsilon=0.05).fit(x, y)
    assert np.all(np.abs(m.beta_) <= 10.0 + 1e-6)
    # interior points must lie inside the epsilon tube
    resid = np.abs(y - m.predict(x))
    interior = np.abs(m.beta_) < 10.0 - 1e-6
    assert np.all(resid[interior] <= 0.05 + 1e-3)


# ----------------------------------------------------------- speed model §III
def test_generator_reproduces_table1_exactly():
    gens = calibrate_generators()
    for gpu, speeds in TABLE1_SPEED.items():
        for model, sp in speeds.items():
            got = 1.0 / gens[gpu].step_time(TABLE1_MODELS[model])
            assert abs(got - sp) / sp < 1e-9


def test_table2_svr_rbf_wins_for_k80():
    rows = synth_dataset({**TABLE1_MODELS,
                          **{f"m{i}": 0.5 + 2.0 * i for i in range(16)}},
                         samples_per=3, seed=0)
    reports = {r.name: r for r in table2_models(rows)}
    assert reports["svr_rbf_k80"].kfold_mae <= \
        reports["univariate_k80"].kfold_mae + 1e-9


# ------------------------------------------------------------- cluster model
def test_ps_capacity_anchor_resnet32():
    # 97 tensors, 1.87 MB: capacity ~41 updates/s (Table III saturation)
    ps = PSBottleneckModel(1.87e6, 1, n_tensors=97)
    assert 38 < ps.capacity_steps_per_s() < 45


def test_cluster_speed_is_sum_until_cap():
    ps = PSBottleneckModel(1.87e6, 1, n_tensors=97)
    w = [WorkerSpec("p100", 12.19)] * 2
    assert cluster_speed(w, ps) == pytest.approx(24.38)
    w8 = [WorkerSpec("p100", 12.19)] * 8
    assert cluster_speed(w8, ps) == pytest.approx(
        ps.capacity_steps_per_s())


@given(st.lists(st.floats(0.1, 30), min_size=1, max_size=10))
def test_composition_monotone(speeds):
    workers = [WorkerSpec("x", s) for s in speeds]
    assert cluster_speed(workers) == pytest.approx(sum(speeds))


@given(st.integers(1000, 100000), st.integers(100, 5000),
       st.floats(0.5, 20.0), st.floats(0, 1), st.floats(0, 1))
@settings(max_examples=30, deadline=None)
def test_eq4_monotonicity(n_w, i_c, t_c, p1, p2):
    inp_lo = Eq4Inputs(n_w, i_c, t_c, 60.0, 30.0, [min(p1, p2)])
    inp_hi = Eq4Inputs(n_w, i_c, t_c, 60.0, 30.0, [max(p1, p2)])
    assert predict_total_time(5.0, inp_lo) <= predict_total_time(5.0, inp_hi)
    # faster cluster -> shorter time
    assert predict_total_time(10.0, inp_lo) < predict_total_time(5.0, inp_lo)


def test_eq5():
    assert expected_revocations([0.2, 0.3, 0.5]) == pytest.approx(1.0)


# ---------------------------------------------------------- checkpoint model
def test_table4_models_fit_linear_world():
    rng = np.random.default_rng(0)
    rows = []
    for i in range(20):
        s_d = float(rng.uniform(1e6, 100e6))
        s_m, s_i = s_d * 0.01, s_d * 0.002
        t = 0.3 + (s_d + s_m + s_i) / 120e6 + rng.normal(0, 0.01)
        rows.append(CkptRow(f"m{i}", s_d, s_m, s_i, t))
    reports = {r.name: r for r in table4_models(rows)}
    assert reports["univariate"].test_mape < 5.0
    assert reports["multivariate_pca2"].test_mape < 10.0
