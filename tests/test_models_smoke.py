"""Per-architecture smoke tests: a REDUCED config of each assigned family
runs one forward + one train step on CPU; output shapes verified, no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, RunConfig, TRAIN_4K, get_config
from repro.launch import steps as st
from repro.models import api

B, S = 2, 32


@pytest.fixture(scope="module")
def keyring():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch, keyring):
    cfg = get_config(arch, smoke=True)
    params, axes = api.init(cfg, keyring)
    batch = api.make_batch(cfg, TRAIN_4K, batch_override=B, seq_override=S)
    logits = api.prefill(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch, keyring):
    cfg = get_config(arch, smoke=True)
    run = RunConfig(optimizer="adamw", lr=2e-3, warmup_steps=1,
                    total_steps=10, zero1=False)
    step, opt = st.make_train_step(cfg, run)
    params, _ = api.init(cfg, keyring)
    state = st.TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    batch = api.make_batch(cfg, TRAIN_4K, batch_override=B, seq_override=S)
    jit_step = jax.jit(step)
    state, m0 = jit_step(state, batch)
    for _ in range(4):
        state, m = jit_step(state, batch)
    assert float(m["loss"]) < float(m0["loss"]), (arch, m0["loss"], m["loss"])
    assert not jnp.isnan(m["loss"])
    assert int(state.step) == 5


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "hubert-xlarge"])
def test_decode_step_shapes(arch, keyring):
    cfg = get_config(arch, smoke=True)
    params, _ = api.init(cfg, keyring)
    state, _ = api.init_decode_state(cfg, batch=B, max_len=16)
    toks = jnp.zeros((B,), jnp.int32)
    logits, new_state = api.decode_step(params, cfg, state, toks, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    # state structure preserved
    assert jax.tree.structure(new_state) == jax.tree.structure(state)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-1.3b", "zamba2-1.2b"])
def test_decode_matches_forward(arch, keyring):
    cfg = get_config(arch, smoke=True).with_(dtype="float32")
    params, _ = api.init(cfg, keyring)
    S_ = 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S_), 0,
                              cfg.vocab_size)
    full = api.prefill(params, cfg, {"tokens": toks})
    state, _ = api.init_decode_state(cfg, batch=B, max_len=S_,
                                     dtype=jnp.float32)
    for i in range(S_):
        lg, state = api.decode_step(params, cfg, state, toks[:, i],
                                    jnp.int32(i))
        err = float(jnp.max(jnp.abs(lg - full[:, i])))
        scale = float(jnp.max(jnp.abs(full[:, i]))) + 1e-6
        assert err / scale < 1e-4, (arch, i, err, scale)


def test_encoder_has_no_decode():
    cfg = get_config("hubert-xlarge", smoke=True)
    with pytest.raises(ValueError):
        api.init_decode_state(cfg, batch=1, max_len=8)


def test_param_count_sanity():
    # full configs should be in the advertised ballpark
    assert 1.4e9 < get_config("qwen3-1.7b").param_count() < 2.4e9
    assert 13e9 < get_config("starcoder2-15b").param_count() < 18e9
    assert 1.0e9 < get_config("mamba2-1.3b").param_count() < 1.7e9
    ds = get_config("deepseek-v2-lite-16b")
    assert 10e9 < ds.param_count() < 20e9
    assert ds.active_param_count() < 0.35 * ds.param_count()
