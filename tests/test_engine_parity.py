"""Three-engine differential parity harness — the lock on `engine="jit"`.

`fleet_jit.run_jit` compiles the lockstep rounds into one jitted JAX
program; this file pins its contract against the other two engines: for
ANY (provider, fleet shape, horizon, compression, chaos scenario, seed)
all three must report exactly equal per-trajectory
revocation/replacement/step counts — they consume the same `FleetDraws`
uniform streams — and times/costs within float association tolerance.

Three layers:

* a committed seed corpus (`CORPUS`) of configurations that each pin a
  distinct code path (stock-chief step loss, AWS graceful window,
  no-replace frozen fleets, single-slot fleets, compression in the PS
  cap, deep replacement chains that force jit pool paging);
* a `hypothesis` fuzz sweep over the same axes (deterministic stub when
  the real package is absent — conftest.py);
* schedule-invariance regressions: results must be byte-identical
  whatever the `jax_enable_x64` global flag and whatever compaction
  schedule the host driver happens to pick, and exact under trajectory
  sharding with pad rows (multidevice CI job).
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.chaos.scenarios import get_scenario, list_scenarios
from repro.core.transient import fleet_jit
from repro.core.transient.fleet import FleetSim, SimWorker
from repro.core.transient.fleet_batched import FleetDraws
from repro.core.transient.fleet_jit import run_jit
from repro.providers import get_provider


def _mk_sim(seed=0, provider="gcp", region="us-central1", gpu="v100",
            sp=4.56, n_workers=4, handover=True, replace=True, i_c=4000,
            t_c=3.84, grad_compression="none", model_bytes=1.87e6):
    workers = [SimWorker(i, gpu, region, sp) for i in range(n_workers)]
    return FleetSim(workers, model_gflops=1.54, model_bytes=model_bytes,
                    step_speed_of=lambda g: sp,
                    checkpoint_interval_steps=i_c, checkpoint_time_s=t_c,
                    n_ps=1, seed=seed, handover=handover, replace=replace,
                    price_of={gpu: 0.74}, provider=provider,
                    grad_compression=grad_compression)


def _assert_parity(mk, run_args, engines=("batched", "event")):
    """run_many on the jit engine and every engine in `engines` from
    identical fresh sims; counts must be exactly equal, continuous stats
    equal up to float association order."""
    j = mk().run_many(*run_args, engine="jit")
    for other in engines:
        o = mk().run_many(*run_args, engine=other)
        assert [r.revocations for r in j.results] == \
            [r.revocations for r in o.results], f"vs {other}"
        assert [r.replacements for r in j.results] == \
            [r.replacements for r in o.results], f"vs {other}"
        assert [r.steps_done for r in j.results] == \
            pytest.approx([r.steps_done for r in o.results], abs=1)
        np.testing.assert_allclose([r.total_time_s for r in j.results],
                                   [r.total_time_s for r in o.results],
                                   rtol=1e-9)
        np.testing.assert_allclose([r.monetary_cost for r in j.results],
                                   [r.monetary_cost for r in o.results],
                                   rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose([r.checkpoint_time_s for r in j.results],
                                   [r.checkpoint_time_s for r in o.results],
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose([r.lost_steps for r in j.results],
                                   [r.lost_steps for r in o.results],
                                   rtol=1e-6, atol=1e-6)
        # recovery accrual (zeros when resilience is off) is part of the
        # contract too: pause windows and retry-delayed restores must be
        # engine-independent (docs/resilience.md)
        np.testing.assert_allclose([r.paused_s for r in j.results],
                                   [r.paused_s for r in o.results],
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose([r.restore_delay_s for r in j.results],
                                   [r.restore_delay_s for r in o.results],
                                   rtol=1e-6, atol=1e-6)
        assert j.stats.finished == o.stats.finished
    return j


# --------------------------------------------------------- seed corpus
# Each row froze a distinct engine code path while the jit engine was
# built; keep appending the shrunk form of any future fuzz failure.
#   (provider, region, gpu, workers, handover, replace, compression,
#    i_c, horizon_h, start_h, seed)
CORPUS = [
    ("gcp", "us-central1", "v100", 4, True, True, "none",
     4000, 48.0, 0.0, 0),         # the paper's baseline cell
    ("gcp", "europe-west1", "k80", 8, False, True, "none",
     1000, 32.0, 0.0, 3),         # revocation-heavy + stock-chief loss
    ("gcp", "us-west1", "k80", 2, True, False, "none",
     4000, 100.0, 7.0, 5),        # replace=False frozen dead fleets
    ("aws", "us-east-1", "v100", 6, False, True, "none",
     1000, 80.0, 9.0, 2),         # 2-min warning: graceful checkpoint
    ("azure", "southeastasia", "v100", 4, False, True, "int8",
     4000, 60.0, 13.5, 1),        # compressed PS cap in the sim
    ("azure", "southcentralus", "v100", 1, True, True, "none",
     4000, 12.0, 23.75, 7),       # single slot, censoring, hour wrap
]


@pytest.mark.parametrize("prov,region,gpu,nw,ho,rep,comp,i_c,mh,sh,seed",
                         CORPUS)
def test_corpus_three_engine_parity(prov, region, gpu, nw, ho, rep, comp,
                                    i_c, mh, sh, seed):
    def mk():
        return _mk_sim(seed=seed, provider=prov, region=region, gpu=gpu,
                       n_workers=nw, handover=ho, replace=rep,
                       grad_compression=comp, i_c=i_c)
    _assert_parity(mk, (250_000, 12, mh, sh))


@pytest.mark.slow
@given(cell=st.sampled_from([("gcp", "us-central1", "v100"),
                             ("gcp", "europe-west1", "k80"),
                             ("aws", "us-east-1", "v100"),
                             ("azure", "southeastasia", "v100")]),
       n_workers=st.sampled_from([1, 3, 4]),
       horizon=st.sampled_from([12.0, 48.0, 96.0]),
       compression=st.sampled_from(["none", "int8"]),
       handover=st.sampled_from([True, False]),
       start_hour=st.sampled_from([0.0, 7.0, 13.5]),
       seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_fuzz_three_engine_parity(cell, n_workers, horizon, compression,
                                  handover, start_hour, seed):
    prov, region, gpu = cell

    def mk():
        return _mk_sim(seed=seed, provider=prov, region=region, gpu=gpu,
                       n_workers=n_workers, handover=handover,
                       grad_compression=compression)
    _assert_parity(mk, (150_000, 12, horizon, start_hour))


# -------------------------------------------------- resilience parity
@pytest.mark.parametrize("quorum", [0.6, 0.9])
def test_resilience_three_engine_parity(quorum):
    """Recovery semantics ride the same contract: keyed restore-retry
    stalls after stock-chief revocations and quorum pause windows must
    reproduce bit-for-bit on all three engines (`paused_s` /
    `restore_delay_s` asserted inside `_assert_parity`), and arming a
    `ResilienceConfig` must not perturb counts or completion times'
    agreement."""
    from repro.resilience import (DegradationPolicy, ResilienceConfig,
                                  RetryPolicy)
    res = ResilienceConfig(
        retry=RetryPolicy(max_attempts=3, base_delay_s=60.0,
                          multiplier=2.0, max_delay_s=600.0, jitter=0.5,
                          deadline_s=1800.0),
        degradation=DegradationPolicy(quorum=quorum, shrink_below=0.95,
                                      shrink_factor=0.7),
        restore_fail_p=0.7, seed=5)

    def mk():
        sim = _mk_sim(seed=5, region="europe-west1", gpu="k80",
                      n_workers=8, handover=False, i_c=1000)
        sim.resilience = res
        return sim
    j = _assert_parity(mk, (250_000, 12, 32.0, 0.0))
    # the config is chosen so the stall channel always fires; the pause
    # channel needs the tight quorum (8-worker fleets rarely drop below
    # 60 % alive with replacement on)
    assert sum(r.restore_delay_s for r in j.results) > 0.0
    if quorum >= 0.9:
        assert sum(r.paused_s for r in j.results) > 0.0


# ----------------------------------------------------- chaos scenarios
@pytest.mark.slow
@pytest.mark.parametrize("name", list_scenarios())
def test_jit_parity_every_chaos_scenario(name):
    """All seven scripted fault timelines run under the jit engine —
    fault-window factors as piecewise-constant device tables, keyed
    join-hazard uniforms as a pool matrix — bit-identically to the
    other two engines."""
    sc = get_scenario(name)
    region = sc.region or get_provider(sc.provider).default_region

    def mk():
        sim = _mk_sim(seed=11, provider=sc.provider, region=region,
                      gpu=sc.gpu, n_workers=sc.n_workers,
                      handover=sc.handover)
        sim.chaos = sc.timeline(sim._roster, seed=11)
        return sim
    _assert_parity(mk, (sc.total_steps, 8, sc.max_hours))


@pytest.mark.slow
def test_chaos_scorecard_truth_hash_engine_and_x64_independent():
    """The scorecard a chaos run emits — truth spans, `truth_hash`,
    ensemble summaries — must be byte-identical whichever engine scored
    it and whatever the global `jax_enable_x64` flag (the latent
    nondeterminism this PR pins down)."""
    from repro.api import Session
    from repro.chaos.runner import _run_sim

    ses = Session.from_arch("qwen3-1.7b", smoke=True)
    sc = get_scenario("regional_wave")
    cards = {}
    prev = jax.config.jax_enable_x64
    try:
        for x64 in (False, True):
            jax.config.update("jax_enable_x64", x64)
            for eng in ("batched", "jit"):
                cards[(eng, x64)] = _run_sim(ses, sc, eng, 8, seed=1)
    finally:
        jax.config.update("jax_enable_x64", prev)
    ref_card = cards[("batched", False)]
    for key, card in cards.items():
        assert card["truth_hash"] == ref_card["truth_hash"], key
        assert card["truth"] == ref_card["truth"], key
        assert card["faulted"] == ref_card["faulted"], key
        assert card["baseline"] == ref_card["baseline"], key
        assert card["parity"]["counts_equal"], key
        assert card["parity"]["time_max_rel_err"] < 1e-9, key


# ------------------------------------------------ schedule invariances
def _raw_bytes_equal(a, b):
    assert set(a) == set(b)
    for key in a:
        assert np.asarray(a[key]).tobytes() == \
            np.asarray(b[key]).tobytes(), key


def test_results_independent_of_x64_flag():
    """run_jit pins float64 via `jax.experimental.enable_x64` no matter
    the global flag, so the raw result arrays are byte-identical with
    and without `jax_enable_x64`."""
    sim = _mk_sim(seed=6, region="europe-west1", gpu="k80", n_workers=4)
    draws = FleetDraws(sim, 32, 0.0)
    prev = jax.config.jax_enable_x64
    try:
        jax.config.update("jax_enable_x64", False)
        a = run_jit(sim, 200_000, 32, 48.0, draws=draws, raw=True)
        jax.config.update("jax_enable_x64", True)
        b = run_jit(sim, 200_000, 32, 48.0, draws=draws, raw=True)
    finally:
        jax.config.update("jax_enable_x64", prev)
    _raw_bytes_equal(a, b)


@pytest.mark.slow
def test_results_independent_of_compaction_schedule():
    """The host driver pages finished trajectories out between
    `lax.while_loop` entries; the body math is width-blind, so forcing
    aggressive compaction (COMPACT_MIN=8 on a 96-wide ensemble, many
    re-entries at shrinking widths) must reproduce the single-entry
    result byte for byte."""
    sim = _mk_sim(seed=6, region="europe-west1", gpu="k80", n_workers=4)
    draws = FleetDraws(sim, 96, 0.0)
    base = run_jit(sim, 150_000, 96, 48.0, draws=draws, raw=True)
    old = fleet_jit.COMPACT_MIN
    fleet_jit.COMPACT_MIN = 8
    fleet_jit._compiled.cache_clear()   # cond() captures it at trace time
    try:
        comp = run_jit(sim, 150_000, 96, 48.0, draws=draws, raw=True)
    finally:
        fleet_jit.COMPACT_MIN = old
        fleet_jit._compiled.cache_clear()
    _raw_bytes_equal(base, comp)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >1 device "
                           "(xla_force_host_platform_device_count)")
def test_sharded_pad_rows_match_batched_oracle():
    """Under trajectory sharding, n not divisible by the device count
    pads the state with inert rows; parity with the NumPy engine proves
    the pads never leak into real trajectories."""
    n = 13                       # 13 % 4 != 0 on the 4-device CI job
    def mk():
        return _mk_sim(seed=4, region="europe-west1", gpu="k80",
                       n_workers=4)
    _assert_parity(mk, (150_000, n, 48.0, 0.0), engines=("batched",))


def test_run_jit_rejects_empty_ensemble():
    with pytest.raises(ValueError, match="at least one trajectory"):
        run_jit(_mk_sim(), 1000, 0)


def test_unsupported_law_family_points_at_batched():
    """A roster whose lifetime law has no jittable port must fail with
    actionable advice, not compile garbage."""
    class _OddLaw:
        pass

    class _OddProvider:
        name = "odd"
        warning_seconds = 0.0
        graceful_checkpoint_on_warning = False

        def lifetime_model(self, region, gpu):
            return _OddLaw()

    sim = _mk_sim()
    sim.provider = _OddProvider()
    with pytest.raises(ValueError, match="no jittable port"):
        run_jit(sim, 1000, 4)
