import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
# NOTE: no XLA_FLAGS here — tests and benches must see the single real
# device; only launch/dryrun.py forces 512 placeholder host devices.
