import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
# NOTE: no XLA_FLAGS here — tests and benches must see the single real
# device; only launch/dryrun.py forces 512 placeholder host devices.

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

# Gate the optional `hypothesis` dependency: this container has no network,
# so when the real package is absent install a minimal deterministic stub
# (tests/_hypothesis_stub.py) before any test module imports it.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub
    _hypothesis_stub.install()

import pytest

# Tests that took >5 s on the reference box (pytest --durations), tagged
# `slow` at param granularity so the fast lane (`-m "not slow"`, the CI
# test job) keeps sub-5s params of the same functions. The `slow` CI job
# runs them on push; `python -m pytest` with no -m filter runs everything.
_SLOW_NODE_IDS = {
    "test_api_session.py::test_train_emits_bus_events",
    "test_calibration.py::test_live_straggler_drift_refit_restores_prediction",
    "test_chaos.py::test_live_ps_crash_walks_the_compression_ladder",
    "test_checkpoint.py::test_restore_resumes_training_state",
    "test_docs.py::test_readme_snippets_execute",
    "test_kernel_properties.py::test_flash_attention_matches_ref_random",
    "test_kernel_properties.py::test_ssd_chunk_size_invariance",
    "test_kernel_properties.py::test_ssd_state_continuity",
    "test_kernels.py::test_flash_attention_fwd"
    "[1-256-256-2-1-64-True-float32-2e-05]",
    "test_kernels.py::test_flash_attention_grads[1-128-4-2-32]",
    "test_kernels.py::test_flash_attention_grads[2-128-2-2-64]",
    "test_kernels.py::test_ssd_matches_decode_recurrence",
    "test_kernels.py::test_ssd_scan[1-128-2-32-1-16-32-float32-0.0005]",
    "test_kernels.py::test_ssd_scan[1-256-2-64-1-32-128-float32-0.0005]",
    "test_kernels.py::test_ssd_scan[2-128-4-32-2-16-64-float32-0.0005]",
    "test_kv_quant.py::test_int8_kv_decode_tracks_fp_forward"
    "[qwen3-1.7b]",
    "test_kv_quant.py::test_int8_kv_decode_tracks_fp_forward"
    "[stablelm-1.6b]",
    "test_kv_quant.py::test_quant_roundtrip_error_bounded",
    "test_mitigation.py::test_compressed_step_reports_payload_bytes",
    "test_mitigation.py::test_error_feedback_convergence_parity",
    "test_mitigation.py::test_legacy_checkpoint_restores_with_zero_residual",
    "test_mitigation.py::test_residual_survives_checkpoint_restore",
    "test_mitigation.py::test_restores_counter_reported",
    "test_mitigation.py::"
    "test_session_async_ps_mode_emits_staleness_histogram",
    "test_mitigation.py::test_trainer_applies_mitigation_mid_run",
    "test_models_smoke.py::test_decode_matches_forward[mamba2-1.3b]",
    "test_models_smoke.py::test_decode_matches_forward[qwen3-1.7b]",
    "test_models_smoke.py::test_decode_matches_forward[zamba2-1.2b]",
    "test_models_smoke.py::test_forward_shapes_no_nans"
    "[deepseek-v2-lite-16b]",
    "test_models_smoke.py::test_forward_shapes_no_nans[hubert-xlarge]",
    "test_models_smoke.py::test_forward_shapes_no_nans[starcoder2-15b]",
    "test_models_smoke.py::test_forward_shapes_no_nans[zamba2-1.2b]",
    "test_models_smoke.py::test_train_step_decreases_loss"
    "[deepseek-v2-lite-16b]",
    "test_models_smoke.py::test_train_step_decreases_loss[hubert-xlarge]",
    "test_models_smoke.py::test_train_step_decreases_loss[mamba2-1.3b]",
    "test_models_smoke.py::test_train_step_decreases_loss[qwen2-vl-2b]",
    "test_models_smoke.py::test_train_step_decreases_loss[zamba2-1.2b]",
    "test_optim_variants.py::test_master_weights_training_converges",
    "test_optim_variants.py::test_moe_forward_same_under_rules",
    "test_perf_models.py::test_table2_svr_rbf_wins_for_k80",
    "test_system.py::test_training_survives_revocation_and_join",
}


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.nodeid.rsplit("/", 1)[-1] in _SLOW_NODE_IDS:
            item.add_marker(pytest.mark.slow)
