import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
# NOTE: no XLA_FLAGS here — tests and benches must see the single real
# device; only launch/dryrun.py forces 512 placeholder host devices.

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

# Gate the optional `hypothesis` dependency: this container has no network,
# so when the real package is absent install a minimal deterministic stub
# (tests/_hypothesis_stub.py) before any test module imports it.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub
    _hypothesis_stub.install()
