"""Provider-layer tests: registry behavior, GCP adapter parity with the
pre-provider hard-wired constants (golden values), AWS/Azure market
semantics, and cross-provider Session smoke coverage."""
import math

import numpy as np
import pytest

from repro.api import Session
from repro.core.perf_model.features import GPU_SPECS
from repro.core.scheduler import plan_launch
from repro.core.transient.replacement import ReplacementModel
from repro.core.transient.revocation import (REGION_GPU_PARAMS, TABLE5_RATES,
                                             RevocationSampler)
from repro.core.transient.startup import StartupModel
from repro.providers import (FleetProvider, LifetimeLaw, Offering,
                             available_providers, get_provider)


# ---------------------------------------------------------------- registry
def test_registry_has_builtins_and_resolves():
    assert available_providers() == ["aws", "azure", "gcp"]
    gcp = get_provider("gcp")
    assert isinstance(gcp, FleetProvider)
    # instance passthrough: provider= params accept either form
    assert get_provider(gcp) is gcp


def test_registry_unknown_provider_names_alternatives():
    with pytest.raises(KeyError, match=r"aws.*azure.*gcp"):
        get_provider("digitalocean")


def test_unoffered_cell_error_names_alternatives():
    with pytest.raises(ValueError, match="does not offer"):
        get_provider("aws").lifetime_model("us-east-1", "p100")
    with pytest.raises(ValueError, match="regions with v100"):
        get_provider("gcp").lifetime_model("europe-west1", "v100")


# --------------------------------------------------------- GCP parity
def test_gcp_offerings_match_table5():
    gcp = get_provider("gcp")
    assert set(gcp.offerings()) == {
        Offering(r, g) for (r, g), rate in TABLE5_RATES.items()
        if rate is not None}
    assert gcp.max_lifetime_hours == 24.0
    assert not gcp.graceful_checkpoint_on_warning


def test_gcp_lifetime_model_is_the_calibrated_object():
    gcp = get_provider("gcp")
    m = gcp.lifetime_model("us-central1", "v100")
    assert m is REGION_GPU_PARAMS[("us-central1", "v100")]
    assert isinstance(m, LifetimeLaw)  # virtual subclass registration
    assert m.prob_revoked_within(24.0) == pytest.approx(
        TABLE5_RATES[("us-central1", "v100")])


def test_gcp_prices_match_gpu_specs():
    gcp = get_provider("gcp")
    for g in ("k80", "p100", "v100"):
        assert gcp.price(g) == GPU_SPECS[g].transient_price
        assert gcp.price(g, transient=False) == GPU_SPECS[g].hourly_price


def test_gcp_sampler_golden_values():
    """Bit-for-bit parity with the pre-provider hard-wired models: these
    goldens were captured before the FleetProvider refactor."""
    s = RevocationSampler(seed=0)  # default provider is gcp
    got = [s.lifetime("us-central1", "v100") for _ in range(5)]
    assert got[:2] == pytest.approx([1.8817134649, 11.281286695], abs=1e-9)
    assert all(math.isinf(v) for v in got[2:])
    assert s.prob_revoked_within("us-west1", "k80", 12.0) == pytest.approx(
        0.052576229970637635, abs=1e-12)

    m = StartupModel(3)
    out = m.sample("p100")
    assert out["total"] == pytest.approx(79.67202289257617, abs=1e-9)
    assert m.mean_total("v100") == pytest.approx(84.0)

    r = ReplacementModel(7)
    assert r.sample(1.54) == pytest.approx(76.71351817939342, abs=1e-9)
    assert r.cold_start_s(2.41) == pytest.approx(77.3352, abs=1e-9)


def test_gcp_explicit_provider_identical_to_default():
    a = RevocationSampler(seed=11)
    b = RevocationSampler(seed=11, provider="gcp")
    for _ in range(8):
        assert (a.lifetime("us-east1", "k80")
                == b.lifetime("us-east1", "k80"))


# ------------------------------------------------------ AWS semantics
def test_aws_uncapped_lifetimes_and_warning():
    aws = get_provider("aws")
    assert math.isinf(aws.max_lifetime_hours)
    assert aws.warning_seconds == 120.0
    assert aws.graceful_checkpoint_on_warning
    law = aws.lifetime_model("us-east-1", "v100")
    samples = law.sample(np.random.default_rng(0), 400)
    finite = samples[np.isfinite(samples)]
    assert finite.max() > 24.0  # no 24 h cap
    # uncapped: revocation probability keeps growing past 24h
    assert law.prob_revoked_within(72.0) > law.prob_revoked_within(24.0)
    # 24 h probability matches the advisor-bucket calibration target
    assert law.prob_revoked_within(24.0) == pytest.approx(0.45, abs=0.05)


def test_aws_price_signal_shapes_hazard():
    """More spot interruptions for servers launched into the demand peak
    than into the overnight trough (short horizon)."""
    law = get_provider("aws").lifetime_model("us-east-1", "v100")
    peak = law.cdf(np.array([3.0]), start_hour=11.5)[0]
    trough = law.cdf(np.array([3.0]), start_hour=23.0)[0]
    assert peak > trough


def test_aws_has_no_p100():
    assert "p100" not in get_provider("aws").gpus()


# ---------------------------------------------------- Azure semantics
def test_azure_tiers_order_hazards():
    az = get_provider("azure")
    assert math.isinf(az.max_lifetime_hours)
    assert az.warning_seconds == 30.0
    lo = az.lifetime_model("westeurope", "k80")     # 0-5% tier
    hi = az.lifetime_model("eastus", "v100")        # 20%+ tier
    assert lo.prob_revoked_within(24.0) == pytest.approx(0.05)
    assert hi.prob_revoked_within(24.0) == pytest.approx(0.30)
    assert az.eviction_tier("eastus", "v100") == "20%+"


def test_azure_exponential_is_memoryless():
    law = get_provider("azure").lifetime_model("eastus", "v100")
    rng = np.random.default_rng(1)
    a = law.sample(rng, 5, start_hour=0.0)
    rng = np.random.default_rng(1)
    b = law.sample(rng, 5, start_hour=13.0)
    np.testing.assert_allclose(a, b)


# --------------------------------------------- cross-provider Session
@pytest.fixture(scope="module")
def session():
    return Session.from_arch("qwen3-1.7b", total_steps=2000,
                             checkpoint_interval=200, zero1=False)


@pytest.mark.parametrize("provider", ["gcp", "aws", "azure"])
def test_session_plan_smoke_every_provider(session, provider):
    best, plans = session.plan(gpu="v100", n_workers=2, steps=500,
                               hours=[0], provider=provider)
    prov = get_provider(provider)
    assert {p.region for p in plans} == set(prov.regions_offering("v100"))
    assert all(p.provider == provider for p in plans)
    assert best.expected_cost == min(p.expected_cost for p in plans)


@pytest.mark.parametrize("provider", ["gcp", "aws", "azure"])
def test_session_simulate_and_predict_every_provider(session, provider):
    res = session.simulate(n_workers=2, gpu="v100", steps=300, seed=0,
                           provider=provider)
    assert res.steps_done == 300 and res.monetary_cost > 0
    assert res.provider == provider
    assert res.region == get_provider(provider).default_region
    rep = session.predict(n_workers=2, gpu="v100", steps=1000,
                          provider=provider)
    assert rep.provider == provider
    assert rep.region == get_provider(provider).default_region
    assert rep.total_time_seconds >= 1000 / rep.cluster_speed - 1e-6


def test_session_predict_gcp_provider_matches_default(session):
    base = session.predict(n_workers=2, gpu="v100", steps=1000, seed=0)
    via = session.predict(n_workers=2, gpu="v100", steps=1000, seed=0,
                          provider="gcp")
    assert base == via


def test_session_default_provider_threading():
    s = Session.from_arch("qwen3-1.7b", total_steps=500,
                          checkpoint_interval=100, provider="azure")
    assert s.provider.name == "azure"
    rep = s.predict(n_workers=1, gpu="v100", steps=200)
    assert rep.provider == "azure"
    with pytest.raises(ValueError, match="does not offer"):
        Session.from_arch("qwen3-1.7b", provider="aws").predict(gpu="p100")


def test_per_call_provider_override_beats_session_default():
    """A per-call provider must fully replace the session default — even
    for GPUs the default market does not sell (aws has no p100)."""
    s = Session.from_arch("qwen3-1.7b", total_steps=500,
                          checkpoint_interval=100, provider="aws")
    rep = s.predict(n_workers=1, gpu="p100", steps=200, provider="gcp")
    assert rep.provider == "gcp"
    best, _ = s.plan(gpu="p100", n_workers=1, steps=200, hours=[0],
                     provider="azure")
    assert best.provider == "azure"


def test_fleet_sim_start_hour_reaches_lifetime_law():
    """Fig 9 diurnal laws must see the planned launch hour: a V100 run
    started inside the 4-8PM quiet window sees no revocation before the
    window ends."""
    from repro.core.transient.fleet import FleetSim, SimWorker

    def mk(start_hour, seed):
        workers = [SimWorker(i, "v100", "us-central1", 15.61)
                   for i in range(4)]
        sim = FleetSim(workers, model_gflops=1.54, model_bytes=1.87e6,
                       step_speed_of=lambda g: 15.61,
                       checkpoint_interval_steps=4000, checkpoint_time_s=2.0,
                       seed=seed)
        return sim.run(400_000, start_hour=start_hour)

    for seed in range(3):
        res = mk(16.0, seed)  # launch at 4PM: quiet until 8PM
        early = [t for t, e in res.events
                 if e.startswith("revoke") and t < 4 * 3600.0]
        assert early == []


def test_plan_launch_provider_prices_diverge():
    """Same workload, same GPU: the three markets price it differently."""
    costs = {}
    for name in available_providers():
        best, _ = plan_launch("v100", 2, 10.0, n_w=100_000, i_c=4000,
                              t_c=2.0, hours=[0], provider=name)
        costs[name] = best.expected_cost
    assert len({round(c, 6) for c in costs.values()}) == 3


# ------------------------------------------------------------------ CLI
def test_cli_provider_flag():
    from repro.__main__ import build_parser
    p = build_parser()
    args = p.parse_args(["plan", "--gpu", "v100", "--provider", "aws"])
    assert args.provider == "aws" and args.region is None
    args = p.parse_args(["simulate", "--provider", "azure",
                         "--region", "eastus"])
    assert (args.provider, args.region) == ("azure", "eastus")
    # default market is the paper's
    assert p.parse_args(["predict"]).provider == "gcp"
