"""Vectorized Monte-Carlo engine tests: batched-vs-scalar sampler parity
(fixed-seed distributional bounds, including the V100 hard-zero diurnal
window), planner best-cell goldens and standard errors, the simulation
ensemble (`FleetSim.run_many` / `SimStats`), and the session-level caches
(calibrated generators, jit artifacts)."""
import math

import numpy as np
import pytest

from benchmarks.mc_speed import reference_scalar_lifetime
from repro.core.scheduler import (expected_revocations_mc,
                                  expected_revocations_mc_stats, plan_launch)
from repro.core.transient.fleet import (FleetEnsemble, FleetSim, SimResult,
                                        SimStats, SimWorker)
from repro.core.transient.revocation import (REGION_GPU_PARAMS,
                                             RevocationSampler)
from repro.providers import get_provider


# ----------------------------------------------------- sampler parity
def _reference_draws(model, n: int, start_hour: float, seed: int = 0):
    """n lifetimes through the pinned pre-vectorization scalar loop."""
    rng = np.random.default_rng(seed)
    return np.array([reference_scalar_lifetime(model, rng, start_hour)
                     for _ in range(n)])


def _ks_distance(a: np.ndarray, b: np.ndarray) -> float:
    grid = np.sort(np.concatenate([a, b]))
    fa = np.searchsorted(np.sort(a), grid, side="right") / len(a)
    fb = np.searchsorted(np.sort(b), grid, side="right") / len(b)
    return float(np.max(np.abs(fa - fb)))


@pytest.mark.parametrize("key,start_hour", [
    (("us-west1", "k80"), 0.0),
    (("europe-west1", "k80"), 10.0),      # front-loaded + K80 morning peak
    (("us-central1", "p100"), 6.0),
    (("us-central1", "v100"), 0.0),
    (("us-central1", "v100"), 16.0),      # launch inside the hard-zero window
    (("asia-east1", "v100"), 12.0),
])
def test_batch_matches_scalar_distribution(key, start_hour):
    """sample_batch (pooled rejection) must match the pre-vectorization
    per-slot loop: same survival mass, same finite-lifetime distribution
    (KS + moment bounds) for every (region, gpu, start_hour)."""
    m = REGION_GPU_PARAMS[key]
    n = 4000
    ref = _reference_draws(m, n, start_hour, seed=0)
    got = m.sample_batch(np.random.default_rng(1), n, start_hour)
    # survival point-mass parity (binomial stderr ~0.008)
    assert abs(np.isinf(ref).mean() - np.isinf(got).mean()) < 0.03
    ref_f, got_f = ref[np.isfinite(ref)], got[np.isfinite(got)]
    # two-sample KS at the ~99.9% level, scaled to the finite-sample
    # count (low-revocation cells keep only p24*n finite draws)
    n_eff = len(ref_f) * len(got_f) / (len(ref_f) + len(got_f))
    assert _ks_distance(ref_f, got_f) < 1.95 / math.sqrt(n_eff)
    assert abs(ref_f.mean() - got_f.mean()) < 0.45
    assert abs(ref_f.std() - got_f.std()) < 0.5


def test_v100_hard_zero_window_respected_in_batch():
    """Thinning must keep the 4-8PM quiet window (Fig 9) essentially
    empty of revocations in the batched path too."""
    m = REGION_GPU_PARAMS[("us-central1", "v100")]
    got = m.sample_batch(np.random.default_rng(2), 4000, 0.0)
    finite = got[np.isfinite(got)]
    local = finite % 24.0
    in_window = ((local >= 16.0) & (local < 20.0)).mean()
    assert in_window < 0.005  # only the ~(1-p)^64 pushed-tail fallback


def test_batch_n1_bitwise_matches_sequential_stream():
    """n=1 keeps the exact pre-vectorization draw order, so interleaved
    scalar calls reproduce the provider-parity goldens."""
    m = REGION_GPU_PARAMS[("us-central1", "v100")]
    a = [float(m.sample_batch(np.random.default_rng(0), 1, 0.0)[0])
         for _ in range(1)]
    b = [reference_scalar_lifetime(m, np.random.default_rng(0), 0.0)]
    assert a == b
    # and across a shared stream
    ra, rb = np.random.default_rng(3), np.random.default_rng(3)
    for _ in range(6):
        assert float(m.sample_batch(ra, 1, 5.0)[0]) == \
            reference_scalar_lifetime(m, rb, 5.0)


def test_sampler_lifetimes_batch_api():
    s = RevocationSampler(seed=0)
    lts = s.lifetimes("us-central1", "v100", 256, start_hour=3.0)
    assert lts.shape == (256,)
    assert np.all((lts > 0) | np.isinf(lts))
    # resolves through the provider layer for non-GCP markets too
    aws = RevocationSampler(seed=0, provider="aws")
    lts = aws.lifetimes("us-east-1", "v100", 128)
    assert lts.shape == (128,) and np.isfinite(lts).any()


# ----------------------------------------------------------- planner
def test_expected_revocations_mc_stats_bounds():
    n_r, se = expected_revocations_mc_stats("us-central1", "v100", 7.0,
                                            20.0, 8, samples=400, seed=1)
    assert 0.0 <= n_r <= 8.0
    assert 0.0 <= se <= 8.0 * 0.5 / math.sqrt(400) + 1e-9
    # scalar wrapper agrees with the stats variant
    assert expected_revocations_mc("us-central1", "v100", 7.0, 20.0, 8,
                                   samples=400, seed=1) == pytest.approx(n_r)


def test_plan_launch_best_cell_goldens():
    """Fixed-seed best cells of the default grid. us-west1 is by far the
    most stable K80 region (Table V), so the best K80 cell must stay
    there regardless of MC noise; the V100 golden pins (region, hour)."""
    best_k80, _ = plan_launch("k80", 4, 4.56, n_w=256_000, i_c=4000,
                              t_c=3.84, seed=0)
    assert best_k80.region == "us-west1"
    best_v100, _ = plan_launch("v100", 4, 15.61, n_w=256_000, i_c=4000,
                               t_c=3.84, seed=0)
    assert (best_v100.region, best_v100.launch_hour) == ("asia-east1", 18)


def test_plan_launch_matches_scalar_reference_best_region():
    """Before/after vectorization: a full scalar-reference planner sweep
    ranks the same best region as the batched grid (common workload)."""
    from benchmarks.mc_speed import scalar_plan_grid
    prov = get_provider("gcp")
    hours = [0, 6, 12, 18]
    ref = scalar_plan_grid("k80", 4, 4.56, 400_000, 4000, 3.84, hours, 0,
                           prov)
    ref_best = min(ref, key=lambda p: p["cost"])
    best, _ = plan_launch("k80", 4, 4.56, n_w=400_000, i_c=4000, t_c=3.84,
                          hours=hours, seed=0)
    assert best.region == ref_best["region"]


def test_plan_launch_stderr_and_samples_knob():
    best, plans = plan_launch("v100", 4, 15.61, n_w=400_000, i_c=4000,
                              t_c=3.84, hours=[0, 12], seed=0, samples=64)
    assert all(p.samples == 64 for p in plans)
    assert all(0.0 <= p.revocation_stderr <= 4.0 * 0.5 / 8.0 for p in plans)
    # stderr shrinks ~1/sqrt(samples)
    _, plans_big = plan_launch("v100", 4, 15.61, n_w=400_000, i_c=4000,
                               t_c=3.84, hours=[0, 12], seed=0,
                               samples=1600)
    assert (np.mean([p.revocation_stderr for p in plans_big])
            <= np.mean([p.revocation_stderr for p in plans]) + 1e-9)


def test_plan_launch_horizon_includes_checkpoint_pauses():
    """Eq (4) wall-clock horizon: a checkpoint-heavy run is exposed to
    the market for longer, so E[revocations] must not drop when t_c
    grows (same seed => same lifetime draws, larger horizon)."""
    kw = dict(n_w=200_000, i_c=1000, hours=[7], seed=3)
    light, _ = plan_launch("v100", 4, 15.61, t_c=0.0, **kw)
    heavy, _ = plan_launch("v100", 4, 15.61, t_c=60.0, **kw)
    assert heavy.expected_revocations >= light.expected_revocations
    assert heavy.expected_time_s > light.expected_time_s


# ---------------------------------------------------------- ensemble
def _mk_sim(seed=0, region="us-central1", n_workers=4):
    sp = 15.61
    workers = [SimWorker(i, "v100", region, sp) for i in range(n_workers)]
    return FleetSim(workers, model_gflops=1.54, model_bytes=1.87e6,
                    step_speed_of=lambda g: sp,
                    checkpoint_interval_steps=4000, checkpoint_time_s=3.84,
                    seed=seed, price_of={"v100": 0.74})


def test_run_many_returns_ensemble_with_stats():
    ens = _mk_sim().run_many(100_000, 12, max_hours=100.0)
    assert isinstance(ens, FleetEnsemble) and len(ens) == 12
    st = ens.stats
    assert isinstance(st, SimStats) and st.n == 12
    assert st.time_p50_s <= st.time_p90_s
    assert st.cost_p50 <= st.cost_p90
    assert min(r.total_time_s for r in ens.results) <= st.time_mean_s \
        <= max(r.total_time_s for r in ens.results)
    assert all(r.steps_done >= 100_000 for r in ens.results)
    assert st.finished == 12


def test_run_many_reports_censored_trajectories():
    """Trajectories cut off by max_hours must show up in `finished`."""
    ens = _mk_sim().run_many(10_000_000, 6, max_hours=0.5)
    assert ens.stats.finished == 0
    assert all(r.steps_done < 10_000_000 for r in ens.results)


def test_plan_launch_rejects_bad_sample_counts():
    with pytest.raises(ValueError, match="at least one MC sample"):
        plan_launch("v100", 2, 10.0, n_w=1000, i_c=100, t_c=1.0,
                    hours=[0], samples=0)
    with pytest.raises(ValueError, match="at least one MC sample"):
        expected_revocations_mc_stats("us-central1", "v100", 0.0, 5.0, 2,
                                      samples=-5)


def test_run_many_trajectories_differ_and_seed_deterministic():
    ens_a = _mk_sim(seed=5).run_many(200_000, 8, max_hours=100.0)
    ens_b = _mk_sim(seed=5).run_many(200_000, 8, max_hours=100.0)
    times_a = [r.total_time_s for r in ens_a.results]
    assert times_a == [r.total_time_s for r in ens_b.results]
    assert len(set(times_a)) > 1  # independent trajectories


def test_run_many_leaves_single_run_untouched():
    """run() with the same seed is bit-identical whether or not an
    ensemble was drawn from the same simulator config first."""
    a = _mk_sim(seed=2).run(200_000, max_hours=100.0)
    sim = _mk_sim(seed=2)
    sim.run_many(200_000, 4, max_hours=100.0)
    b = _mk_sim(seed=2).run(200_000, max_hours=100.0)
    assert a.total_time_s == b.total_time_s
    assert a.revocations == b.revocations


def test_session_simulate_samples(tmp_path):
    from repro.api import Session
    s = Session.from_arch("qwen3-1.7b", total_steps=300,
                          checkpoint_interval=100, zero1=False)
    one = s.simulate(n_workers=2, gpu="v100", steps=300, seed=0)
    assert isinstance(one, SimResult)
    # samples=1 default result unchanged by the ensemble machinery
    again = s.simulate(n_workers=2, gpu="v100", steps=300, seed=0,
                       samples=1)
    assert again.total_time_s == one.total_time_s
    ens = s.simulate(n_workers=2, gpu="v100", steps=300, seed=0,
                     samples=8)
    assert isinstance(ens, FleetEnsemble) and ens.stats.n == 8
    assert ens.stats.time_p50_s <= ens.stats.time_p90_s
    assert ens.stats.cost_mean > 0


# ------------------------------------------------------------ caches
def test_calibrate_generators_memoized():
    from repro.core.perf_model.speed_model import calibrate_generators
    a = calibrate_generators()
    b = calibrate_generators()
    assert a is not b                      # callers get their own dict
    assert all(a[g] is b[g] for g in a)    # ...of shared calibrated models


def test_jit_cache_roundtrip_and_stats():
    from repro.core import jit_cache
    built = []
    key = ("unit-test-key", 1)
    a = jit_cache.cached("unit", key, lambda: built.append(1) or "art")
    b = jit_cache.cached("unit", key, lambda: built.append(1) or "art2")
    assert a == b == "art" and built == [1]
    st = jit_cache.stats()
    assert st["hits"] >= 1 and st["entries"] >= 1


def test_trainer_jit_step_shared_across_instances():
    """Two trainers over the same (cfg, run) reuse one jitted step — the
    ROADMAP Session-level caching item."""
    import dataclasses

    from repro.configs import RunConfig, get_config
    from repro.core.trainer import TransientTrainer
    from repro.data.pipeline import ShardedLoader, source_for_config

    cfg = get_config("qwen3-1.7b", smoke=True)
    run = RunConfig(total_steps=10, warmup_steps=1, zero1=False)

    def mk(ckpt_dir):
        src = source_for_config(cfg, 32, seed=0)
        return TransientTrainer(
            cfg, dataclasses.replace(run, checkpoint_dir=ckpt_dir),
            ShardedLoader(src, 4))

    t1 = mk("/tmp/mc_a")
    t2 = mk("/tmp/mc_b")  # checkpoint path differs: still one jitted step
    assert t1._jit_step is t2._jit_step
    assert t1.opt is t2.opt
