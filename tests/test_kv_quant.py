"""int8 KV cache (beyond-paper decode optimization): accuracy + size."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "stablelm-1.6b"])
def test_int8_kv_decode_tracks_fp_forward(arch):
    cfg = get_config(arch, smoke=True).with_(dtype="float32")
    cfgq = cfg.with_(kv_quant=True)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full = api.prefill(params, cfg, {"tokens": toks})
    state, _ = api.init_decode_state(cfgq, batch=B, max_len=S,
                                     dtype=jnp.float32)
    for i in range(S):
        lg, state = api.decode_step(params, cfgq, state, toks[:, i],
                                    jnp.int32(i))
    scale = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-9
    rel = float(jnp.max(jnp.abs(lg - full[:, -1]))) / scale
    corr = float(jnp.corrcoef(np.asarray(lg).ravel(),
                              np.asarray(full[:, -1]).ravel())[0, 1])
    assert rel < 0.05, rel
    assert corr > 0.999, corr


def test_int8_cache_half_the_bytes():
    cfg = get_config("qwen3-1.7b")
    def total(c):
        vals, _ = api.decode_state_specs(c, batch=1, max_len=32768)
        return sum(int(jnp.dtype(v.dtype).itemsize) *
                   int(np.prod(v.shape)) for v in jax.tree.leaves(vals))
    bf16 = total(cfg)
    q = total(cfg.with_(kv_quant=True))
    # int8 payload = half of bf16; scales add hd-th overhead
    assert q < 0.52 * bf16


def test_quant_roundtrip_error_bounded():
    from repro.models.layers import _quant_int8
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 2, 64))
    q, s = _quant_int8(x)
    back = q.astype(jnp.float32) * s[..., None]
    err = jnp.max(jnp.abs(back - x) / (jnp.max(jnp.abs(x)) + 1e-9))
    assert float(err) < 1.0 / 127.0 + 1e-3
