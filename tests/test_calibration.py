"""Calibration layer tests (docs/calibration.md): the Estimator protocol
across the prediction stack, the versioned ModelStore, CUSUM drift
detection -> refit end-to-end (unit level and through the live chaos
trainer), the PROFET/Habitat-style transfer path against held-out
calibrated cells, recorded-trace ingestion/replay, and the unarmed-mode
golden-parity contract (static calibrations stay bit-identical)."""
import dataclasses
import math

import numpy as np
import pytest

from repro.calibration import (ClusterSpeedEstimator, CusumDetector,
                               Estimator, ModelStore, RecalibrationConfig,
                               Recalibrator, TraceEvent, fit_p24_effects,
                               holdout_p24_report, lifetimes_from_trace,
                               parse_trace, score_predictions,
                               transfer_lifetime_model, transfer_p24,
                               transfer_step_time_model)
from repro.calibration.traces import (eviction_hazard_windows,
                                      price_hazard_windows)
from repro.core.perf_model.regression import kfold_mae, mae, mape, ols_fit
from repro.core.perf_model.speed_model import (GPUStepTimeModel,
                                               calibrate_generators)


# ------------------------------------------------------------ protocol
def test_estimator_protocol_adopted_across_the_stack():
    from repro.core.perf_model.checkpoint_model import (
        CheckpointTimePredictor, CkptRow)
    from repro.core.perf_model.cluster_model import PSBottleneckModel
    from repro.core.transient.revocation import LifetimeModel

    ckpt_rows = [CkptRow(f"m{i}", s, s / 10, s / 100, 1.0 + s / 1e9)
                 for i, s in enumerate(np.linspace(1e8, 4e9, 8))]
    adopters = [
        calibrate_generators()["v100"],
        CheckpointTimePredictor.fit(ckpt_rows),
        PSBottleneckModel(1.87e6, 1, ps_bw=1e9),
        LifetimeModel.fit("us-central1", "v100",
                          np.array([1.0, 2.0, 5.0, np.inf])),
        ClusterSpeedEstimator(speed=27.4),
    ]
    for est in adopters:
        assert isinstance(est, Estimator), type(est)
        assert isinstance(est.params_hash(), str)
    # provider laws expose residuals + score on top of the protocol:
    # n = finite (uncensored) observations on the base LifetimeLaw path
    from repro.providers import get_provider
    law = get_provider("aws").lifetime_model("us-east-1", "v100")
    assert law.residuals(np.array([1.0, 3.0, 10.0, np.inf])).shape == (3,)
    sc = law.score(np.array([1.0, 3.0, 10.0, np.inf]))
    assert set(sc) >= {"n", "mae", "max_abs"} and sc["n"] == 3
    assert isinstance(law.params_hash(), str)
    with pytest.raises(ValueError, match="no finite"):
        law.score(np.array([np.inf]))


def test_params_hash_is_stable_and_parameter_sensitive():
    m = calibrate_generators()["v100"]
    assert m.params_hash() == m.params_hash()
    bumped = GPUStepTimeModel(m.gpu, np.asarray(m.c_anchors, float).copy(),
                              np.asarray(m.t_anchors, float) * 1.01)
    assert bumped.params_hash() != m.params_hash()


def test_cluster_speed_estimator_fit_and_guards():
    recs = [{"t": float(i) * 0.05, "step": i, "loss": 1.0}
            for i in range(5)]
    est = ClusterSpeedEstimator.fit(recs)
    assert est.predict() == pytest.approx(20.0)
    assert est.n_obs == 5 and est.source == "refit"
    with pytest.raises(ValueError, match="2 records"):
        ClusterSpeedEstimator.fit(recs[:1])
    with pytest.raises(ValueError, match="zero time span"):
        ClusterSpeedEstimator.fit([{"t": 1.0, "step": 1},
                                   {"t": 1.0, "step": 2}])
    with pytest.raises(ValueError, match="no observations"):
        score_predictions([], [])


# ----------------------------------------------------------- ModelStore
def test_model_store_versioning_snapshot_and_rollback():
    store = ModelStore()
    a = ClusterSpeedEstimator(speed=100.0)
    b = ClusterSpeedEstimator(speed=82.5, n_obs=6, source="refit")
    assert store.register("cluster_speed", a) == 1
    with pytest.raises(ValueError, match="already registered"):
        store.register("cluster_speed", a)
    assert store.update("cluster_speed", b) == 2
    assert store.current("cluster_speed") is b
    assert store.at_version("cluster_speed", 1) is a
    # rollback reinstates the old estimator as a NEW version (append-only)
    assert store.rollback("cluster_speed") == 3
    assert store.current("cluster_speed") is a
    assert store.version("cluster_speed") == 3
    trail = store.snapshots("cluster_speed")
    assert [v for v, _ in trail] == [1, 2, 3]
    assert trail[0][1] == trail[2][1] != trail[1][1]   # hash = calibration
    with pytest.raises(KeyError, match="unknown model"):
        store.current("nope")
    with pytest.raises(ValueError, match="no version 9"):
        store.rollback("cluster_speed", 9)


def test_store_seeds_from_the_exact_memoized_calibrations():
    """Golden parity: resolving step-time models through the store hands
    back the *same objects* as the module-global path, so the unarmed
    prediction stack is bit-identical by construction."""
    store = ModelStore.with_static_calibrations()
    gens = calibrate_generators()
    assert {n for n in store.names() if n.startswith("step_time/")} \
        == {f"step_time/{g}" for g in gens}
    for gpu, gen in gens.items():
        assert store.current(f"step_time/{gpu}") is gen
        assert store.version(f"step_time/{gpu}") == 1


def test_session_resolves_generators_through_its_store():
    from repro.api import Session
    s = Session.from_arch("qwen3-1.7b", smoke=True)
    gens = calibrate_generators()
    assert s.models.current("step_time/v100") is gens["v100"]
    # Table I anchor via the store-resolved handle: bit-identical
    assert s.models.current("step_time/v100").step_time(1.54) \
        == gens["v100"].step_time(1.54)


def test_unarmed_run_config_is_the_jit_cache_identity():
    from repro.configs import RunConfig
    from repro.core.jit_cache import normalized_run
    armed = RunConfig(recalibration=RecalibrationConfig())
    assert normalized_run(armed) == normalized_run(RunConfig())


# ---------------------------------------------------------------- drift
def test_cusum_accumulates_allowance_excess_and_resets_on_alarm():
    det = CusumDetector(allowance=0.05, threshold=0.15)
    assert not det.observe(0.04) and det.statistic == 0.0   # inside slack
    assert not det.observe(None)                            # no measurement
    assert not det.observe(0.12)                            # s = 0.07
    assert not det.observe(0.12)                            # s = 0.14
    assert det.observe(0.12)                                # s = 0.21 >= thr
    assert det.statistic == 0.0                             # reset on alarm
    assert len(det.alarms) == 1
    # a one-off spike below threshold-in-one-step never fires
    det2 = CusumDetector(allowance=0.05, threshold=0.15)
    assert not det2.observe(0.12) and not det2.observe(0.0)


def test_recalibrator_drift_refit_and_mitigation_reset():
    class FakeProfiler:
        def __init__(self, speed):
            self.speed = speed

        def history(self):
            return [{"t": i / self.speed, "step": i, "loss": 1.0}
                    for i in range(8)]

    events = []
    rec = Recalibrator(RecalibrationConfig(), store=ModelStore(),
                       emit=lambda k, p: events.append((k, p)))
    rec.seed(100.0)
    assert rec.version == 1
    prof = FakeProfiler(speed=80.0)   # true speed shifted 20 % down
    assert rec.observe(5, 0.12, prof) is None     # s = 0.07
    assert rec.observe(10, 0.12, prof) is None    # s = 0.14
    new = rec.observe(15, 0.12, prof)             # s = 0.21 >= 0.15: alarm
    assert new == pytest.approx(80.0)
    assert [k for k, _ in events] == ["model_drift", "model_refit"]
    assert rec.version == 2
    assert rec.store.current("cluster_speed").source == "refit"
    assert rec.refits[0]["old_speed"] == 100.0
    assert rec.refits[0]["new_speed"] == pytest.approx(80.0)
    # cooldown: the check right after a refit is skipped
    assert rec.observe(20, 0.5, prof) is None
    # a mitigation voids accumulated deviation instead of feeding it
    rec.detector.s_pos = 0.14
    rec.notify_mitigation(20)
    assert rec.detector.statistic == 0.0


def test_live_straggler_drift_refit_restores_prediction(tmp_path):
    """End-to-end through the real trainer: an injected mid-run speed
    shift must raise model_drift, refit from profiler history, and bring
    the controller deviation back inside the paper's 6.7 % threshold —
    with no false mitigation (the straggler gets no PS lever)."""
    from repro.api import Session
    from repro.chaos import get_scenario
    from repro.chaos.runner import _run_live

    session = Session.from_arch("qwen3-1.7b", smoke=True)
    session.run = dataclasses.replace(
        session.run, recalibration=RecalibrationConfig())
    live = _run_live(session, get_scenario("straggler"), seed=0)
    recal = live["recalibration"]
    assert len(recal["drift_events"]) >= 1
    assert len(recal["refits"]) >= 1
    refit = recal["refits"][-1]
    assert refit["new_speed"] < refit["old_speed"]       # learned the slowdown
    assert recal["model_version"] >= 2
    assert recal["post_refit_deviation"] is not None
    assert abs(recal["post_refit_deviation"]) < 0.067
    # drift must not corrupt detection/mitigation scoring
    assert live["actions_applied"] == []
    assert live["false_alarms"] == 0 and live["missed_detections"] == 0


# ------------------------------------------------------------- transfer
def test_step_time_transfer_predicts_held_out_gpu():
    gens = calibrate_generators()
    for target in ("p100", "v100", "k80"):
        pred = transfer_step_time_model(target)
        actual = gens[target]
        errs = [abs(pred.step_time(float(c)) - actual.step_time(float(c)))
                / actual.step_time(float(c))
                for c in np.asarray(actual.c_anchors, float)]
        assert float(np.mean(errs)) < 0.30, (target, errs)
    with pytest.raises(KeyError, match="unknown gpu"):
        transfer_step_time_model("h100")


def test_lifetime_transfer_in_sample_signal_and_holdout_bound():
    """Table V is interaction-dominated (us-west1 holds both the calmest
    and the most brutal cell), so an additive region+gpu decomposition
    cannot beat the grand mean *held out* on 12 cells — docs/calibration.md
    says so explicitly. What the tests pin instead: in-sample the effects
    must explain real variance (beat the grand-mean baseline), and the
    leave-one-out error must stay inside the documented 0.3 bound so a
    regression in the fit shows up."""
    from repro.core.transient.revocation import TABLE5_RATES
    observed = {k: v for k, v in TABLE5_RATES.items() if v is not None}
    grand = float(np.mean(list(observed.values())))
    naive_mae = float(np.mean([abs(grand - p)
                               for p in observed.values()]))
    eff = fit_p24_effects()
    in_sample = float(np.mean([abs(transfer_p24(r, g, eff) - p)
                               for (r, g), p in observed.items()]))
    assert in_sample < naive_mae
    rows = list(holdout_p24_report())
    assert len(rows) >= 5
    model_mae = float(np.mean([r["abs_err"] for r in rows]))
    assert model_mae < 0.30
    # filling a never-offered cell yields a usable LifetimeModel
    p = transfer_p24("us-west1", "v100", eff)
    assert 0.0 < p < 1.0
    lm = transfer_lifetime_model("us-west1", "v100", eff)
    assert lm.prob_revoked_within(24.0) == pytest.approx(p)
    with pytest.raises(KeyError, match="never observed"):
        transfer_p24("mars-east1", "v100", eff)


# --------------------------------------------------------------- traces
TRACE = """
# comment line
{"kind": "eviction", "t_h": 0.2, "lifetime_h": 0.2, "region": "r", "gpu": "v100"}
{"kind": "eviction", "t_h": 0.8, "lifetime_h": 0.8, "region": "r", "gpu": "v100"}
{"kind": "eviction", "t_h": 0.9, "lifetime_h": 0.9, "region": "r", "gpu": "v100"}
{"kind": "eviction", "t_h": 9.0, "lifetime_h": 9.0, "region": "r", "gpu": "v100", "censored": true}
{"kind": "price", "t_h": 0.0, "price": 0.08}
{"kind": "price", "t_h": 1.0, "price": 0.15}
{"kind": "price", "t_h": 2.0, "price": 0.12}
{"kind": "price", "t_h": 3.0, "price": 0.09}
"""


def test_trace_parser_hazard_windows_and_lifetimes():
    events = parse_trace(TRACE)
    assert [e.t_h for e in events] == sorted(e.t_h for e in events)
    lt = lifetimes_from_trace(events, region="r", gpu="v100")
    assert lt.tolist()[:3] == [0.2, 0.8, 0.9] and np.isinf(lt[3])
    ev = eviction_hazard_windows(events, n_workers=2, bucket_h=1.0)
    # 3 evictions in [0,1) over 2 fleet-hours; the censored record is
    # exposure, not an event
    assert ev == [(0.0, 1.0, 1.5, "r")]
    pw = price_hazard_windows(events, bid=0.10, hazard_per_excess=2.0)
    assert len(pw) == 1
    start, end, hz = pw[0]
    assert (start, end) == (1.0, 3.0)
    assert hz == pytest.approx(2.0 * np.mean([0.5, 0.2]))
    with pytest.raises(ValueError, match="kind"):
        TraceEvent.from_record({"kind": "meteor", "t_h": 1.0})
    with pytest.raises(ValueError, match="not JSON"):
        parse_trace("{nope}")


def test_trace_injector_replays_the_bundled_scenario():
    from repro.chaos import get_scenario
    from repro.chaos.injectors import PreemptionWave, PriceSpike

    sc = get_scenario("recorded_trace")
    waves = [f for f in sc.faults if isinstance(f, PreemptionWave)]
    spikes = [f for f in sc.faults if isinstance(f, PriceSpike)]
    assert len(waves) == 2 and len(spikes) == 1
    # 6 evictions per half-hour bucket / (4 workers * 0.5 h) = 3/h
    assert all(w.hazard_per_h == pytest.approx(3.0) for w in waves)
    assert all(w.region == "us-central1" for w in waves)
    assert spikes[0].hazard_per_h > 0


def test_recalibrator_ingests_trace_into_lifetime_models(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text(TRACE)
    rec = Recalibrator(RecalibrationConfig(trace_path=str(p)),
                       store=ModelStore())
    written = rec.ingest_trace()
    assert written == ["lifetime/trace/r/v100"]
    lm = rec.store.current("lifetime/trace/r/v100")
    # 3 of 4 recorded servers died inside the horizon
    assert lm.p24 == pytest.approx(0.75)
    # ingesting again refits as a new version, not a duplicate name
    rec.ingest_trace()
    assert rec.store.version("lifetime/trace/r/v100") == 2


# ----------------------------------------------------- regression guards
def test_regression_metrics_reject_degenerate_inputs():
    with pytest.raises(ValueError, match="empty"):
        mae([], [])
    with pytest.raises(ValueError, match="empty"):
        mape([], [])
    with pytest.raises(ValueError, match="all targets are zero"):
        mape([0.0, 0.0], [1.0, 2.0])
    assert mape([2.0, 0.0], [2.0, 1.0]) >= 0.0   # partial zeros still fine
    X = np.arange(10, dtype=float).reshape(-1, 1)
    y = 2.0 * X[:, 0] + 1.0
    with pytest.raises(ValueError, match="empty"):
        kfold_mae(ols_fit, X[:0], y[:0])
    with pytest.raises(ValueError, match="k=12 invalid"):
        kfold_mae(ols_fit, X, y, k=12)
    assert kfold_mae(ols_fit, X, y, k=5)[0] == pytest.approx(0.0, abs=1e-8)
