"""Property + regression tests for the serving gateway and fleet sim.

Pins the ISSUE-10 invariants (docs/serving.md):

* every request reaches EXACTLY one terminal outcome — never both
  completed and shed, never resolved twice;
* the admission queue is FIFO within a priority class and strict across
  classes;
* a draining or down replica never admits, however briefly;
* the batched and event simulator engines agree to 1e-6 with chaos on;
* `plan_serving` produces a deterministic, pinned ranking;
* the first generated token respects `temperature` (two seeds diverge at
  token 0 — the regression the gateway refactor retired);
* per-token decode percentiles thread through `Session.serve`.
"""
from __future__ import annotations

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.chaos import FaultTimeline, PreemptionWave
from repro.resilience import ResilienceConfig
from repro.serving import (ACTIVE, COMPLETED, DROPPED, SHED, AdmissionQueue,
                           Replica, ReplicaSet, ServingDegradationPolicy,
                           ServingFleetSim, ServingSLO, ServingWorkload,
                           plan_serving)
from repro.serving import simulator as sim_mod

WAVE_POLICY = ServingDegradationPolicy(reduce_tokens_below=1.0,
                                       shrink_batch_below=0.75,
                                       shed_below=0.5)


def _wave_sim(seed: int, *, armed: bool = True,
              provider: str = "aws") -> ServingFleetSim:
    """Small serve_wave-shaped sim: a preemption wave dense enough that
    revocations land inside the ~minute-long workload."""
    rset = ReplicaSet(4, provider, gpu="v100", seed=seed)
    rset.chaos = FaultTimeline([PreemptionWave(0.01, 0.05, 60.0)],
                               rset.roster(), seed=seed)
    wl = ServingWorkload(n_requests=120, arrival_rate_per_s=2.0,
                         max_tokens=16, queue_budget_s=15.0,
                         hedge_timeout_s=20.0)
    return ServingFleetSim(rset, wl, policy=WAVE_POLICY,
                           resilience=ResilienceConfig() if armed else None,
                           token_time_s=0.05, batch_ceiling=8,
                           horizon_s=1800.0, seed=seed)


# --------------------------------------------------------------- outcomes
@given(seed=st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_each_request_exactly_one_terminal_outcome(seed):
    """No request is both completed and shed (or resolved twice): spy on
    `_Trajectory._finish` and require one call per rid."""
    calls = []
    orig = sim_mod._Trajectory._finish

    def spy(self, rid, status, t, reason="", tokens=0):
        calls.append((self.traj, rid, status))
        return orig(self, rid, status, t, reason, tokens)

    sim_mod._Trajectory._finish = spy
    try:
        sim = _wave_sim(seed)
        results = sim.run_many(3, engine="event")
    finally:
        sim_mod._Trajectory._finish = orig

    n = sim.workload.n_requests
    for traj in range(3):
        rids = [rid for tj, rid, _ in calls if tj == traj]
        assert sorted(rids) == list(range(n)), \
            f"traj {traj}: requests resolved != exactly once"
        statuses = {s for tj, _, s in calls if tj == traj}
        assert statuses <= {COMPLETED, SHED, DROPPED}
    for res in results:
        assert res.completed + res.shed + res.dropped_inflight == n


# ------------------------------------------------------------------ queue
@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_fifo_within_priority(seed):
    """Pops come highest class first, FIFO inside each class."""
    from repro.serving.requests import Request
    rng = np.random.default_rng(seed)
    q = AdmissionQueue(capacity=64, queue_budget_s=math.inf)
    offered = []
    for rid in range(int(rng.integers(2, 40))):
        req = Request(rid=rid, arrival_s=float(rid),
                      prompt_tokens=8, max_tokens=4,
                      priority=int(rng.integers(0, 2)))
        assert q.offer(req, now=float(rid))
        offered.append(req)
    popped = []
    while True:
        req = q.pop(now=1e9)
        if req is None:
            break
        popped.append(req)
    assert len(popped) == len(offered)
    want = sorted(offered, key=lambda r: (r.priority, r.rid))
    assert [r.rid for r in popped] == [r.rid for r in want]


def test_queue_full_sheds_and_requeue_front_bypasses_capacity():
    from repro.serving.requests import Request
    q = AdmissionQueue(capacity=2, queue_budget_s=math.inf)
    reqs = [Request(rid=i, arrival_s=0.0, prompt_tokens=8, max_tokens=4)
            for i in range(4)]
    assert q.offer(reqs[0], 0.0) and q.offer(reqs[1], 0.0)
    assert not q.offer(reqs[2], 0.0)          # full → shed
    assert q.shed[-1][1] == "queue_full"
    q.requeue_front(reqs[3], 1.0)             # handover bypasses the bound
    assert len(q) == 3
    assert q.pop(2.0).rid == 3                # and pops first in its class


def test_queue_budget_shed_records_expiry_instant():
    from repro.serving.requests import Request
    q = AdmissionQueue(capacity=8, queue_budget_s=5.0)
    q.offer(Request(rid=0, arrival_s=0.0, prompt_tokens=8, max_tokens=4),
            now=1.0)
    assert q.pop(now=100.0) is None           # expired long before the look
    req, reason, t = q.shed[-1]
    assert (req.rid, reason, t) == (0, "queue_budget", 6.0)


# ---------------------------------------------------------------- replica
@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_drained_or_down_replica_never_admits(seed):
    """State-machine walk: `can_admit()` iff status is ACTIVE."""
    rng = np.random.default_rng(seed)
    r = Replica(slot=0, death_s=100.0)
    now = 0.0
    for _ in range(30):
        op = int(rng.integers(0, 3))
        now += float(rng.uniform(0.1, 10.0))
        if op == 0:
            r.start_drain()
            assert not r.can_admit()
        elif op == 1:
            r.kill(now, startup_s=float(rng.uniform(1.0, 60.0)))
            assert not r.can_admit()
        else:
            r.rejoin(now, lifetime_s=float(rng.uniform(0.1, 3600.0)),
                     warning_s=float(rng.uniform(0.0, 120.0)))
            assert r.can_admit()
            assert r.drain_s >= now          # notice never in the past
        assert r.can_admit() == (r.status == ACTIVE)


# ------------------------------------------------------------ sim parity
@given(seed=st.integers(0, 12))
@settings(max_examples=4, deadline=None)
def test_engine_parity_batched_vs_event(seed):
    """The heap driver and the lexsort driver replay identical histories
    under a revocation wave (counts exact, times within 1e-6)."""
    a = _wave_sim(seed).run_many(3, engine="batched")
    b = _wave_sim(seed).run_many(3, engine="event")
    for ra, rb in zip(a, b):
        assert (ra.completed, ra.shed, ra.dropped_inflight,
                ra.dropped_warned, ra.handovers, ra.requeues, ra.hedges,
                ra.revocations, ra.replacements, ra.tokens_served) == \
               (rb.completed, rb.shed, rb.dropped_inflight,
                rb.dropped_warned, rb.handovers, rb.requeues, rb.hedges,
                rb.revocations, rb.replacements, rb.tokens_served)
        np.testing.assert_allclose(ra.latencies_s, rb.latencies_s,
                                   rtol=1e-6, atol=1e-9)
        assert ra.cost == pytest.approx(rb.cost, rel=1e-6)


def test_armed_fleet_drops_nothing_on_warned_revocations():
    """AWS warns 120s ahead; an armed fleet drains, so warned revocations
    drop zero in-flight requests (the serve_wave headline gate)."""
    results = _wave_sim(0, armed=True, provider="aws").run_many(6)
    assert sum(r.warned_revocations for r in results) > 0
    assert sum(r.dropped_warned for r in results) == 0


# ---------------------------------------------------------------- planner
def test_plan_serving_golden_ranking():
    """Pinned simulator-scored grid: keyed streams make this exact."""
    wl = ServingWorkload(n_requests=120, arrival_rate_per_s=2.0,
                         max_tokens=16)
    best, plans = plan_serving(wl, ServingSLO(p99_latency_s=5.0),
                               replica_counts=(2, 4),
                               providers=("gcp", "aws"),
                               token_time_s=0.05, samples=4, seed=3)
    ranking = [(p.provider, p.region, p.replicas) for p in plans]
    assert ranking == [("gcp", "us-central1", 2), ("aws", "us-east-1", 2),
                       ("gcp", "us-central1", 4), ("aws", "us-east-1", 4)]
    assert best is plans[0]
    assert all(p.meets_slo for p in plans)
    assert best.cost_per_1k == pytest.approx(0.207017, abs=1e-4)
    assert best.latency_p99_s == pytest.approx(0.829606, abs=1e-4)


# ----------------------------------------------------------- degradation
def test_degradation_tiers_are_cumulative():
    p = WAVE_POLICY
    assert p.tier(4, 4) == "full"
    assert p.tier(3, 4) == "reduce_tokens"
    assert p.tier(2, 4) == "shrink_batch"
    assert p.tier(1, 4) == "shed_low_priority"
    # cumulative: the shed tier also caps tokens and shrinks the batch
    assert p.token_cap("shed_low_priority", 32) == 16
    assert p.batch_ceiling("shed_low_priority", 8) == 4
    assert p.token_cap("full", 32) == 32
    assert p.batch_ceiling("reduce_tokens", 8) == 8
    assert not ServingDegradationPolicy().sheds_low_priority(
        ServingDegradationPolicy().tier(1, 4))  # defaults never degrade


# --------------------------------------------------------- model gateway
@pytest.fixture(scope="module")
def smoke_session():
    from repro.api.session import Session
    return Session.from_arch("qwen3-1.7b", smoke=True)


def test_temperature_diverges_at_token_zero(smoke_session):
    """Regression: the old loop argmax'd the FIRST token regardless of
    temperature; two sampling seeds could never differ before token 1.
    Same prompt, different sampling seeds → token 0 must differ."""
    from repro.api.serving import generate
    prompt = np.full((2, 8), 7, dtype=np.int32)
    a = generate(smoke_session.cfg, batch=2, prompt_len=8, tokens=4,
                 temperature=1.0, seed=11, prompt=prompt)
    b = generate(smoke_session.cfg, batch=2, prompt_len=8, tokens=4,
                 temperature=1.0, seed=12, prompt=prompt)
    ga, gb = np.asarray(a.generated), np.asarray(b.generated)
    assert ga.shape == gb.shape == (2, 4)
    assert (ga[:, 0] != gb[:, 0]).any(), \
        "seeds must be able to diverge at the first generated token"
    # greedy stays deterministic (same seed → identical replay)
    c = smoke_session.serve(tokens=4, batch=2, prompt_len=8, seed=11)
    d = smoke_session.serve(tokens=4, batch=2, prompt_len=8, seed=11)
    np.testing.assert_array_equal(np.asarray(c.generated),
                                  np.asarray(d.generated))


def test_serve_report_threads_decode_percentiles(smoke_session):
    rep = smoke_session.serve(tokens=8, batch=2, prompt_len=8)
    assert rep.decode_ms_p50 > 0.0
    assert rep.decode_ms_p50 <= rep.decode_ms_p95 <= rep.decode_ms_p99
    ev = smoke_session.bus.of_kind("serve")[-1].payload
    assert ev["decode_ms_p99"] >= ev["decode_ms_p50"] > 0.0


@pytest.mark.slow
def test_gateway_staggered_join_matches_solo(smoke_session):
    """A request boarding mid-flight decodes the same greedy tokens it
    would alone — slots are isolated in the shared decode state."""
    from repro.serving.engine import GatewayEngine
    cfg = smoke_session.cfg
    prompts = [[3, 1, 4, 1, 5], [9, 2, 6, 5, 3]]

    solo = {}
    for rid, prompt in enumerate(prompts):
        eng = GatewayEngine(cfg, slots=2, max_len=16, seed=1)
        eng.join(0, rid=rid, prompt=prompt, max_new=4)
        toks = []
        while eng.busy():
            for ev in eng.step():
                if "tokens" in ev:
                    toks = ev["tokens"]
        solo[rid] = toks

    eng = GatewayEngine(cfg, slots=2, max_len=16, seed=1)
    eng.join(0, rid=0, prompt=prompts[0], max_new=4)
    done = {}
    for step in range(40):
        if step == 3:  # board rid 1 while rid 0 is mid-flight
            eng.join(1, rid=1, prompt=prompts[1], max_new=4)
        if not eng.busy():
            break
        for ev in eng.step():
            if "tokens" in ev:
                done[ev["rid"]] = ev["tokens"]
    assert done == solo
