"""`repro.api.Session` facade + `python -m repro` CLI smoke coverage:
plan -> simulate -> predict on a reduced config, elastic training through
the event bus, and the shared argparse helpers."""
import jax
import numpy as np
import pytest

from repro.api import EventBus, Session
from repro.configs import RunConfig
from repro.core.trainer import MembershipEvent


@pytest.fixture(scope="module")
def session():
    return Session.from_arch("qwen3-1.7b", total_steps=200,
                             checkpoint_interval=50, zero1=False)


def test_from_arch_resolves_and_describes(session):
    info = session.describe()
    assert info["arch"] == "qwen3-1.7b"
    assert info["params"] > 0
    assert session.model_gflops() > 0
    with pytest.raises(KeyError):
        Session.from_arch("not-an-arch")


def test_plan_scores_region_hour_grid(session):
    best, plans = session.plan(gpu="v100", n_workers=2, steps=500,
                               hours=[0, 12])
    regions = {p.region for p in plans}
    assert len(plans) == 2 * len(regions)
    assert best.expected_cost == min(p.expected_cost for p in plans)
    assert best.n_workers == 2


def test_simulate_transient_run(session):
    res = session.simulate(n_workers=3, gpu="v100", steps=300, seed=0)
    assert res.steps_done == 300
    assert res.total_time_s > 0
    assert res.monetary_cost > 0
    # handover policy never loses steps to recomputation
    assert res.recompute_time_s == 0.0


def test_predict_composes_eq4(session):
    rep = session.predict(n_workers=2, gpu="v100", steps=1000,
                          checkpoint_interval=100)
    assert rep.cluster_speed <= 2 * rep.worker_speed + 1e-9
    # Eq (4) total >= pure compute + checkpoint time
    floor = 1000 / rep.cluster_speed + 10 * rep.checkpoint_seconds
    assert rep.total_time_seconds >= floor - 1e-6
    assert 0 <= rep.expected_revocations <= 2


def test_train_emits_bus_events(tmp_path):
    s = Session.from_arch("qwen3-1.7b", total_steps=12, warmup_steps=1,
                          checkpoint_interval=5, lr=1e-3, zero1=False)
    rep = s.train(12, global_batch=4, seq_len=32, members=2,
                  events=[MembershipEvent(step=4, kind="revoke",
                                          member_id=1)],
                  checkpoint_dir=str(tmp_path))
    assert rep.steps_run == 12
    assert not np.isnan(rep.losses).any()
    steps_seen = [e.payload["step"] for e in s.bus.of_kind("step")]
    assert steps_seen == list(range(12))
    epochs = s.bus.of_kind("epoch")
    assert len(epochs) == 1 and epochs[0].payload["kind"] == "revoke"
    assert len(s.bus.of_kind("checkpoint")) == rep.checkpoints


def test_event_bus_wildcard_and_history():
    bus = EventBus(keep_history=3)
    got = []
    bus.subscribe("*", lambda kind, p: got.append(kind))
    bus.on("a")(lambda kind, p: got.append("only-" + kind))
    for k in ("a", "b", "c", "d"):
        bus.emit(k, x=1)
    assert got == ["only-a", "a", "b", "c", "d"]
    assert [e.kind for e in bus.history] == ["b", "c", "d"]  # bounded


# ------------------------------------------------------------------ CLI
def test_cli_parser_covers_all_subcommands():
    from repro.__main__ import _HANDLERS, build_parser
    p = build_parser()
    for argv in (["train", "--arch", "qwen3-1.7b", "--steps", "3"],
                 ["serve", "--tokens", "4"],
                 ["plan", "--gpu", "k80"],
                 ["simulate", "--workers", "2"],
                 ["predict"],
                 ["bench", "--only", "table1_speed"]):
        args = p.parse_args(argv)
        assert args.cmd == argv[0]
        assert args.cmd in _HANDLERS
    # dryrun dispatches before argparse (its flags belong to launch.dryrun)
    assert "dryrun" not in _HANDLERS


def test_cli_run_config_mapping():
    from repro.launch import cli
    p = cli.make_parser("t", "t")
    cli.add_arch_arg(p)
    cli.add_scale_args(p)
    cli.add_batch_args(p)
    cli.add_train_args(p)
    args = p.parse_args(["--steps", "40", "--lr", "0.01", "--seed", "7"])
    run = cli.run_config_from_args(args)
    assert isinstance(run, RunConfig)
    assert (run.total_steps, run.lr, run.seed) == (40, 0.01, 7)
    assert run.warmup_steps == 4
    session = cli.session_from_args(args)
    assert session.arch == "qwen3-1.7b" and session.run.total_steps == 40


def test_bench_driver_exit_codes():
    from benchmarks import run as bench_run
    assert bench_run.main(["--list"]) == 0
    assert bench_run.main(["--only", "definitely_not_a_module"]) == 2
