"""Validate the multi-pod dry-run deliverable from its artifacts: every
applicable (arch x shape) cell compiled on BOTH production meshes with sane
cost/collective numbers. (Artifacts are produced by
scripts/run_dryrun_sweep.py; this test documents+guards the deliverable.)"""
import glob
import json
import os

import pytest

from repro.configs import ALL_SHAPES, ARCH_IDS, get_config, valid_cells

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(ART, "*.json")),
    reason="dry-run artifacts not generated (run scripts/run_dryrun_sweep.py)")


def _load(arch, shape):
    path = os.path.join(ART, f"{arch}__{shape}.json")
    if not os.path.exists(path):
        return None
    return json.load(open(path))


def test_all_40_cells_have_artifacts():
    missing = []
    for arch in ARCH_IDS:
        for s in ALL_SHAPES:
            if _load(arch, s.name) is None:
                missing.append((arch, s.name))
    assert not missing, missing


def test_applicable_cells_compiled_on_both_meshes():
    bad = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        valid = {s.name for s in valid_cells(cfg)}
        for s in ALL_SHAPES:
            recs = _load(arch, s.name)
            if s.name not in valid:
                assert any(r.get("skipped") for r in recs), (arch, s.name)
                continue
            meshes = {r.get("mesh") for r in recs if r.get("ok")}
            if not {"16x16", "2x16x16"} <= meshes:
                bad.append((arch, s.name, meshes))
    assert not bad, bad


def test_singlepod_costs_are_sane():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in valid_cells(cfg):
            recs = [r for r in _load(arch, s.name)
                    if r.get("ok") and r.get("mesh") == "16x16"]
            for r in recs:
                assert r["flops_per_device_corrected"] > 0, (arch, s.name)
                assert r["bytes_per_device_corrected"] > 0
                terms = r["roofline"]
                assert all(v >= 0 for v in terms.values())
                # useful-flops ratio must be physical (0 < ratio <= ~1.1)
                if s.kind == "train":
                    assert 0.01 < r["useful_flops_ratio"] < 1.2, \
                        (arch, s.name, r["useful_flops_ratio"])
