"""The long_500k cell rationale, as executable facts: SSM decode state is
O(1) in context length, attention KV cache is O(L) — why mamba2/zamba2 run
the 500k cell and pure-attention archs skip it (docs/DESIGN.md §4)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import LONG_500K, get_config, valid_cells
from repro.models import api


def _state_bytes(cfg, max_len):
    vals, _ = api.decode_state_specs(cfg, batch=1, max_len=max_len)
    return sum(int(jnp.dtype(v.dtype).itemsize) *
               int(jnp.prod(jnp.array(v.shape)))
               for v in jax.tree.leaves(vals))


def test_ssm_state_constant_in_context_length():
    cfg = get_config("mamba2-1.3b")
    assert _state_bytes(cfg, 1024) == _state_bytes(cfg, 524288)


def test_attention_cache_linear_in_context_length():
    cfg = get_config("qwen3-1.7b")
    b1, b2 = _state_bytes(cfg, 1024), _state_bytes(cfg, 4096)
    assert b2 == pytest.approx(4 * b1, rel=0.01)


def test_hybrid_cache_sublinear():
    """zamba2: one shared attention block per 6 mamba layers -> cache grows
    with L but ~7x smaller than a full-attention peer of the same size."""
    zb = get_config("zamba2-1.2b")
    qw = get_config("qwen3-1.7b")
    L = 32768
    per_layer_zb = _state_bytes(zb, L) / zb.n_layers
    per_layer_qw = _state_bytes(qw, L) / qw.n_layers
    assert per_layer_zb < per_layer_qw

    # growth from 32k -> 500k is far below linear (only the shared blocks)
    g = _state_bytes(zb, 524288) / _state_bytes(zb, 32768)
    assert g < 16.5  # linear would be 16x on the attention part alone


def test_long_500k_cell_membership():
    runs = {a for a in ("mamba2-1.3b", "zamba2-1.2b")}
    for arch in ("qwen3-1.7b", "yi-6b", "starcoder2-15b", "stablelm-1.6b",
                 "qwen2-vl-2b", "granite-moe-3b-a800m",
                 "deepseek-v2-lite-16b", "hubert-xlarge",
                 "mamba2-1.3b", "zamba2-1.2b"):
        cfg = get_config(arch)
        names = {s.name for s in valid_cells(cfg)}
        assert (LONG_500K.name in names) == (arch in runs), arch
