"""Property-based kernel validation (hypothesis): random shape/dtype sweeps
against the jnp oracles, plus structural invariants (causality, scale/shift
equivariances) that hold for ANY correct implementation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

_dims = st.sampled_from([32, 64, 128])
_heads = st.sampled_from([(2, 1), (2, 2), (4, 2)])  # (H, KV)


@given(sq=_dims, hk=_heads, hd=st.sampled_from([32, 64]),
       seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_flash_attention_matches_ref_random(sq, hk, hd, seed):
    H, KV = hk
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, H, hd))
    k = jax.random.normal(ks[1], (1, sq, KV, hd))
    v = jax.random.normal(ks[2], (1, sq, KV, hd))
    out = ops.flash_attention(q, k, v, True, 32, 32)
    want = ref.flash_attention_ref(q, k, v, True)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


@given(seed=st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_flash_attention_causality(seed):
    """Future tokens must not influence past outputs."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    S, H, hd = 64, 2, 32
    q = jax.random.normal(ks[0], (1, S, H, hd))
    k = jax.random.normal(ks[1], (1, S, H, hd))
    v = jax.random.normal(ks[2], (1, S, H, hd))
    out1 = ops.flash_attention(q, k, v, True, 32, 32)
    # perturb the LAST key/value only
    k2 = k.at[:, -1].add(jax.random.normal(ks[3], (1, H, hd)))
    out2 = ops.flash_attention(q, k2, v, True, 32, 32)
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], atol=1e-6)


@given(scale=st.floats(0.25, 4.0), seed=st.integers(0, 10))
@settings(max_examples=8, deadline=None)
def test_rmsnorm_scale_invariance(scale, seed):
    """rmsnorm(c*x) == rmsnorm(x) for any positive scalar c."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64))
    g = jnp.ones((64,))
    a = ops.rmsnorm(x, g)
    b = ops.rmsnorm(x * scale, g)
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


@given(s=st.sampled_from([64, 128]), chunk=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_ssd_chunk_size_invariance(s, chunk, seed):
    """The chunked SSD result must not depend on the chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, h, p, n = 1, 2, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y1 = ops.ssd_scan(x, dt, A, B, C, chunk)
    y2 = ref.ssd_scan_ref(x, dt, A, B, C, chunk=s)  # single chunk
    scale = float(jnp.max(jnp.abs(y2))) + 1e-6
    np.testing.assert_allclose(np.asarray(y1) / scale,
                               np.asarray(y2) / scale, atol=2e-4)


@given(seed=st.integers(0, 10))
@settings(max_examples=6, deadline=None)
def test_ssd_state_continuity(seed):
    """Splitting a sequence in two and carrying the state == one pass."""
    from repro.models.ssm import ssd
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, s, h, p, n = 1, 64, 2, 8, 4
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y_full = ssd(x, dt, A, B, C, chunk=16)
    half = s // 2
    y1, st1 = ssd(x[:, :half], dt[:, :half], A, B[:, :half], C[:, :half],
                  chunk=16, return_state=True)
    y2 = ssd(x[:, half:], dt[:, half:], A, B[:, half:], C[:, half:],
             chunk=16, initial_state=st1)
    y_split = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(y_full, y_split, atol=1e-4, rtol=1e-3)
