"""Property-based kernel validation (hypothesis): random shape/dtype sweeps
against the jnp oracles, plus structural invariants (causality, scale/shift
equivariances) that hold for ANY correct implementation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

_dims = st.sampled_from([32, 64, 128])
_heads = st.sampled_from([(2, 1), (2, 2), (4, 2)])  # (H, KV)


@given(sq=_dims, hk=_heads, hd=st.sampled_from([32, 64]),
       seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_flash_attention_matches_ref_random(sq, hk, hd, seed):
    H, KV = hk
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, H, hd))
    k = jax.random.normal(ks[1], (1, sq, KV, hd))
    v = jax.random.normal(ks[2], (1, sq, KV, hd))
    out = ops.flash_attention(q, k, v, True, 32, 32)
    want = ref.flash_attention_ref(q, k, v, True)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


@given(seed=st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_flash_attention_causality(seed):
    """Future tokens must not influence past outputs."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    S, H, hd = 64, 2, 32
    q = jax.random.normal(ks[0], (1, S, H, hd))
    k = jax.random.normal(ks[1], (1, S, H, hd))
    v = jax.random.normal(ks[2], (1, S, H, hd))
    out1 = ops.flash_attention(q, k, v, True, 32, 32)
    # perturb the LAST key/value only
    k2 = k.at[:, -1].add(jax.random.normal(ks[3], (1, H, hd)))
    out2 = ops.flash_attention(q, k2, v, True, 32, 32)
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], atol=1e-6)


@given(scale=st.floats(0.25, 4.0), seed=st.integers(0, 10))
@settings(max_examples=8, deadline=None)
def test_rmsnorm_scale_invariance(scale, seed):
    """rmsnorm(c*x) == rmsnorm(x) for any positive scalar c."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64))
    g = jnp.ones((64,))
    a = ops.rmsnorm(x, g)
    b = ops.rmsnorm(x * scale, g)
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


@given(s=st.sampled_from([64, 128]), chunk=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_ssd_chunk_size_invariance(s, chunk, seed):
    """The chunked SSD result must not depend on the chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, h, p, n = 1, 2, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y1 = ops.ssd_scan(x, dt, A, B, C, chunk)
    y2 = ref.ssd_scan_ref(x, dt, A, B, C, chunk=s)  # single chunk
    scale = float(jnp.max(jnp.abs(y2))) + 1e-6
    np.testing.assert_allclose(np.asarray(y1) / scale,
                               np.asarray(y2) / scale, atol=2e-4)


# ----------------------------------------------- event-select kernel
from repro.kernels.event_select import event_select_fwd


def _es_both(ev, **kw):
    """Pallas kernel (interpret mode off-TPU) and the jnp oracle."""
    t, i = event_select_fwd(ev, interpret=True, **kw)
    rt, ri = ref.event_select_ref(ev)
    return (np.asarray(t), np.asarray(i)), (np.asarray(rt), np.asarray(ri))


@given(n=st.sampled_from([1, 7, 64, 300]), m=st.sampled_from([2, 8, 17]),
       mask_p=st.floats(0.0, 1.0), seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_event_select_matches_ref_random(n, m, mask_p, seed):
    """Random event matrices with random inf masking — including rows
    that come out fully masked — agree with the oracle bit for bit."""
    rng = np.random.default_rng(seed)
    ev = rng.uniform(0.0, 1e6, (n, m))
    ev[rng.random((n, m)) < mask_p] = np.inf
    (t, i), (rt, ri) = _es_both(jnp.asarray(ev))
    np.testing.assert_array_equal(t, rt)
    np.testing.assert_array_equal(i, ri)


def test_event_select_all_masked_rows_return_inf_col0():
    ev = jnp.full((5, 4), jnp.inf)
    (t, i), (rt, ri) = _es_both(ev)
    assert np.all(np.isinf(t)) and np.all(i == 0)
    np.testing.assert_array_equal(t, rt)
    np.testing.assert_array_equal(i, ri)


def test_event_select_ties_break_to_lowest_column():
    """Exact duplicates of the min must resolve to the lowest column —
    NumPy argmin semantics, which the engine parity contract pins (a
    revocation timer beats a join timer at the same instant)."""
    ev = jnp.asarray([[3.0, 1.0, 1.0, 5.0],
                     [2.0, 2.0, 2.0, 2.0],
                     [np.inf, 4.0, np.inf, 4.0]])
    (t, i), (rt, ri) = _es_both(ev)
    np.testing.assert_array_equal(i, [1, 0, 1])
    np.testing.assert_array_equal(t, [1.0, 2.0, 4.0])
    np.testing.assert_array_equal(i, ri)
    np.testing.assert_array_equal(t, rt)


def test_event_select_minus_inf_sentinel():
    """-inf (an already-due event) wins every row it appears in and
    still tie-breaks low; mixed ±inf rows must not poison the min."""
    ev = jnp.asarray([[-np.inf, 0.0, np.inf],
                     [np.inf, -np.inf, -np.inf],
                     [0.5, np.inf, -np.inf]])
    (t, i), (rt, ri) = _es_both(ev)
    np.testing.assert_array_equal(t, [-np.inf, -np.inf, -np.inf])
    np.testing.assert_array_equal(i, [0, 1, 2])
    np.testing.assert_array_equal(t, rt)
    np.testing.assert_array_equal(i, ri)


@given(n=st.sampled_from([1, 5, 37, 255, 257]), seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_event_select_row_counts_off_block_boundary(n, seed):
    """n not a multiple of block_rows exercises the pad path: padded
    rows are all-inf and must be sliced back off."""
    rng = np.random.default_rng(seed)
    ev = jnp.asarray(rng.uniform(0.0, 1.0, (n, 6)))
    for br in (4, 16, 256):
        t, i = event_select_fwd(ev, interpret=True, block_rows=br)
        rt, ri = ref.event_select_ref(ev)
        assert t.shape == (n,) and i.shape == (n,)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(rt))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_event_select_dispatch_matches_kernel():
    """ops.event_select (the engine's entry point) agrees with the
    explicit kernel whatever backend it dispatched to."""
    rng = np.random.default_rng(0)
    ev = rng.uniform(0.0, 10.0, (33, 9))
    ev[rng.random((33, 9)) < 0.3] = np.inf
    ev = jnp.asarray(ev)
    t, i = ops.event_select(ev)
    kt, ki = event_select_fwd(ev, interpret=True)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(kt))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ki))


@given(seed=st.integers(0, 10))
@settings(max_examples=6, deadline=None)
def test_ssd_state_continuity(seed):
    """Splitting a sequence in two and carrying the state == one pass."""
    from repro.models.ssm import ssd
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, s, h, p, n = 1, 64, 2, 8, 4
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y_full = ssd(x, dt, A, B, C, chunk=16)
    half = s // 2
    y1, st1 = ssd(x[:, :half], dt[:, :half], A, B[:, :half], C[:, :half],
                  chunk=16, return_state=True)
    y2 = ssd(x[:, half:], dt[:, half:], A, B[:, half:], C[:, half:],
             chunk=16, initial_state=st1)
    y_split = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(y_full, y_split, atol=1e-4, rtol=1e-3)
