"""Transient-fleet layer tests: revocation models, startup, replacement,
fleet simulation invariants."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.transient.fleet import FleetSim, SimWorker
from repro.core.transient.replacement import (ReplacementModel,
                                              recomputation_overhead_s)
from repro.core.transient.revocation import (REGION_GPU_PARAMS, TABLE5_RATES,
                                             RevocationSampler)
from repro.core.transient.startup import StartupModel


# ----------------------------------------------------------------- lifetimes
@pytest.mark.parametrize("key", sorted(
    k for k, v in TABLE5_RATES.items() if v is not None))
def test_cdf_monotone_and_bounded(key):
    m = REGION_GPU_PARAMS[key]
    ts = np.linspace(0, 24, 200)
    c = m.cdf(ts)
    assert np.all(np.diff(c) >= -1e-12)
    assert c[-1] == pytest.approx(m.p24, abs=1e-9)
    assert m.prob_revoked_within(24.0) == pytest.approx(m.p24, abs=1e-9)


def test_empirical_rate_matches_table5():
    samp = RevocationSampler(seed=0)
    for key, rate in list(TABLE5_RATES.items()):
        if rate is None:
            continue
        region, gpu = key
        n = 400
        revoked = sum(1 for _ in range(n)
                      if math.isfinite(samp.lifetime(region, gpu)))
        assert abs(revoked / n - rate) < 0.08, (key, revoked / n, rate)


def test_uswest_k80_long_lived_vs_europe():
    """Fig 8: >50% of europe-west1 K80s die in 2h; <5% in us-west1."""
    eu = REGION_GPU_PARAMS[("europe-west1", "k80")]
    us = REGION_GPU_PARAMS[("us-west1", "k80")]
    assert eu.cdf(np.array([2.0]))[0] > 0.4
    assert us.cdf(np.array([2.0]))[0] < 0.05


# ------------------------------------------------------------------- startup
def test_startup_under_100s_and_ordering():
    m = StartupModel(0)
    for gpu in ("k80", "p100", "v100"):
        tr = m.mean_total(gpu, transient=True)
        od = m.mean_total(gpu, transient=False)
        assert tr < 100.0
        assert tr > od  # transient slower than on-demand
    assert m.mean_total("p100") > m.mean_total("k80")  # paper: ~8.7% slower


# ---------------------------------------------------------------- replacement
def test_cold_warm_ordering_and_complexity_growth():
    m = ReplacementModel(0)
    assert m.cold_start_s(0.59) > m.warm_start_s(0.59)
    assert m.cold_start_s(21.3) > m.cold_start_s(0.59)


@given(st.integers(0, 4000), st.floats(0.5, 50))
def test_recompute_bounded_by_interval(steps_since, speed):
    t = recomputation_overhead_s(steps_since, speed, True)
    assert t <= 4000 / speed + 1e-9
    assert recomputation_overhead_s(steps_since, speed, False) == 0.0


# -------------------------------------------------------------------- fleet
def _mk_sim(seed=0, handover=True, replace=True):
    workers = [SimWorker(i, "k80", "us-west1", 4.56) for i in range(4)]
    return FleetSim(workers, model_gflops=1.54, model_bytes=1.87e6,
                    step_speed_of=lambda g: 4.56,
                    checkpoint_interval_steps=1000, checkpoint_time_s=3.84,
                    seed=seed, handover=handover, replace=replace)


def test_fleet_completes_and_conserves():
    res = _mk_sim().run(8000)
    assert res.steps_done >= 8000
    assert res.revocations >= 0
    assert res.total_time_s > 0
    # no-revocation lower bound: N/sp + ckpt time
    lower = 8000 / (4 * 4.56)
    assert res.total_time_s >= lower


def test_fleet_handover_never_slower():
    """Chief handover removes recompute time vs stock identity-reuse."""
    t_handover = np.mean([_mk_sim(s, True).run(6000).recompute_time_s
                          for s in range(3)])
    t_stock = np.mean([_mk_sim(s, False).run(6000).recompute_time_s
                       for s in range(3)])
    assert t_handover <= t_stock + 1e-9


def test_fleet_no_replacement_slower():
    fast = np.mean([_mk_sim(s, True, True).run(6000).total_time_s
                    for s in range(3)])
    slow = np.mean([_mk_sim(s, True, False).run(6000).total_time_s
                    for s in range(3)])
    assert fast <= slow + 1e-9
