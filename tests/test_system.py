"""End-to-end behaviour tests for the transient training system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config
from repro.core.controller import Action, Controller
from repro.core.profiler import PerformanceProfiler
from repro.core.trainer import MembershipEvent, TransientTrainer
from repro.data.pipeline import ShardedLoader, SyntheticTokenSource
from repro.dist.elastic import Member


@pytest.fixture
def small_setup(tmp_path):
    cfg = get_config("qwen3-1.7b", smoke=True)
    run = RunConfig(total_steps=40, warmup_steps=2, checkpoint_interval=8,
                    checkpoint_dir=str(tmp_path), lr=1e-3, zero1=False)
    src = SyntheticTokenSource(cfg.vocab_size, 24)
    return cfg, run, src


def test_training_survives_revocation_and_join(small_setup):
    cfg, run, src = small_setup
    tr = TransientTrainer(cfg, run, ShardedLoader(src, 8),
                          members=[Member(0), Member(1), Member(2)])
    state, _ = tr.restore_or_init()
    events = [MembershipEvent(step=5, kind="revoke", member_id=2),
              MembershipEvent(step=9, kind="revoke", member_id=1),
              MembershipEvent(step=14, kind="join", member_id=3)]
    state, rep = tr.run_steps(state, 20, events=events)
    assert rep.epochs == 4                      # initial + 3 events
    assert rep.losses[-1] < rep.losses[0]       # still learning throughout
    assert not np.isnan(rep.losses).any()
    assert rep.checkpoints >= 2


def test_restart_resumes_from_checkpoint(small_setup):
    cfg, run, src = small_setup
    tr = TransientTrainer(cfg, run, ShardedLoader(src, 8))
    state, _ = tr.restore_or_init()
    state, rep1 = tr.run_steps(state, 16)       # checkpoints at 8, 16
    # simulate full cluster loss; a NEW worker restores
    tr2 = TransientTrainer(cfg, run, ShardedLoader(src, 8), holder="worker-9")
    tr2.ckpt.lease.notify_revoked()
    state2, start = tr2.restore_or_init()
    assert start == 16
    assert int(state2.step) == 16
    # training continues (does not restart from scratch)
    state2, rep2 = tr2.run_steps(state2, 2)
    assert rep2.losses[0] < rep1.losses[0]      # continued, not restarted


def test_all_members_revoked_raises(small_setup):
    cfg, run, src = small_setup
    tr = TransientTrainer(cfg, run, ShardedLoader(src, 8), members=[Member(0)])
    state, _ = tr.restore_or_init()
    with pytest.raises(RuntimeError):
        tr.run_steps(state, 5, events=[
            MembershipEvent(step=1, kind="revoke", member_id=0)])


def test_controller_flags_underperformance():
    prof = PerformanceProfiler(window=2, warmup_steps=0, warmup_seconds=0.0)
    t = 0.0
    for s in range(6):
        prof.record(s, t=t)
        t += 0.2                                 # 5 steps/s measured
    ctrl = Controller(threshold=0.067)
    det = ctrl.check(prof, predicted_speed=10.0)  # predicted 10 steps/s
    assert det.bottleneck
    assert det.action in (Action.REPLACE_WORKER,
                          Action.ADD_PARAMETER_SERVER)
    ok = ctrl.check(prof, predicted_speed=5.05)
    assert not ok.bottleneck


def test_async_sgd_converges_with_heterogeneous_workers():
    from repro.core.ps_async import async_sgd
    target = jnp.array([1.0, -2.0, 0.5])

    def loss_fn(w, x, y):
        return jnp.mean((x @ w - y) ** 2)

    def data(worker, key):
        x = jax.random.normal(key, (16, 3))
        return x, x @ target

    w0 = jnp.zeros(3)
    # 4 workers with 3x pace spread (K80-vs-V100-like)
    w, trace = async_sgd(loss_fn, w0, data, [0.1, 0.1, 0.2, 0.3],
                         lr=0.05, total_updates=150)
    assert trace.losses[-1] < 1e-2
    assert max(trace.staleness_hist) >= 1       # staleness actually occurred
    np.testing.assert_allclose(w, target, atol=0.05)


def test_grad_compression_error_feedback():
    from repro.dist.compression import ErrorFeedback
    params = {"w": jnp.zeros((64,))}
    ef = ErrorFeedback("int8")
    res = ef.init(params)
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64)
    applied_sum = np.zeros(64)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=64), jnp.float32)}
        d, res = ef.roundtrip(g, res)
        true_sum += np.asarray(g["w"])
        applied_sum += np.asarray(d["w"])
    # error feedback: accumulated applied updates track the true sum
    denom = np.linalg.norm(true_sum) + 1e-9
    assert np.linalg.norm(applied_sum - true_sum) / denom < 0.05
