"""Minimal, deterministic stand-in for `hypothesis` when it is not
installed (this container has no network; the real package wins whenever it
is importable — see conftest.py).

Supports exactly the subset the test-suite uses:
  * strategies: integers, floats, sampled_from, lists
  * @given(*strategies, **strategies)
  * @settings(max_examples=..., deadline=...)

Semantics: each @given test runs against boundary examples (all-min,
all-max) plus a fixed number of seeded pseudo-random draws — deterministic
across runs, so failures reproduce.
"""
from __future__ import annotations

import random
import sys
import types
from typing import Any, List

_RANDOM_EXAMPLES = 8


class _Strategy:
    def examples(self, rng: random.Random) -> List[Any]:
        raise NotImplementedError

    def draw(self, rng: random.Random) -> Any:
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def examples(self, rng):
        return [self.lo, self.hi]

    def draw(self, rng):
        return rng.randint(self.lo, self.hi)


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float, **_kw):
        self.lo, self.hi = float(lo), float(hi)

    def examples(self, rng):
        return [self.lo, self.hi]

    def draw(self, rng):
        return rng.uniform(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, choices):
        self.choices = list(choices)

    def examples(self, rng):
        return [self.choices[0], self.choices[-1]]

    def draw(self, rng):
        return rng.choice(self.choices)


class _Lists(_Strategy):
    def __init__(self, elem: _Strategy, min_size: int = 0,
                 max_size: int = 10, **_kw):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def examples(self, rng):
        return [[self.elem.draw(rng) for _ in range(self.min_size)],
                [self.elem.draw(rng) for _ in range(self.max_size)]]

    def draw(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.draw(rng) for _ in range(n)]


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = lambda lo, hi: _Integers(lo, hi)
strategies.floats = lambda lo, hi, **kw: _Floats(lo, hi, **kw)
strategies.sampled_from = lambda choices: _SampledFrom(choices)
strategies.lists = lambda elem, **kw: _Lists(elem, **kw)


def given(*arg_strats: _Strategy, **kw_strats: _Strategy):
    def deco(fn):
        # NOT functools.wraps: pytest would follow __wrapped__ and treat the
        # strategy parameters as fixtures. The wrapper takes no arguments.
        def wrapper(*outer_args, **outer_kw):
            rng = random.Random(f"stub:{fn.__module__}.{fn.__qualname__}")
            # boundary combos (all-min, all-max), then seeded random draws
            combos = []
            for pick in (0, 1):
                combos.append((
                    [s.examples(rng)[pick] for s in arg_strats],
                    {k: s.examples(rng)[pick] for k, s in kw_strats.items()}))
            n_random = getattr(fn, "_stub_max_examples", _RANDOM_EXAMPLES)
            for _ in range(min(n_random, _RANDOM_EXAMPLES)):
                combos.append(([s.draw(rng) for s in arg_strats],
                               {k: s.draw(rng) for k, s in kw_strats.items()}))
            for args, kw in combos:
                fn(*outer_args, *args, **{**outer_kw, **kw})
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__qualname__ = fn.__qualname__
        wrapper._stub_inner = fn
        wrapper.hypothesis_stub = True
        return wrapper
    return deco


def settings(max_examples: int = None, deadline=None, **_kw):
    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = max_examples
        return fn
    return deco


def install() -> None:
    """Register the stub under the `hypothesis` names in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
