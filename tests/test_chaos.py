"""Chaos subsystem tests (docs/chaos.md): fault-timeline semantics, the
keyed-hazard determinism contract, two-engine parity under every
registered scenario, the ground-truth evaluator on hand-built histories,
and the live detect -> attribute -> mitigate runs behind
`python -m repro chaos`."""
import json

import numpy as np
import pytest

from repro.api import Session
from repro.chaos import (CheckpointOutage, FaultTimeline, LiveFault,
                         LivePlan, PSCrash, PreemptionWave, PriceSpike,
                         Scenario, StragglerFault, get_scenario,
                         list_scenarios, register_scenario, run_scenario,
                         score_history)
from repro.chaos.runner import _run_sim
from repro.core.transient.fleet import FleetSim, SimWorker


@pytest.fixture(scope="module")
def session():
    return Session.from_arch("qwen3-1.7b", smoke=True)


def _mk_sim(seed=0, n_workers=4, handover=True, chaos=None):
    sp = 15.61
    workers = [SimWorker(i, "v100", "us-central1", sp)
               for i in range(n_workers)]
    return FleetSim(workers, model_gflops=1.54, model_bytes=1.87e6,
                    step_speed_of=lambda g: sp,
                    checkpoint_interval_steps=4000, checkpoint_time_s=3.84,
                    n_ps=1, seed=seed, handover=handover, replace=True,
                    price_of={"v100": 0.74}, provider="gcp", chaos=chaos)


def _timeline(faults, sim=None, seed=0):
    sim = sim or _mk_sim()
    return FaultTimeline(faults, sim._roster, seed=seed)


# ------------------------------------------------- timeline semantics
def test_timeline_factors_are_half_open_windows():
    tl = _timeline((StragglerFault(1.0, 1.0, slot=2, speed_factor=0.3),
                    PSCrash(0.5, 1.0, 0.25),
                    CheckpointOutage(2.0, 0.5)))
    t = np.array([0.0, 3600.0, 7200.0 - 1e-6, 7200.0])
    m = tl.speed_mults(t)
    assert m.shape == (4, 4)
    assert m[0, 2] == 1.0 and m[1, 2] == 0.3 and m[2, 2] == 0.3
    assert m[3, 2] == 1.0                       # end instant excluded
    assert np.all(m[:, [0, 1, 3]] == 1.0)       # only slot 2 touched
    pf = tl.ps_factor(np.array([1799.0, 1800.0, 5399.0, 5400.0]))
    assert list(pf) == [1.0, 0.25, 0.25, 1.0]
    blk = tl.ckpt_blocked(np.array([7199.0, 7200.0, 9000.0 - 1e-3, 9000.0]))
    assert list(blk) == [False, True, True, False]
    # boundaries: every factor-change instant, sorted, in seconds
    assert list(tl.boundaries_s) == [1800.0, 3600.0, 5400.0, 7200.0, 9000.0]
    nb = tl.next_boundary(np.array([0.0, 1800.0, 9000.0]))
    assert list(nb) == [1800.0, 3600.0, np.inf]


def test_timeline_rejects_out_of_roster_slot():
    with pytest.raises(ValueError, match="slot 9"):
        _timeline((StragglerFault(0.0, 1.0, slot=9, speed_factor=0.5),))


def test_hazard_faults_add_no_boundaries():
    tl = _timeline((PreemptionWave(1.0, 2.0, 4.0),
                    PriceSpike(0.5, 1.0, 2.0)))
    assert tl.boundaries_s.size == 0
    assert np.isinf(tl.next_boundary(np.array([0.0]))).all()


def test_truth_spans_record_fault_fields():
    tl = _timeline((PreemptionWave(0.5, 1.0, 6.0, region="us-central1"),
                    PSCrash(1.0, 0.5, 0.0)))
    spans = tl.truth_spans()
    assert spans[0]["kind"] == "preemption_wave"
    assert spans[0]["start_s"] == 1800.0 and spans[0]["end_s"] == 5400.0
    assert spans[0]["region"] == "us-central1"
    assert spans[0]["hazard_per_h"] == 6.0
    assert spans[1] == {"kind": "ps_crash", "start_s": 3600.0,
                        "end_s": 5400.0, "capacity_factor": 0.0}


# ------------------------------------------- keyed hazard determinism
def test_initial_transform_is_pure_function_of_seed():
    wave = PreemptionWave(0.0, 2.0, 5.0)
    lt = np.full((16, 4), np.inf)
    a = _timeline((wave,), seed=7).transform_initial(lt)
    b = _timeline((wave,), seed=7).transform_initial(lt)
    c = _timeline((wave,), seed=8).transform_initial(lt)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.all(a[np.isfinite(a)] <= 2.0)     # kills land in the window
    assert np.isfinite(a).any()                 # hazard 5/h over 2h: some do


def test_region_filter_spares_other_regions():
    sim = FleetSim([SimWorker(0, "v100", "us-central1", 15.0),
                    SimWorker(1, "v100", "europe-west1", 15.0)],
                   model_gflops=1.54, model_bytes=1.87e6,
                   step_speed_of=lambda g: 15.0,
                   checkpoint_interval_steps=4000, checkpoint_time_s=3.84,
                   n_ps=1, seed=0, price_of={"v100": 0.74}, provider="gcp")
    tl = FaultTimeline((PreemptionWave(0.0, 8.0, 50.0,
                                       region="us-central1"),),
                       sim._roster, seed=0)
    lt = np.full((64, 2), np.inf)
    out = tl.transform_initial(lt)
    assert np.isfinite(out[:, 0]).all()         # hazard 50/h: all killed
    assert np.isinf(out[:, 1]).all()            # other region untouched


def test_join_transform_independent_of_batch_grouping():
    """The keyed-stream contract: transforming joins one at a time must
    equal transforming them as one batch (the event engine asks per join,
    the batched engine per generation)."""
    tl = _timeline((PriceSpike(0.0, 4.0, 3.0),), seed=3)
    lt = np.array([5.0, np.inf, 1.5, 8.0])
    trajs = np.array([0, 0, 1, 2])
    slots = np.array([0, 1, 2, 3])
    gens = np.array([1, 1, 2, 1])
    hours = np.array([0.5, 1.0, 0.0, 2.0])
    batch = tl.transform_joins(lt, trajs, slots, gens, hours)
    single = np.array([
        tl.transform_joins(lt[i:i + 1], trajs[i:i + 1], slots[i:i + 1],
                           gens[i:i + 1], hours[i:i + 1])[0]
        for i in range(4)])
    np.testing.assert_array_equal(batch, single)


# ------------------------------------------------- engine parity
def test_standalone_run_matches_ensemble_of_one():
    """`FleetSim.run` under chaos builds its own single-trajectory
    `FleetDraws`, so it must reproduce `run_many(1)` on both engines."""
    faults = (PreemptionWave(0.25, 1.0, 6.0),)

    def fresh():
        sim = _mk_sim()
        sim.chaos = _timeline(faults, sim=sim)
        return sim

    solo = fresh().run(300_000, max_hours=8.0)
    ens_b = fresh().run_many(300_000, 1, max_hours=8.0, engine="batched")
    ens_e = fresh().run_many(300_000, 1, max_hours=8.0, engine="event")
    for r in (ens_b.results[0], ens_e.results[0]):
        assert r.revocations == solo.revocations
        assert r.replacements == solo.replacements
        assert r.total_time_s == pytest.approx(solo.total_time_s, rel=1e-9)


@pytest.mark.parametrize("name", list_scenarios())
def test_every_scenario_holds_engine_parity(session, name):
    """Per-trajectory revocation/replacement/steps counts must be equal
    and times bit-close on both engines, for every registered scenario —
    and the ground-truth hash (truth + transformed lifetime matrix) must
    not depend on the engine choice."""
    sc = get_scenario(name)
    a = _run_sim(session, sc, "batched", 4, seed=1)
    b = _run_sim(session, sc, "event", 4, seed=1)
    assert a["parity"]["counts_equal"] and b["parity"]["counts_equal"]
    assert a["parity"]["time_max_rel_err"] < 1e-9
    assert b["parity"]["time_max_rel_err"] < 1e-9
    assert a["truth_hash"] == b["truth_hash"]
    assert a["faulted"] == b["faulted"] and a["baseline"] == b["baseline"]


def test_dead_ps_stalls_for_the_window(session):
    """Capacity 0 for an hour must cost the run ~the whole window (plus
    nothing else: no revocations are scripted)."""
    card = _run_sim(session, get_scenario("dead_ps"), "batched", 4, seed=0)
    assert card["impact"]["extra_time_s"] == pytest.approx(3600.0, abs=600)
    # no scripted hazard — only stock lifetimes that now fire because the
    # stalled run ends later can add the odd revocation
    assert card["impact"]["extra_revocations"] <= 1.0


# ------------------------------------------------- scenario registry
def test_registry_lists_builtins_and_rejects_duplicates():
    names = list_scenarios()
    assert len(names) >= 6
    for expected in ("regional_wave", "price_spike", "dead_ps", "ps_crash",
                     "straggler", "ckpt_outage", "wave_price_combo"):
        assert expected in names
    with pytest.raises(ValueError, match="already registered"):
        @register_scenario
        def dup():
            return Scenario(name="regional_wave", description="dup")
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("nope")


def test_liveplan_truth_pairs_spans():
    plan = LivePlan(
        n_steps=100,
        faults=(LiveFault(10, "ps_crash", {"capacity_factor": 0.1}),
                LiveFault(40, "ps_recover"),
                LiveFault(20, "straggler", {"slot": 1,
                                            "speed_factor": 0.5}),
                LiveFault(60, "ckpt_outage")))
    spans = {s["kind"]: s for s in plan.truth()}
    assert spans["ps_crash"]["start_step"] == 10
    assert spans["ps_crash"]["end_step"] == 40          # paired
    assert spans["straggler"]["end_step"] == 100        # unpaired -> n_steps
    assert spans["straggler"]["slot"] == 1
    assert spans["ckpt_outage"]["end_step"] == 100


# ------------------------------------------------- evaluator
def _span(kind, start, end, **kw):
    return {"kind": kind, "start_step": start, "end_step": end, **kw}


def test_evaluator_latency_miss_false_alarm_and_wrong_action():
    truth = [_span("ps_crash", 20, 60),
             _span("straggler", 120, 160, slot=1)]
    history = [
        ("detection", {"step": 30, "bottleneck": True,
                       "action": "enable_compression"}),      # latency 10
        ("detection", {"step": 90, "bottleneck": True,
                       "action": "add_parameter_server"}),    # false alarm
        ("detection", {"step": 50, "bottleneck": False}),     # not counted
        ("mitigation", {"action": "enable_compression"}),
    ]
    s = score_history(history, truth)
    assert s["detections"] == 2
    assert s["detection_latency_steps"] == 10
    assert s["missed_detections"] == 1          # straggler span never hit
    assert s["false_alarms"] == 1
    assert s["wrong_actions"] == 0              # compression fits ps_crash
    assert s["actions_applied"] == ["enable_compression"]
    # a PS lever pulled while only the straggler span covers the step
    wrong = score_history(
        [("detection", {"step": 130, "bottleneck": True,
                        "action": "enable_compression"})], truth)
    assert wrong["wrong_actions"] == 1 and wrong["wrong_action_rate"] == 1.0


def test_evaluator_grace_forgives_post_span_decay():
    truth = [_span("straggler", 20, 50, slot=0)]
    late = [("detection", {"step": 55, "bottleneck": True,
                           "action": "replace_worker"})]
    strict = score_history(late, truth, grace=0)
    lenient = score_history(late, truth, grace=10)
    assert strict["false_alarms"] == 1 and strict["missed_detections"] == 1
    assert lenient["false_alarms"] == 0 and lenient["missed_detections"] == 0


def test_evaluator_counts_checkpoint_failures_inside_outage():
    truth = [_span("ckpt_outage", 20, 45)]
    history = [("checkpoint_failed", {"step": s, "failures": i + 1})
               for i, s in enumerate((25, 30, 35, 40, 45))]
    history.append(("checkpoint_failed", {"step": 90, "failures": 6}))
    s = score_history(history, truth)
    assert s["spans"][0]["checkpoint_failures"] == 5
    assert s["checkpoint_failures"] == 6        # global count keeps all
    assert s["missed_detections"] == 0          # outages aren't detectable


# ------------------------------------------------- live runs, end to end
def test_live_ps_crash_walks_the_compression_ladder(session):
    """The headline loop: a silent PS slowdown detected from measurement
    alone, attributed to the PS, mitigated by walking none -> int8 ->
    topk, after which the payload shrink restores full speed."""
    card = run_scenario(get_scenario("ps_crash"), session=session,
                        samples=4, smoke=True)
    live = card["live"]
    assert card["smoke"]["passed"], card["smoke"]["failures"]
    assert live["actions_applied"] == ["enable_compression",
                                       "enable_compression"]
    assert live["final_compression"] == "topk"
    assert live["missed_detections"] == 0
    assert live["false_alarms"] == 0
    assert live["detection_latency_steps"] == 0
    assert live["faults"] == [{"fault": "ps_crash", "step": 20,
                               "capacity_factor": 0.1}]


def test_live_straggler_is_not_blamed_on_the_ps(session):
    card = run_scenario(get_scenario("straggler"), session=session,
                        samples=4, smoke=True)
    live = card["live"]
    assert card["smoke"]["passed"], card["smoke"]["failures"]
    assert live["actions_applied"] == []        # no PS lever fits
    assert live["wrong_actions"] == 0
    assert live["missed_detections"] == 0
    assert live["final_compression"] == "none"


def test_live_ckpt_outage_fails_saves_and_stays_quiet(session):
    card = run_scenario(get_scenario("ckpt_outage"), session=session,
                        samples=4, smoke=True)
    live = card["live"]
    assert card["smoke"]["passed"], card["smoke"]["failures"]
    assert live["checkpoint_failures"] == 5     # every save in 20..45
    assert live["false_alarms"] == 0            # invisible to the profiler
    assert {"fault": "ckpt_outage", "step": 20} in live["faults"]
    assert {"fault": "ckpt_recover", "step": 45} in live["faults"]


def test_inject_fault_rejects_unknown_kind():
    import tempfile

    from repro.configs import RunConfig, get_config
    from repro.core.trainer import TransientTrainer
    from repro.data.pipeline import ShardedLoader, SyntheticTokenSource

    cfg = get_config("qwen3-1.7b", smoke=True)
    run = RunConfig(total_steps=4, warmup_steps=1, checkpoint_interval=0,
                    checkpoint_dir=tempfile.mkdtemp(), lr=1e-3, zero1=False)
    tr = TransientTrainer(cfg, run, ShardedLoader(
        SyntheticTokenSource(cfg.vocab_size, 24), 8))
    with pytest.raises(ValueError, match="unknown fault kind"):
        tr.inject_fault("gamma_ray")
    tr.inject_fault("ckpt_outage", step=3)
    assert tr.ckpt_outage and tr.faults == [{"fault": "ckpt_outage",
                                             "step": 3}]
    tr.inject_fault("ckpt_recover", step=4)
    assert not tr.ckpt_outage


def test_scorecard_is_deterministic(session):
    a = run_scenario(get_scenario("ps_crash"), session=session,
                     samples=4, seed=0, smoke=True)
    b = run_scenario(get_scenario("ps_crash"), session=session,
                     samples=4, seed=0, smoke=True)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
