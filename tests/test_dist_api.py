"""Unit coverage for the rebuilt `repro.dist` layer: tree_shardings
round-trip on a host-device mesh, elastic batch rebalance, gradient
compression schemes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as sh
from repro.dist.compression import ErrorFeedback, compression_ratio
from repro.dist.elastic import ElasticMembership, Member, split_batch


# ------------------------------------------------------------------ sharding
def test_tree_shardings_roundtrip():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    axes = {"wq": ("embed", "heads", None), "scale": ("embed",),
            "tok": ("batch", "seq")}
    specs = {"wq": jax.ShapeDtypeStruct((8, 4, 2), jnp.float32),
             "scale": jax.ShapeDtypeStruct((8,), jnp.float32),
             "tok": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    shardings = sh.tree_shardings(mesh, axes, sh.MEGATRON_RULES, specs)
    assert set(shardings) == {"wq", "scale", "tok"}
    assert all(isinstance(s, jax.sharding.NamedSharding)
               for s in shardings.values())
    assert shardings["wq"].spec == P(None, "model", None)
    assert shardings["tok"].spec == P("data", None)
    # the shardings place actual arrays (round-trip through device_put)
    x = jax.device_put(jnp.zeros((8, 4, 2)), shardings["wq"])
    assert x.shape == (8, 4, 2)


def test_rule_sets_registry_consistent():
    assert set(sh.RULE_SETS) == {"megatron", "decode", "ep", "dp", "dpep",
                                 "fsdp"}
    for rules in sh.RULE_SETS.values():
        for v in rules.values():
            assert v is None or isinstance(v, (str, tuple))


def test_constrain_identity_outside_context():
    x = jnp.ones((4, 8))
    assert sh.constrain(x, "batch", "embed") is x


def test_spec_with_shape_applies_divisibility():
    am = sh.abstract_mesh((4, 2), ("data", "model"))
    assert sh.spec(("batch", "heads"), sh.MEGATRON_RULES, am,
                   shape=(6, 4)) == P(None, "model")


# ------------------------------------------------------------------- elastic
def test_split_batch_remainder_goes_first():
    assert split_batch(10, [7, 3, 5]) == {7: 4, 3: 3, 5: 3}
    assert split_batch(6, []) == {}


def test_membership_epoch_sequence():
    m = ElasticMembership([Member(0), Member(1), Member(2)], global_batch=10)
    e0 = m.current_epoch()
    assert e0.number == 0 and sum(e0.batch_of.values()) == 10
    e1 = m.revoke(1)
    assert e1.number == 1 and sorted(e1.batch_of.values()) == [5, 5]
    e2 = m.join(Member(9, gpu="k80"))
    assert e2.number == 2 and sum(e2.batch_of.values()) == 10
    assert {mm.id for mm in e2.members} == {0, 2, 9}
    with pytest.raises(KeyError):
        m.revoke(1)          # already gone
    with pytest.raises(KeyError):
        m.join(Member(9))    # already present
    assert 0 in m and 1 not in m  # __contains__ (trainer staleness guard)


# --------------------------------------------------------------- compression
@pytest.mark.parametrize("scheme,max_rel_err", [("none", 0.0),
                                                ("bf16", 0.01),
                                                ("int8", 0.02)])
def test_compression_schemes_bounded_error(scheme, max_rel_err):
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=256), jnp.float32)}
    ef = ErrorFeedback(scheme)
    res = ef.init(g)
    d, new_res = ef.roundtrip(g, res)
    err = float(jnp.linalg.norm(d["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert err <= max_rel_err
    # residual + applied reconstructs the corrected gradient exactly
    np.testing.assert_allclose(np.asarray(d["w"] + new_res["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)


def test_compression_ratio_and_unknown_scheme():
    assert compression_ratio("none") == 1.0
    assert compression_ratio("int8") == 0.25
    with pytest.raises(ValueError):
        ErrorFeedback("fp4")
