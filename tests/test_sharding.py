"""Sharding-rule resolution + divisibility fallback properties, and an
in-process mini dry-run on a small forced-host-device mesh (subprocess)."""
import json
import os
import subprocess
import sys

import pytest
from hypothesis import given, settings, strategies as st

import jax
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as sh

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def mesh1():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_logical_spec_resolution():
    m = mesh1()
    spec = sh.logical_spec(("batch", "seq", "heads"), sh.MEGATRON_RULES, m)
    assert spec == P(("data",), None, "model")


def test_unknown_names_replicate():
    m = mesh1()
    assert sh.logical_spec(("nope", None), sh.MEGATRON_RULES, m) == P(None, None)


def test_duplicate_axis_not_reused():
    m = mesh1()
    spec = sh.logical_spec(("heads", "ff"), sh.MEGATRON_RULES, m)
    # both map to "model"; second must drop to None
    assert spec == P("model", None)


@given(st.integers(1, 64), st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_divisible_spec_property(dim0, dim1):
    m = jax.make_mesh((1, 1), ("data", "model"))
    spec = sh.divisible_spec(m, P("data", "model"), (dim0, dim1))
    # with 1-sized axes everything divides
    assert spec == P("data", "model")


def test_divisible_spec_drops_indivisible():
    # fake a 4x2 mesh via abstract mesh sizes using the real 1-device mesh is
    # impossible; emulate with AbstractMesh (sh.abstract_mesh papers over the
    # constructor-signature change across jax releases)
    am = sh.abstract_mesh((4, 2), ("data", "model"))
    spec = sh.divisible_spec(am, P("data", "model"), (6, 4))
    assert spec == P(None, "model")  # 6 % 4 != 0 -> drop data; 4 % 2 == 0
    spec2 = sh.divisible_spec(am, P(("data", "model"),), (8,))
    assert spec2 == P(("data", "model"))
    spec3 = sh.divisible_spec(am, P(("data", "model"),), (4,))
    assert spec3 == P("data")


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """Lower+compile the smoke config on an 8-device host mesh — the same
    code path as the production dry-run, in miniature."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, os.path.join(%r, "src"))
import jax, jax.numpy as jnp
from repro.configs import get_config, RunConfig, SHAPES
from repro.dist import sharding as sh
from repro.launch import steps as st
from repro.models import api

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("qwen3-1.7b", smoke=True)
run = RunConfig(zero1=True)
step, _ = st.make_train_step(cfg, run)
with sh.use_sharding(mesh, sh.MEGATRON_RULES):
    state_specs = st.train_state_specs(cfg, run)
    state_sh = st.train_state_shardings(mesh, cfg, run)
    import jax as j
    b_specs = {"tokens": j.ShapeDtypeStruct((8, 64), jnp.int32),
               "labels": j.ShapeDtypeStruct((8, 64), jnp.int32)}
    b_sh = sh.tree_shardings(mesh, {"tokens": ("batch", "seq"),
                                    "labels": ("batch", "seq")},
                             sh.MEGATRON_RULES, b_specs)
    lowered = jax.jit(step, in_shardings=(state_sh, b_sh)).lower(
        state_specs, b_specs)
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):  # jax<0.5 returns a per-program list
        ca = ca[0] if ca else {}
    print(json.dumps({"ok": True, "flops": float(ca.get("flops", 0))}))
""" % ROOT
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["flops"] > 0
