"""Launch-planner (§V-C future work) tests."""
import numpy as np
import pytest

from repro.core.scheduler import (LaunchPlan, expected_revocations_mc,
                                  plan_launch)
from repro.core.transient.revocation import REGION_GPU_PARAMS


def test_regions_enumerated_per_gpu():
    best, plans = plan_launch("v100", 2, 10.0, n_w=50_000, i_c=4000,
                              t_c=2.0, hours=[0, 12])
    regions = {p.region for p in plans}
    expected = {r for (r, g) in REGION_GPU_PARAMS if g == "v100"}
    assert regions == expected
    assert isinstance(best, LaunchPlan)
    assert best.expected_cost == min(p.expected_cost for p in plans)


def test_lower_revocation_region_wins_for_k80():
    """us-west1 K80s are by far the most stable (Table V: 22.9% vs 66.7%
    in europe-west1) — the planner must prefer it over europe-west1."""
    best, plans = plan_launch("k80", 4, 4.56, n_w=400_000, i_c=4000, t_c=3.84,
                              hours=[0, 6, 12, 18])
    by_region = {}
    for p in plans:
        by_region.setdefault(p.region, []).append(p.expected_cost)
    assert min(by_region["us-west1"]) < min(by_region["europe-west1"])


def test_expected_revocations_monotone_in_duration():
    short = expected_revocations_mc("us-central1", "v100", 0.0, 1.0, 4)
    long_ = expected_revocations_mc("us-central1", "v100", 0.0, 20.0, 4)
    assert long_ >= short


def test_v100_quiet_window_affects_short_runs():
    """Launching a ~3h V100 run at 4PM (quiet window 4-8PM) should see
    fewer revocations than launching into the morning peak."""
    quiet = expected_revocations_mc("us-central1", "v100", 16.0, 3.0, 8,
                                    samples=400, seed=1)
    peak = expected_revocations_mc("us-central1", "v100", 7.0, 3.0, 8,
                                   samples=400, seed=1)
    assert quiet <= peak
