"""Pallas kernel validation: sweep shapes/dtypes in interpret mode and
assert_allclose against the pure-jnp oracles in kernels/ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("B,Sq,Sk,H,KV,hd,causal", [
    (1, 128, 128, 4, 4, 64, True),     # MHA causal
    (2, 128, 128, 4, 2, 32, True),     # GQA
    (1, 256, 256, 2, 1, 64, True),     # MQA longer
    (1, 128, 128, 4, 4, 64, False),    # bidirectional
])
def test_flash_attention_fwd(B, Sq, Sk, H, KV, hd, causal, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Sq, H, hd), dtype)
    k = _rand(ks[1], (B, Sk, KV, hd), dtype)
    v = _rand(ks[2], (B, Sk, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal, 64, 64)
    want = ref.flash_attention_ref(q, k, v, causal)
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,KV,hd", [(1, 128, 4, 2, 32), (2, 128, 2, 2, 64)])
def test_flash_attention_grads(B, S, H, KV, hd):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, KV, hd), jnp.float32)
    v = _rand(ks[2], (B, S, KV, hd), jnp.float32)
    w = jnp.cos(jnp.arange(hd))

    def f_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True, 64, 64) * w)

    def f_ref(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v, True) * w)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 5e-4)])
@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 128, 2, 32, 1, 16, 32),
    (2, 128, 4, 32, 2, 16, 64),     # grouped B/C
    (1, 256, 2, 64, 1, 32, 128),
])
def test_ssd_scan(b, s, h, p, g, n, chunk, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = _rand(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(_rand(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(_rand(ks[2], (h,), jnp.float32) * 0.5)
    B = _rand(ks[3], (b, s, g, n), dtype)
    C = _rand(ks[4], (b, s, g, n), dtype)
    y = ops.ssd_scan(x, dt, A, B, C, chunk)
    want = ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(want) / scale, atol=tol)


def test_ssd_matches_decode_recurrence():
    """Chunked SSD == step-by-step recurrence (the serve-path invariant)."""
    from repro.models.ssm import ssd, ssd_decode_step
    b, s, h, p, n = 1, 32, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y_chunk = ssd(x, dt, A, B, C, chunk=8)
    state = jnp.zeros((b, h, n, p))
    outs = []
    for t in range(s):
        state, yt = ssd_decode_step(state, x[:, t], dt[:, t], A, B[:, t],
                                    C[:, t])
        outs.append(yt)
    y_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(y_chunk, y_step, atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("shape", [(4, 128), (2, 7, 256), (3, 33, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = _rand(ks[0], shape, dtype)
    scale = _rand(ks[1], shape[-1:], jnp.float32)
    y = ops.rmsnorm(x, scale)
    want = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(y.astype(np.float32), want.astype(np.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_rmsnorm_grad_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 64))
    s = jnp.ones((64,))
    g1 = jax.grad(lambda xx: jnp.sum(jnp.sin(ops.rmsnorm(xx, s))))(x)
    g2 = jax.grad(lambda xx: jnp.sum(jnp.sin(ref.rmsnorm_ref(xx, s))))(x)
    np.testing.assert_allclose(g1, g2, atol=1e-5)
