"""Data pipeline determinism/resumability + elastic membership invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import (CIFARLikeSource, ShardedLoader,
                                 SyntheticTokenSource)
from repro.dist.elastic import ElasticMembership, Member


def test_token_source_deterministic():
    s = SyntheticTokenSource(1000, 16, seed=3)
    a = s.batch(5, 0, 4, 8)
    b = s.batch(5, 0, 4, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch(6, 0, 4, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_shards_disjoint_streams():
    s = SyntheticTokenSource(1000, 16, seed=3)
    a = s.batch(5, 0, 4, 8)
    b = s.batch(5, 1, 4, 8)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_loader_resume_identical():
    s = SyntheticTokenSource(1000, 16)
    l1 = ShardedLoader(s, global_batch=8)
    for _ in range(3):
        l1.next_global(2)
    state = l1.state()
    want = l1.next_global(2)
    l2 = ShardedLoader.from_state(s, state)
    got = l2.next_global(2)
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_labels_in_range():
    s = CIFARLikeSource()
    b = s.batch(0, 0, 1, 32)
    assert b["labels"].min() >= 0 and b["labels"].max() < 10
    assert b["images"].shape == (32, 32, 32, 3)


# -------------------------------------------------------- elastic membership
@given(st.integers(2, 8), st.integers(1, 7))
@settings(max_examples=30, deadline=None)
def test_batch_resplit_conserves_global(n_members, n_revoke):
    n_revoke = min(n_revoke, n_members - 1)
    m = ElasticMembership([Member(i) for i in range(n_members)],
                          global_batch=256)
    for i in range(n_revoke):
        epoch = m.revoke(i)
    assert sum(epoch.batch_of.values()) == 256
    assert len(epoch.members) == n_members - n_revoke


def test_join_rolls_epoch_and_restores_capacity():
    m = ElasticMembership([Member(0), Member(1)], global_batch=64)
    e1 = m.revoke(1)
    assert e1.batch_of[0] == 64
    e2 = m.join(Member(2))
    assert sum(e2.batch_of.values()) == 64
    assert len(e2.members) == 2
    assert m.epoch_no == 2


def test_revoking_all_members_yields_empty_epoch():
    m = ElasticMembership([Member(0)], global_batch=8)
    e = m.revoke(0)
    assert e.members == ()
