"""Docs-tree gate: the architecture/provider docs exist with the sections
code cites, every intra-repo markdown link resolves, and the README
quickstart snippets are present and well-formed (CI's docs job executes
them; see scripts/check_docs.py)."""
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "scripts"))

import check_docs  # noqa: E402


def test_design_doc_exists_with_cited_sections():
    text = (ROOT / "docs" / "DESIGN.md").read_text()
    # sections the source tree cites (fleet.py §2, dryrun §4, providers §5)
    for section in ("## §1", "## §2", "## §3", "## §4", "## §5"):
        assert section in text, f"DESIGN.md missing {section}"
    assert "measure" in text.lower() and "mitigate" in text.lower()
    assert "Eq (4)" in text and "Eq (5)" in text


def test_providers_doc_covers_adapters_and_guide():
    text = (ROOT / "docs" / "providers.md").read_text()
    for needle in ("FleetProvider", "GCPPreemptible", "AWSSpot",
                   "AzureLowPriority", "register_provider",
                   "Adding a provider"):
        assert needle in text, f"providers.md missing {needle!r}"


def test_readme_documents_every_subcommand_and_provider_flag():
    text = (ROOT / "README.md").read_text()
    for cmd in ("train", "serve", "plan", "simulate", "predict", "bench",
                "dryrun"):
        assert f"python -m repro {cmd}" in text, f"README missing {cmd}"
    assert "--provider" in text
    assert "docs/DESIGN.md" in text and "docs/providers.md" in text


def test_intra_repo_markdown_links_resolve():
    assert check_docs.check_links() == []


def test_design_section_citations_resolve():
    assert check_docs.check_section_citations() == []


def test_readme_quickstart_snippets_extracted():
    snippets = check_docs.readme_snippets()
    assert len(snippets) >= 3
    assert "Session.from_arch" in snippets[0] and ".plan(" in snippets[0]
    assert any("provider" in s for s in snippets)


@pytest.mark.slow
def test_readme_snippets_execute():
    """Full doctest-style run of the README (CI docs job equivalent)."""
    assert check_docs.exec_snippets() == []
