"""Beyond-paper optimization knobs: master-weights (bf16 grads / fp32
master), ZeRO-1 sharding derivation, DP/EP rule-sets, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, TRAIN_4K, get_config
from repro.dist import sharding as sh
from repro.launch import steps as st
from repro.models import api
from repro.optim import adamw, cosine_warmup, make_optimizer


def test_master_weights_training_converges():
    cfg = get_config("qwen3-1.7b", smoke=True)
    batch = api.make_batch(cfg, TRAIN_4K, batch_override=2, seq_override=32)
    losses = {}
    for mw in (False, True):
        run = RunConfig(lr=2e-3, warmup_steps=1, total_steps=10,
                        zero1=False, master_weights=mw)
        step, opt = st.make_train_step(cfg, run)
        state = st.init_train_state(cfg, run, jax.random.PRNGKey(0))
        if mw:
            assert all(p.dtype == jnp.bfloat16
                       for p in jax.tree.leaves(state.params))
            assert "w32" in state.opt
        jit = jax.jit(step)
        ls = []
        for _ in range(6):
            state, m = jit(state, batch)
            ls.append(float(m["loss"]))
        losses[mw] = ls
    # both converge, and to similar loss (master copy preserves accuracy)
    assert losses[False][-1] < losses[False][0]
    assert losses[True][-1] < losses[True][0]
    assert abs(losses[True][-1] - losses[False][-1]) < 0.15


def test_master_weights_bits_match_fp32_updates():
    """fp32 master evolves identically to plain fp32 adam (same grads)."""
    p32 = {"w": jnp.ones((8,), jnp.float32) * 0.5}
    pbf = jax.tree.map(lambda x: x.astype(jnp.bfloat16), p32)
    opt32 = adamw(0.1, master=False)
    optm = adamw(0.1, master=True)
    s32, sm = opt32.init(p32), optm.init(pbf)
    g = {"w": jnp.full((8,), 0.3, jnp.float32)}
    gb = jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)
    step = jnp.zeros((), jnp.int32)
    p32n, s32n = opt32.update(g, s32, p32, step)
    pbfn, smn = optm.update(gb, sm, pbf, step)
    np.testing.assert_allclose(np.asarray(smn["w32"]["w"]),
                               np.asarray(p32n["w"]), rtol=1e-2)
    assert pbfn["w"].dtype == jnp.bfloat16


@pytest.mark.parametrize("rules_name,rules", [
    ("dp", sh.DP_RULES), ("ep", sh.EP_RULES), ("dpep", sh.DPEP_RULES),
    ("fsdp", sh.FSDP_RULES)])
def test_rule_variants_resolve(rules_name, rules):
    m = jax.make_mesh((1, 1), ("data", "model"))
    spec = sh.logical_spec(("batch", "seq", "embed"), rules, m)
    assert spec is not None
    if rules_name == "dp":
        assert spec[0] == ("data", "model")


def test_moe_forward_same_under_rules():
    """MoE math is layout-independent: same outputs under any rule-set
    (single-device mesh makes all constraints no-ops, but the constrain
    calls must at least resolve for every rule-set)."""
    cfg = get_config("granite-moe-3b-a800m", smoke=True).with_(dtype="float32")
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    batch = api.make_batch(cfg, TRAIN_4K, batch_override=2, seq_override=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    outs = []
    for rules in (sh.MEGATRON_RULES, sh.DP_RULES, sh.EP_RULES, sh.DPEP_RULES):
        with sh.use_sharding(mesh, rules):
            outs.append(api.prefill(params, cfg, batch))
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5)


def test_cosine_warmup_shape():
    fn = cosine_warmup(1.0, 10, 100)
    assert float(fn(jnp.asarray(0))) < 0.2
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, abs=0.05)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, abs=0.02)


def test_zero1_shards_opt_state():
    cfg = get_config("qwen3-1.7b", smoke=True)
    run = RunConfig(zero1=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ps = st.param_shardings(mesh, cfg)
    os_ = st.opt_shardings(mesh, cfg, run, ps)
    assert set(os_.keys()) == {"m", "v"}
    # every m-leaf sharding has "data" somewhere (zero1) when divisible
    n_data = sum(1 for s in jax.tree.leaves(os_["m"])
                 if "data" in str(s.spec))
    assert n_data > 0
