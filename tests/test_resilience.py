"""Recovery-layer tests (docs/resilience.md, DESIGN.md §8): backoff
properties, keyed stall draws, quorum tiers, `call_with_retries`
semantics, checkpoint integrity fallback and the writer-lease
kill-holder-mid-save regression."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpointer import (CheckpointCorruptError,
                                           Checkpointer, LeaseLostError)
from repro.resilience import (DegradationPolicy, ResilienceConfig,
                              RetryExhausted, RetryPolicy,
                              call_with_retries, stall_from_uniforms,
                              stall_pool)
from repro.resilience.policy import live_jitter_uniforms


# ------------------------------------------------------------- RetryPolicy
@given(attempt=st.integers(1, 16), u=st.floats(0.0, 1.0),
       base=st.floats(0.01, 10.0), mult=st.floats(1.0, 4.0),
       jitter=st.floats(0.0, 1.0))
@settings(max_examples=64, deadline=None)
def test_backoff_bounded_and_positive(attempt, u, base, mult, jitter):
    p = RetryPolicy(base_delay_s=base, multiplier=mult, max_delay_s=60.0,
                    jitter=jitter)
    d = p.backoff(attempt, u)
    assert 0.0 <= d <= p.max_delay_s * (1.0 + p.jitter)
    # jitter is symmetric around the deterministic schedule
    mid = min(p.max_delay_s, base * mult ** (attempt - 1))
    assert abs(d - mid) <= jitter * mid + 1e-12


def test_backoff_monotone_before_cap_and_deterministic():
    p = RetryPolicy(base_delay_s=0.5, multiplier=2.0, max_delay_s=8.0,
                    jitter=0.25)
    mids = [p.backoff(a, 0.5) for a in range(1, 8)]
    assert mids == sorted(mids)          # u=0.5 → no jitter → monotone
    assert mids[-1] == p.max_delay_s     # and capped
    assert p.backoff(3, 0.77) == p.backoff(3, 0.77)


# ------------------------------------------------------------ stall draws
@given(fail_p=st.floats(0.0, 1.0), seed=st.integers(0, 500))
@settings(max_examples=32, deadline=None)
def test_stall_within_deadline(fail_p, seed):
    retry = RetryPolicy(max_attempts=5, base_delay_s=1.0, max_delay_s=30.0,
                        jitter=0.5, deadline_s=40.0)
    u = np.random.default_rng(seed).random((7, 3, 10))
    s = stall_from_uniforms(retry, fail_p, u)
    assert s.shape == (7, 3)
    assert (s >= 0.0).all() and (s <= retry.deadline_s).all()


def test_stall_edge_probabilities():
    retry = RetryPolicy(max_attempts=4, base_delay_s=2.0, multiplier=2.0,
                        max_delay_s=100.0, jitter=0.0, deadline_s=1e9)
    u = np.random.default_rng(0).random((5, 8))
    # fail_p=0: no attempt ever fails, stall is exactly zero
    assert (stall_from_uniforms(retry, 0.0, u) == 0.0).all()
    # fail_p=1: every attempt fails — with zero jitter the stall is the
    # full deterministic schedule 2+4+8+16
    np.testing.assert_allclose(stall_from_uniforms(retry, 1.0, u), 30.0)


def test_stall_pool_rows_stable_across_ensemble_width():
    """Trajectory j's stall row must not depend on how many trajectories
    were drawn alongside it — the FleetDraws prefix contract."""
    res = ResilienceConfig(restore_fail_p=0.7, seed=5)
    small = stall_pool(res, sim_seed=3, n=4, slots=8, gen=1)
    large = stall_pool(res, sim_seed=3, n=16, slots=8, gen=1)
    np.testing.assert_array_equal(small, large[:4])
    # distinct generations draw from distinct keyed streams
    other = stall_pool(res, sim_seed=3, n=4, slots=8, gen=2)
    assert not np.array_equal(small, other)


# ------------------------------------------------------------ quorum tiers
def test_degradation_tiers_and_boundaries():
    d = DegradationPolicy(quorum=0.5, shrink_below=0.75, shrink_factor=0.6)
    assert d.tier(1, 4) == "pause"            # 0.25 < 0.5
    assert d.tier(2, 4) == "shrink_batch"     # 0.5 is NOT below quorum
    assert d.tier(3, 4) == "continue"         # 0.75 is NOT below shrink
    assert d.speed_factor(2, 4) == 0.6
    assert d.speed_factor(1, 4) == 0.0
    # the defaults never degrade — ResilienceConfig() preserves behavior
    assert DegradationPolicy().tier(0, 4) == "continue"
    assert DegradationPolicy().speed_factor(1, 1000) == 1.0


# -------------------------------------------------------- call_with_retries
def _no_sleep(_dt):
    pass


def test_retries_recover_and_report_attempts():
    calls = []
    events = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise IOError("transient")
        return "ok"

    out, attempts = call_with_retries(
        flaky, RetryPolicy(max_attempts=4), op="save", sleep=_no_sleep,
        emit=lambda k, p: events.append((k, p)))
    assert (out, attempts) == ("ok", 3)
    assert [p["outcome"] for _, p in events] == ["fail", "fail", "ok"]
    assert all(k == "retry" and p["op"] == "save" for k, p in events)


def test_retries_exhaust_with_ledger():
    events = []

    def always():
        raise IOError("down")

    with pytest.raises(RetryExhausted) as ei:
        call_with_retries(always, RetryPolicy(max_attempts=3), op="save",
                          sleep=_no_sleep,
                          emit=lambda k, p: events.append(p))
    assert ei.value.attempts == 3
    assert isinstance(ei.value.last, IOError)
    # the ledger the chaos gate checks: exactly one gave_up record
    assert [p["outcome"] for p in events] == ["fail", "fail", "gave_up"]
    assert events[-1]["backoff_s"] == 0.0    # no sleep after giving up


def test_non_transient_errors_propagate_unretried():
    calls = []

    def broken():
        calls.append(1)
        raise KeyError("not transient")

    with pytest.raises(KeyError):
        call_with_retries(broken, RetryPolicy(max_attempts=4),
                          sleep=_no_sleep, retry_on=(IOError,))
    assert len(calls) == 1


def test_sleep_total_never_exceeds_deadline():
    slept = []

    def always():
        raise IOError("down")

    policy = RetryPolicy(max_attempts=10, base_delay_s=4.0, multiplier=3.0,
                         max_delay_s=50.0, jitter=0.25, deadline_s=20.0)
    with pytest.raises(RetryExhausted):
        call_with_retries(always, policy, sleep=slept.append)
    assert sum(slept) <= policy.deadline_s + 1e-9


def test_retry_delays_deterministic_per_seed_and_key():
    a = live_jitter_uniforms(RetryPolicy(), seed=7, key=11)
    b = live_jitter_uniforms(RetryPolicy(), seed=7, key=11)
    c = live_jitter_uniforms(RetryPolicy(), seed=7, key=12)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    # the trainer tags its restore stream key=-1 — negative keys must
    # wrap, not crash (SeedSequence entropy is non-negative)
    np.testing.assert_array_equal(
        live_jitter_uniforms(RetryPolicy(), seed=7, key=-1),
        live_jitter_uniforms(RetryPolicy(), seed=7, key=2 ** 32 - 1))


# --------------------------------------------------- checkpoint integrity
def _tree(step: float):
    return {"w": jnp.full((4, 3), step, jnp.float32),
            "opt": {"mu": jnp.arange(6, dtype=jnp.float32) + step}}


def _save_steps(root, steps, holder="w0"):
    ck = Checkpointer(root, holder=holder, keep=10)
    for s in steps:
        ck.save(s, _tree(float(s)))
    return ck


def test_restore_latest_valid_falls_back_past_corruption(tmp_path):
    ck = _save_steps(str(tmp_path), [5, 10, 15])
    ck.corrupt(15)
    skipped = []
    tree, step, depth = ck.restore_latest_valid(
        _tree(0.0), on_fallback=lambda s, e: skipped.append(s))
    assert (step, depth, skipped) == (10, 1, [15])
    np.testing.assert_allclose(tree["w"], 10.0)
    with pytest.raises(CheckpointCorruptError):
        ck.validate(15)
    ck.validate(10)                      # untouched generation stays clean


def test_restore_fails_loudly_when_every_generation_is_bad(tmp_path):
    ck = _save_steps(str(tmp_path), [5, 10])
    ck.corrupt(5)
    ck.corrupt(10)
    with pytest.raises(CheckpointCorruptError, match="every committed"):
        ck.restore_latest_valid(_tree(0.0))


def test_validate_catches_torn_payload(tmp_path):
    ck = _save_steps(str(tmp_path), [3])
    data = os.path.join(str(tmp_path), "step_3", "data-00000.bin")
    with open(data, "r+b") as f:          # truncate: a torn write
        f.truncate(8)
    with pytest.raises(CheckpointCorruptError, match="torn|checksum"):
        ck.validate(3)


def test_all_steps_ignores_stray_entries_and_stale_latest(tmp_path):
    ck = _save_steps(str(tmp_path), [5, 10])
    root = str(tmp_path)
    open(os.path.join(root, "step_backup"), "w").write("x")     # file
    os.makedirs(os.path.join(root, ".tmp_step_99"))             # tmp dir
    os.makedirs(os.path.join(root, "step_12x"))                 # bad name
    assert ck.all_steps() == [5, 10]
    # a LATEST pointing at a GC'd step falls through to the newest dir
    with open(os.path.join(root, "LATEST"), "w") as f:
        f.write("999")
    assert ck.latest_step() == 10
    _tree_out, step, depth = ck.restore_latest_valid(_tree(0.0))
    assert (step, depth) == (10, 0)


# ------------------------------------------------------------ writer lease
def test_lease_steal_after_expiry_uses_injected_clock(tmp_path):
    clock = [0.0]
    a = Checkpointer(str(tmp_path), holder="a", clock=lambda: clock[0])
    b = Checkpointer(str(tmp_path), holder="b", clock=lambda: clock[0])
    assert a.lease.try_acquire()
    assert not b.lease.try_acquire()     # live lease: steal refused
    clock[0] = a.lease.ttl + 1.0
    assert b.lease.try_acquire()         # expired: steal succeeds
    assert not a.lease.held_by_me()


def test_kill_holder_mid_save_aborts_commit(tmp_path):
    """Regression: the holder is revoked after starting a save and a
    survivor steals the lease; the holder's commit must abort before the
    rename so the contested write never becomes visible."""
    root = str(tmp_path)
    a = _save_steps(root, [5], holder="a")
    b = Checkpointer(root, holder="b")
    assert a.lease.held_by_me()
    # revocation lands while a's step-10 save is in flight
    a.lease.notify_revoked()
    assert b.lease.try_acquire()
    flat = {k: np.asarray(v) for k, v in
            (("w", np.ones(3)), ("b", np.zeros(2)))}
    with pytest.raises(LeaseLostError):
        a._write(10, flat, {}, fenced=True)
    assert a.all_steps() == [5]          # nothing torn was published
    assert not os.path.exists(os.path.join(root, ".tmp_step_10"))
    # the survivor can checkpoint immediately — no recompute-from-scratch
    assert b.save(10, _tree(10.0)) is not None
    assert b.all_steps() == [5, 10]
    with open(os.path.join(root, "writer.lease")) as f:
        assert json.load(f)["holder"] == "b"
