"""Ground-truth scoring of the detection/mitigation loop (docs/chaos.md).

`score_history` replays an `EventBus` history (the `(kind, payload)`
tuples a live chaos run recorded) against the scenario's ground-truth
fault spans and scores what the Controller actually did:

* **detection latency** — steps from a fault's start to the first
  bottleneck=True `detection` event inside the span;
* **missed detections** — spans that expect a detection but never got one
  inside `[start, end + grace]` (`grace` forgives the measurement decay
  right after a fault ends: the profiler averages over history, so the
  deviation needs a few checks to wash out);
* **false alarms** — bottleneck detections outside every span+grace;
* **wrong actions** — detections whose recommended action is not in the
  covering span's expected set (a PS lever pulled on a straggler, say);
* **mitigation/checkpoint accounting** — actions applied, checkpoint
  saves failed during outage spans;
* **recovery accounting** — the resilience layer's `retry`,
  `restore_fallback`, `degradation` and `lease_handover` events
  (docs/resilience.md) summarized into the `recovery` block: attempts,
  backoff seconds slept, exhausted retries, fallback restores and
  degradation-tier transitions (all zero when resilience is off).

Spans whose kind has an empty expected-action set (checkpoint outages:
nothing speed-visible to detect) do not count toward detection scoring.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

#: Controller actions that are a correct response to each fault kind.
#: `ps_crash` walks the §VI-B ladder; a straggler should be flagged as an
#: under-performing worker (replacement — not a PS lever); a checkpoint
#: outage is invisible to the speed controller (detections not expected).
EXPECTED_ACTIONS: Dict[str, Tuple[str, ...]] = {
    "ps_crash": ("enable_compression", "add_parameter_server"),
    "straggler": ("replace_worker", "request_replacement"),
    "ckpt_outage": (),
}

#: Fault kinds the speed controller is expected to *detect* at all.
DETECTABLE = ("ps_crash", "straggler")


def score_serving(armed: List, stock: List, baseline: List
                  ) -> Dict[str, object]:
    """Score a serving-fleet chaos run (docs/serving.md).

    `armed`/`stock` are `ServingSimResult` lists from the *faulted*
    ensemble with resilience on/off; `baseline` is the armed fleet with
    no faults (the p99 reference). Returns the `serving.impact` block the
    serve_wave smoke gates read:

    * **armed_dropped_warned** — in-flight requests lost to *warned*
      revocations with resilience armed; the drain+handover contract says
      this is exactly zero.
    * **drop_delta** — stock minus armed mean in-flight drops: what
      arming the gateway saved.
    * **p99_inflation** — armed faulted p99 over armed fault-free p99;
      admission control bounds this (a queued request sheds at its budget
      instead of waiting unboundedly).
    * **recovery_cycles_total** — degraded→full tier transitions summed
      over the armed ensemble (each is one full degrade/recover arc).
    """
    import numpy as np

    def pool_p99(results):
        lat = np.concatenate([r.latencies_s for r in results])
        return float(np.percentile(lat, 99)) if lat.size else float("inf")

    def drop_mean(results):
        return float(np.mean([r.dropped_inflight for r in results]))

    p99_f, p99_b = pool_p99(armed), pool_p99(baseline)
    return {
        "armed_dropped_warned": int(sum(r.dropped_warned for r in armed)),
        "stock_dropped_warned": int(sum(r.dropped_warned for r in stock)),
        "drop_delta": round(drop_mean(stock) - drop_mean(armed), 6),
        "p99_faulted_s": round(p99_f, 6),
        "p99_baseline_s": round(p99_b, 6),
        "p99_inflation": round(p99_f / max(p99_b, 1e-9), 6),
        "recovery_cycles_total": int(sum(r.recovery_cycles
                                         for r in armed)),
        "degraded_events_total": int(sum(len(r.degraded_events)
                                         for r in armed)),
    }


def score_history(history: Iterable[Tuple[str, dict]],
                  truth: List[dict], grace: float = 0.0) -> Dict[str, object]:
    """Score one live run. `history` is `[(kind, payload), ...]` in emit
    order; `truth` is `LivePlan.truth()` output (`start_step`/`end_step`
    spans). Returns a JSON-serializable scorecard fragment."""
    history = list(history)
    detections = [p for k, p in history
                  if k == "detection" and p.get("bottleneck")]
    mitigations = [p for k, p in history if k == "mitigation"]
    ckpt_failed = [p for k, p in history if k == "checkpoint_failed"]
    faults_seen = [p for k, p in history if k == "fault"]
    retry_ev = [p for k, p in history if k == "retry"]
    fallbacks = [p for k, p in history if k == "restore_fallback"]
    degradations = [p for k, p in history if k == "degradation"]
    handovers = [p for k, p in history if k == "lease_handover"]

    def covering(step: float) -> Optional[dict]:
        for span in truth:
            if span["start_step"] <= step <= span["end_step"] + grace:
                return span
        return None

    spans_out: List[dict] = []
    missed = 0
    latencies: List[float] = []
    for span in truth:
        entry = dict(span)
        if span["kind"] in DETECTABLE:
            hits = [d["step"] for d in detections
                    if span["start_step"] <= d["step"]
                    <= span["end_step"] + grace]
            entry["detected"] = bool(hits)
            if hits:
                entry["detection_latency_steps"] = hits[0] - span["start_step"]
                latencies.append(entry["detection_latency_steps"])
            else:
                missed += 1
        if span["kind"] == "ckpt_outage":
            entry["checkpoint_failures"] = sum(
                1 for p in ckpt_failed
                if span["start_step"] <= p["step"] <= span["end_step"])
        spans_out.append(entry)

    false_alarms = sum(1 for d in detections if covering(d["step"]) is None)
    wrong = 0
    judged = 0
    for d in detections:
        span = covering(d["step"])
        expected = EXPECTED_ACTIONS.get(span["kind"]) if span else None
        if not expected:          # uncovered or action-less span kind
            continue
        judged += 1
        if d.get("action") not in expected + ("none",):
            wrong += 1

    return {
        "spans": spans_out,
        "detections": len(detections),
        "missed_detections": missed,
        "false_alarms": false_alarms,
        "detection_latency_steps": (min(latencies) if latencies else None),
        "wrong_actions": wrong,
        "wrong_action_rate": (wrong / judged) if judged else 0.0,
        "actions_applied": [m["action"] for m in mitigations],
        "checkpoint_failures": len(ckpt_failed),
        "faults_injected": len(faults_seen),
        "recovery": {
            "retry_attempts": len(retry_ev),
            "retried": sum(1 for p in retry_ev
                           if p.get("outcome") == "fail"),
            "gave_up": sum(1 for p in retry_ev
                           if p.get("outcome") == "gave_up"),
            "backoff_seconds": round(sum(p.get("backoff_s", 0.0)
                                         for p in retry_ev), 6),
            "restore_fallbacks": len(fallbacks),
            "degradation_tiers": [p.get("tier") for p in degradations],
            "lease_handovers": len(handovers),
        },
    }
