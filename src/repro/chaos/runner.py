"""Chaos scenario runner: sim ensembles + live trainer drive + scorecard.

`run_scenarios` is what `Session.chaos` and `python -m repro chaos` call.
Per scenario it produces one JSON-serializable scorecard:

* **sim** — a faulted vs baseline fleet-simulation ensemble on the
  requested engine (recovery cost in wall-clock, $ and lost steps), a
  batched-vs-event *parity probe* (same `FleetDraws`-keyed fault
  transforms must give identical per-trajectory revocation/replacement
  counts and matching times on both engines), and the ground-truth
  timeline plus a hash of the hazard-transformed lifetime matrix — the
  bit-identical-across-engines contract, pinned.
* **live** (scenarios with a `LivePlan`, unless `live=False`) — the real
  `TransientTrainer` run under a *virtual clock*: a bus subscriber prices
  every step at the truly degraded cluster speed (belief model with the
  PS bandwidth secretly scaled, straggler-scaled workers) while the
  trainer's own capacity model stays healthy — so detection, attribution
  and mitigation happen from measurement alone, deterministically on any
  machine. The bus history is then scored against the plan's ground
  truth (`evaluator.score_history`).

Nothing in the scorecard depends on wall-clock time or temp paths, so a
fixed (scenario, seed, samples) triple reproduces it bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import tempfile
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.chaos.evaluator import score_history
from repro.chaos.scenarios import (Scenario, get_scenario, list_scenarios)
from repro.core.perf_model.cluster_model import (PSBottleneckModel,
                                                 WorkerSpec, cluster_speed)

#: trajectories used for the per-scenario two-engine parity probe
PARITY_SAMPLES = 8


class VirtualClock:
    """Deterministic stand-in for `time.monotonic` in live chaos runs.
    The chaos driver advances it by the modeled duration of each step, so
    profiler speeds — and therefore detection latencies — are a function
    of the scenario alone, not of the machine the test runs on."""

    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _ens_summary(ens) -> Dict[str, float]:
    lost = float(np.mean([r.lost_steps for r in ens.results]))
    return {"time_mean_s": round(ens.stats.time_mean_s, 6),
            "cost_mean": round(ens.stats.cost_mean, 6),
            "revocations_mean": ens.stats.revocations_mean,
            "replacements_mean": ens.stats.replacements_mean,
            "lost_steps_mean": round(lost, 6),
            "finished": ens.stats.finished,
            # recovery cost (zeros unless resilience is armed)
            "paused_s_mean": round(float(np.mean(
                [r.paused_s for r in ens.results])), 6),
            "restore_delay_s_mean": round(float(np.mean(
                [r.restore_delay_s for r in ens.results])), 6)}


def _run_sim(session, sc: Scenario, engine: str, samples: int,
             seed: int) -> Dict[str, object]:
    from repro.core.transient.fleet_batched import FleetDraws

    def build(chaos: bool):
        sim, n_steps = session._fleet_sim(
            n_workers=sc.n_workers, gpu=sc.gpu, region=sc.region,
            steps=sc.total_steps, seed=seed, handover=sc.handover,
            provider=sc.provider)
        if chaos:
            sim.chaos = sc.timeline(sim._roster, seed=seed)
        return sim, n_steps

    sim_f, n_steps = build(chaos=True)
    truth = sim_f.chaos.truth_spans()
    # the shared-draws contract, pinned: the hazard-transformed initial
    # lifetime matrix is a pure function of (scenario, seed) — both
    # engines consume these exact values
    draws = FleetDraws(sim_f, PARITY_SAMPLES, 0.0)
    h = hashlib.sha1(json.dumps(truth, sort_keys=True).encode())
    h.update(np.ascontiguousarray(draws.initial).tobytes())
    truth_hash = h.hexdigest()

    faulted = sim_f.run_many(n_steps, samples, max_hours=sc.max_hours,
                             engine=engine)
    baseline = build(chaos=False)[0].run_many(
        n_steps, samples, max_hours=sc.max_hours, engine=engine)

    # two-engine parity probe on a small slice of the ensemble: the
    # requested engine (falling back to "batched" when the requested one
    # *is* the oracle) vs. the per-trajectory event loop
    probe = engine if engine != "event" else "batched"
    pa = build(chaos=True)[0].run_many(n_steps, PARITY_SAMPLES,
                                       max_hours=sc.max_hours,
                                       engine=probe)
    pb = build(chaos=True)[0].run_many(n_steps, PARITY_SAMPLES,
                                       max_hours=sc.max_hours,
                                       engine="event")
    counts_equal = all(
        a.revocations == b.revocations and a.replacements == b.replacements
        and a.steps_done == b.steps_done
        for a, b in zip(pa.results, pb.results))
    time_err = max(
        abs(a.total_time_s - b.total_time_s) / max(b.total_time_s, 1e-9)
        for a, b in zip(pa.results, pb.results))

    fs, bs = _ens_summary(faulted), _ens_summary(baseline)
    return {
        "engine": engine, "samples": samples,
        "truth": truth, "truth_hash": truth_hash,
        "faulted": fs, "baseline": bs,
        "impact": {
            "extra_time_s": round(fs["time_mean_s"] - bs["time_mean_s"], 6),
            "extra_cost": round(fs["cost_mean"] - bs["cost_mean"], 6),
            "extra_revocations": round(fs["revocations_mean"]
                                       - bs["revocations_mean"], 6),
            "extra_lost_steps": round(fs["lost_steps_mean"]
                                      - bs["lost_steps_mean"], 6),
        },
        "parity": {"trajectories": PARITY_SAMPLES, "engine": probe,
                   "counts_equal": counts_equal,
                   "time_max_rel_err": time_err},
    }


def _serving_summary(results) -> Dict[str, object]:
    from repro.serving import summarize_serving
    return summarize_serving(results)


def _run_serving(session, sc: Scenario, engine: str, samples: int,
                 seed: int) -> Dict[str, object]:
    """Serving-fleet scorecard for scenarios carrying a `ServingScript`.

    Always runs the armed-vs-stock pair on the faulted fleet plus an
    armed fault-free baseline, so the drop-delta and p99-inflation gates
    hold in any CI invocation — arming here means the session's
    ResilienceConfig when one is set, else the defaults."""
    from repro.chaos.evaluator import score_serving
    from repro.resilience import ResilienceConfig
    from repro.serving import ReplicaSet, ServingFleetSim

    spec = sc.serving
    armed_cfg = session.run.resilience or ResilienceConfig()

    def build(chaos: bool, resilience) -> ServingFleetSim:
        rset = ReplicaSet(spec.replicas, sc.provider, region=sc.region,
                          gpu=sc.gpu, seed=seed)
        if chaos:
            rset.chaos = sc.timeline(rset.roster(), seed=seed)
        return ServingFleetSim(
            rset, spec.workload, policy=spec.policy,
            resilience=resilience, token_time_s=spec.token_time_s,
            batch_ceiling=spec.batch_ceiling, horizon_s=spec.horizon_s,
            seed=seed)

    run_engine = engine if engine in ("batched", "event") else "batched"
    armed = build(True, armed_cfg).run_many(samples, engine=run_engine)
    stock = build(True, None).run_many(samples, engine=run_engine)
    baseline = build(False, armed_cfg).run_many(samples, engine=run_engine)

    # two-engine parity probe, same contract as the training sims: the
    # batched candidate-array engine and the per-trajectory event heap
    # must agree on every count and every latency
    probe = "batched" if run_engine == "event" else run_engine
    pa = build(True, armed_cfg).run_many(PARITY_SAMPLES, engine=probe)
    pb = build(True, armed_cfg).run_many(PARITY_SAMPLES, engine="event")
    counts_equal = all(
        (a.completed, a.shed, a.dropped_inflight, a.dropped_warned,
         a.handovers, a.requeues, a.hedges, a.revocations, a.replacements,
         a.recovery_cycles)
        == (b.completed, b.shed, b.dropped_inflight, b.dropped_warned,
            b.handovers, b.requeues, b.hedges, b.revocations,
            b.replacements, b.recovery_cycles)
        for a, b in zip(pa, pb))
    time_err = 0.0
    for a, b in zip(pa, pb):
        if a.latencies_s.shape != b.latencies_s.shape:
            counts_equal = False
            continue
        if a.latencies_s.size:
            time_err = max(time_err, float(np.max(
                np.abs(a.latencies_s - b.latencies_s)
                / np.maximum(b.latencies_s, 1e-9))))
        time_err = max(time_err,
                       abs(a.total_time_s - b.total_time_s)
                       / max(b.total_time_s, 1e-9))

    return {
        "engine": run_engine, "samples": samples,
        "replicas": spec.replicas,
        "armed": _serving_summary(armed),
        "stock": _serving_summary(stock),
        "baseline": _serving_summary(baseline),
        "impact": score_serving(armed, stock, baseline),
        "parity": {"trajectories": PARITY_SAMPLES, "engine": probe,
                   "counts_equal": counts_equal,
                   "time_max_rel_err": time_err},
    }


def _run_live(session, sc: Scenario, seed: int) -> Dict[str, object]:
    """Drive the real trainer through the scenario's `LivePlan`."""
    from repro.api.session import Session

    plan = sc.live
    demand = plan.n_workers * plan.worker_speed
    healthy_cap = plan.ps_capacity_over_demand * demand
    model_bytes = session.model_bytes()
    # n_tensors=0: a pure network-bound PS whose capacity is exactly
    # ps_bw / (2 * bytes), so the sizing below is closed-form
    ps = PSBottleneckModel(model_bytes, 1, ps_bw=2.0 * model_bytes
                           * healthy_cap)
    workers = [WorkerSpec(sc.gpu, plan.worker_speed)
               for _ in range(plan.n_workers)]
    predicted = cluster_speed(workers, ps)

    child = Session(
        session.cfg,
        dataclasses.replace(session.run, total_steps=plan.n_steps,
                            warmup_steps=1, seed=seed,
                            checkpoint_interval=plan.checkpoint_interval,
                            grad_compression="none"),
        arch=session.arch)
    clock = VirtualClock()
    ps_factor = [1.0]
    slot_factor: Dict[int, float] = {}
    fired: set = set()

    def on_step(kind: str, payload: dict) -> None:
        tr = child.trainer
        step = payload["step"]
        for i, f in enumerate(plan.faults):
            if f.step == step and i not in fired:
                fired.add(i)
                if f.kind == "ps_crash":
                    ps_factor[0] = float(f.payload.get("capacity_factor",
                                                       0.5))
                elif f.kind == "ps_recover":
                    ps_factor[0] = 1.0
                elif f.kind == "straggler":
                    slot_factor[int(f.payload["slot"])] = float(
                        f.payload["speed_factor"])
                elif f.kind == "straggler_end":
                    slot_factor.pop(int(f.payload.get("slot", -1)), None)
                tr.inject_fault(f.kind, step=step, **dict(f.payload))
        # reality = the trainer's (healthy, possibly mitigated) belief
        # with the PS bandwidth secretly scaled and stragglers slowed —
        # mitigations the trainer applies (compression, extra PS) are
        # real and genuinely shorten recovery
        real_ps = dataclasses.replace(
            tr.ps_model, ps_bw=tr.ps_model.ps_bw * ps_factor[0])
        specs = [WorkerSpec(w.gpu, w.speed * slot_factor.get(i, 1.0))
                 for i, w in enumerate(workers)]
        sp = cluster_speed(specs, real_ps)
        clock.advance(1.0 / max(sp, 1e-9))

    child.bus.subscribe("step", on_step)
    rep = child.train(plan.n_steps, global_batch=4, seq_len=32,
                      checkpoint_dir=tempfile.mkdtemp(), resume=False,
                      predicted_speed=predicted,
                      check_every=plan.check_every,
                      ps_model=ps, workers=workers, clock=clock)
    history = [(e.kind, e.payload) for e in child.bus.history]
    score = score_history(history, plan.truth(),
                          grace=2 * plan.check_every)
    out = {
        "n_steps": rep.steps_run,
        "virtual_seconds": round(clock.t, 6),
        "predicted_speed": predicted,
        "final_compression": child.trainer.run.grad_compression,
        "final_n_ps": child.trainer.ps_model.n_ps,
        "faults": rep.faults,
        **score,
    }
    if child.run.resilience is not None:
        # recovery scorecard (docs/resilience.md): the trainer's own
        # counters plus a post-run fallback drill — corrupt the newest
        # committed checkpoint and require the validated restore to land
        # on the previous good generation, never on torn state
        out["recovery"] = {**score["recovery"],
                           "retries": rep.retries,
                           "recovered_saves": rep.recovered_saves,
                           "save_failures": rep.checkpoint_failures,
                           "fallback_depth": rep.fallback_depth,
                           "paused_steps": rep.paused_steps,
                           "fallback_drill": _fallback_drill(child.trainer)}
    if child.run.recalibration is not None:
        # drift scorecard (docs/calibration.md): the refit ledger plus the
        # first check *after* the last refit — if the refit worked, that
        # deviation is back inside the controller threshold while the
        # fault is still active
        post_dev = None
        if rep.refits:
            last = rep.refits[-1]["step"]
            after = [p["deviation"] for k, p in history
                     if k == "detection" and p["step"] > last
                     and p.get("deviation") is not None]
            if after:
                post_dev = round(float(after[0]), 6)
        out["recalibration"] = {
            "drift_events": rep.drift_events,
            "refits": rep.refits,
            "model_version": child.trainer.controller.model_version,
            "post_refit_deviation": post_dev,
        }
    return out


def _fallback_drill(trainer) -> Dict[str, object]:
    """Corrupt the newest checkpoint on disk and prove
    `restore_latest_valid` falls back to the previous valid generation
    (the zero-torn-state-loads guarantee, exercised end-to-end)."""
    import jax

    steps = trainer.ckpt.all_steps()
    if len(steps) < 2:
        return {"ok": None, "reason": f"{len(steps)} checkpoint(s) on "
                                      "disk; drill needs 2"}
    trainer.ckpt.corrupt(steps[-1])
    shapes = jax.eval_shape(trainer.init_state, None)
    try:
        _tree, got, depth = trainer.ckpt.restore_latest_valid(shapes)
    except Exception as exc:  # noqa: BLE001 — scored, not raised
        return {"ok": False, "corrupted_step": steps[-1],
                "error": f"{type(exc).__name__}: {exc}"}
    return {"ok": bool(got == steps[-2] and depth >= 1),
            "corrupted_step": steps[-1], "restored_step": got,
            "fallback_depth": depth}


def _check_expectations(sc: Scenario, card: Dict[str, object]) -> List[str]:
    """Evaluate the scenario's smoke gates; returns failure strings."""
    fails: List[str] = []
    exp = sc.expect

    def gate(key, ok, detail):
        if key in exp and not ok(exp[key]):
            fails.append(f"{key}={exp[key]}: {detail}")

    serving = card.get("serving")
    if serving is not None:
        if not serving["parity"]["counts_equal"]:
            fails.append("serving parity: per-trajectory counts differ")
        if serving["parity"]["time_max_rel_err"] > 1e-6:
            fails.append("serving parity: latencies diverge "
                         f"({serving['parity']['time_max_rel_err']:.2e})")
        simp = serving["impact"]
        gate("serving_zero_dropped_warned",
             lambda v: (not v) or simp["armed_dropped_warned"] == 0,
             f"got {simp['armed_dropped_warned']} armed warned drops")
        gate("serving_min_armed_drop_delta",
             lambda v: simp["drop_delta"] >= v,
             f"got {simp['drop_delta']}")
        gate("serving_max_p99_inflation",
             lambda v: simp["p99_inflation"] <= v,
             f"got {simp['p99_inflation']}")
        gate("serving_min_degraded_cycles",
             lambda v: simp["recovery_cycles_total"] >= v,
             f"got {simp['recovery_cycles_total']}")

    sim = card["sim"]
    if sim is None:                 # serving-only scenario: no fleet sim
        return fails
    imp = sim["impact"]
    if not sim["parity"]["counts_equal"]:
        fails.append("engine parity: per-trajectory counts differ")
    if sim["parity"]["time_max_rel_err"] > 1e-6:
        fails.append("engine parity: times diverge "
                     f"({sim['parity']['time_max_rel_err']:.2e})")

    gate("min_extra_revocations", lambda v: imp["extra_revocations"] >= v,
         f"got {imp['extra_revocations']}")
    gate("max_extra_revocations", lambda v: imp["extra_revocations"] <= v,
         f"got {imp['extra_revocations']}")
    gate("min_extra_time_s", lambda v: imp["extra_time_s"] >= v,
         f"got {imp['extra_time_s']}")
    gate("min_extra_lost_steps", lambda v: imp["extra_lost_steps"] >= v,
         f"got {imp['extra_lost_steps']}")

    if card.get("resilience_armed"):
        # resilient_* gates fire only when the run was armed with a
        # ResilienceConfig (the plain CI chaos sweep skips them)
        fs = sim["faulted"]
        gate("resilient_min_paused_s",
             lambda v: fs["paused_s_mean"] >= v,
             f"got {fs['paused_s_mean']}")
        gate("resilient_min_restore_delay_s",
             lambda v: fs["restore_delay_s_mean"] >= v,
             f"got {fs['restore_delay_s_mean']}")

    live = card.get("live")
    if live is None:        # live gates only apply when the live run ran
        return fails
    gate("live_detected_all", lambda v: (not v)
         or live["missed_detections"] == 0,
         f"missed {live['missed_detections']}")
    gate("live_max_latency_steps",
         lambda v: live["detection_latency_steps"] is not None
         and live["detection_latency_steps"] <= v,
         f"got {live['detection_latency_steps']}")
    gate("live_actions", lambda v: live["actions_applied"] == list(v),
         f"got {live['actions_applied']}")
    gate("live_final_compression", lambda v: live["final_compression"] == v,
         f"got {live['final_compression']}")
    gate("live_max_false_alarms", lambda v: live["false_alarms"] <= v,
         f"got {live['false_alarms']}")
    gate("live_max_wrong_actions", lambda v: live["wrong_actions"] <= v,
         f"got {live['wrong_actions']}")
    gate("live_min_ckpt_failures",
         lambda v: live["checkpoint_failures"] >= v,
         f"got {live['checkpoint_failures']}")
    rec = live.get("recovery")
    if card.get("resilience_armed") and rec is not None:
        gate("resilient_live_min_retries",
             lambda v: rec["retries"] >= v, f"got {rec['retries']}")
        gate("resilient_live_min_recovered_saves",
             lambda v: rec["recovered_saves"] >= v,
             f"got {rec['recovered_saves']}")
        gate("resilient_drill_ok",
             lambda v: (not v) or rec["fallback_drill"]["ok"] is True,
             f"got {rec['fallback_drill']}")
        # a silent save failure would show as checkpoint_failed events
        # without matching gave_up retry records — require the ledger to
        # balance whenever any save failed
        if rec["save_failures"] > rec["gave_up"]:
            fails.append("recovery ledger: "
                         f"{rec['save_failures']} save failure(s) but only "
                         f"{rec['gave_up']} exhausted-retry record(s)")
    recal = live.get("recalibration")
    if card.get("recalibration_armed") and recal is not None:
        # recalib_* gates fire only when the run was armed with a
        # RecalibrationConfig (the plain CI chaos sweep skips them)
        gate("recalib_min_drift_events",
             lambda v: len(recal["drift_events"]) >= v,
             f"got {len(recal['drift_events'])}")
        gate("recalib_min_refits", lambda v: len(recal["refits"]) >= v,
             f"got {len(recal['refits'])}")
        gate("recalib_max_post_refit_deviation",
             lambda v: recal["post_refit_deviation"] is not None
             and abs(recal["post_refit_deviation"]) <= v,
             f"got {recal['post_refit_deviation']}")
    return fails


def run_scenario(sc: Scenario, *, session=None, engine: str = "batched",
                 live: bool = True, samples: int = 32, seed: int = 0,
                 smoke: bool = False) -> Dict[str, object]:
    """One scenario -> one scorecard dict (see the module docstring)."""
    if session is None:
        from repro.api.session import Session
        session = Session.from_arch("qwen3-1.7b", smoke=True)
    card: Dict[str, object] = {
        "scenario": sc.name, "description": sc.description, "seed": seed,
        "resilience_armed": session.run.resilience is not None,
        "recalibration_armed": session.run.recalibration is not None,
        # serving scenarios script faults over a ReplicaSet, not a
        # training fleet — the per-worker training sim would be noise
        "sim": (None if sc.serving is not None
                else _run_sim(session, sc, engine, samples, seed)),
        "serving": (_run_serving(session, sc, engine, samples, seed)
                    if sc.serving is not None else None),
        "live": (_run_live(session, sc, seed)
                 if live and sc.live is not None else None),
    }
    if smoke:
        fails = _check_expectations(sc, card)
        card["smoke"] = {"passed": not fails, "failures": fails}
    return card


def run_scenarios(scenario: str = "all", *, session=None,
                  engine: str = "batched", live: bool = True,
                  samples: int = 32, seed: int = 0, smoke: bool = False,
                  progress: Optional[Callable[[str], None]] = None
                  ) -> Dict[str, object]:
    """Run one registered scenario (or all of them) -> full scorecard."""
    names = list_scenarios() if scenario == "all" else [scenario]
    cards = {}
    for name in names:
        if progress:
            progress(f"chaos: running scenario {name}")
        cards[name] = run_scenario(get_scenario(name), session=session,
                                   engine=engine, live=live,
                                   samples=samples, seed=seed, smoke=smoke)
    out = {"engine": engine, "samples": samples, "seed": seed,
           "scenarios": cards}
    if smoke:
        out["passed"] = all(c["smoke"]["passed"] for c in cards.values())
    return out
