"""Replay a *recorded* provider trace as a chaos fault script.

The scripted scenarios in `scenarios.py` invent their faults; this module
derives them from a measurement file instead (the PR 6 carried-forward
item). It reuses the calibration layer's trace parser
(`repro.calibration.traces`) and compiles the recorded history into the
standard primitives:

  * eviction clusters -> `PreemptionWave`s (empirical hazard per bucket:
    evictions / exposed fleet-hours), region-scoped when the records are;
  * spot-price excursions above the fleet's bid -> `PriceSpike`s whose
    hazard scales with the mean fractional excess over the bid.

Because the output is ordinary primitives, the replay inherits the whole
chaos contract for free: keyed hazard draws, engine parity, ground-truth
spans and the smoke gates — a recorded bad afternoon becomes a
reproducible, scoreable scenario.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.calibration.traces import (TraceEvent, eviction_hazard_windows,
                                      load_trace, price_hazard_windows)
from repro.chaos.injectors import FaultTimeline, PreemptionWave, PriceSpike


@dataclasses.dataclass(frozen=True)
class TraceInjector:
    """A recorded trace compiled against a fleet size and a bid."""
    events: Tuple[TraceEvent, ...]
    n_workers: int = 4
    bid: Optional[float] = None        # None = ignore price records
    bucket_h: float = 0.5              # eviction-clustering granularity
    hazard_per_excess: float = 2.0     # price hazard per unit bid excess

    @classmethod
    def from_file(cls, path: str, n_workers: int = 4,
                  bid: Optional[float] = None,
                  bucket_h: float = 0.5,
                  hazard_per_excess: float = 2.0) -> "TraceInjector":
        return cls(tuple(load_trace(path)), n_workers=n_workers, bid=bid,
                   bucket_h=bucket_h, hazard_per_excess=hazard_per_excess)

    def faults(self) -> Tuple[object, ...]:
        """The trace as chaos primitives, in window-start order."""
        out: List[object] = []
        for start, end, hazard, region in eviction_hazard_windows(
                self.events, self.n_workers, self.bucket_h):
            out.append(PreemptionWave(start, end - start, hazard,
                                      region=region))
        if self.bid is not None:
            for start, end, hazard in price_hazard_windows(
                    self.events, self.bid, self.hazard_per_excess):
                out.append(PriceSpike(start, end - start, hazard))
        return tuple(sorted(out, key=lambda f: (f.start_h, f.kind)))

    def timeline(self, roster: Sequence[Tuple],
                 seed: int = 0) -> FaultTimeline:
        """Compile the replay against a launch roster — same contract as
        `Scenario.timeline`."""
        return FaultTimeline(self.faults(), roster, seed=seed)
