"""Named, seeded, composable chaos scenarios (docs/chaos.md).

A `Scenario` scripts faults against a small transient fleet and records
the ground truth the evaluator scores against. The *sim* side is a tuple
of `injectors` primitives compiled into a `FaultTimeline`; scenarios that
also carry a `LivePlan` drive the real `TransientTrainer` through the
same fault kinds via `TransientTrainer.inject_fault` under a virtual
clock, so the Controller's detect -> attribute -> mitigate loop (§VI-B)
is exercised for real, not just simulated.

Register new scenarios with the `@register_scenario` decorator::

    @register_scenario
    def my_outage() -> Scenario:
        return Scenario(name="my_outage", faults=(PSCrash(1.0, 0.5, 0.1),),
                        description="...")

`expect` holds the smoke gates `python -m repro chaos --smoke` enforces;
see `runner._check_expectations` for the supported keys.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from repro.chaos.injectors import (CheckpointOutage, FaultTimeline, PSCrash,
                                   PreemptionWave, PriceSpike, StragglerFault)


@dataclasses.dataclass(frozen=True)
class LiveFault:
    """One `TransientTrainer.inject_fault` call, scheduled at a step."""
    step: int
    kind: str                       # ps_crash/ps_recover/ckpt_outage/...
    payload: Mapping = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class LivePlan:
    """How a scenario drives the live trainer.

    The harness sizes a synthetic PS-bound cluster: `n_workers` workers
    of `worker_speed` steps/s each, against one PS whose *healthy*
    capacity is `ps_capacity_over_demand` x the aggregate worker demand
    (values < 1 reproduce the paper's §VI-B saturated-PS regime, which
    is what lets the controller attribute a measured slowdown to the PS
    and walk the compression ladder). Faults in `faults` fire at their
    step boundaries; paired start/end kinds define the ground-truth
    spans `truth()` returns (an unpaired start runs to `n_steps`).
    """
    n_steps: int
    faults: Tuple[LiveFault, ...]
    check_every: int = 5
    checkpoint_interval: int = 0
    n_workers: int = 4
    worker_speed: float = 25.0
    ps_capacity_over_demand: float = 2.0

    _ENDS = {"ps_crash": "ps_recover", "ckpt_outage": "ckpt_recover",
             "straggler": "straggler_end"}

    def truth(self) -> List[dict]:
        """Ground-truth spans in *steps*: [{kind, start_step, end_step}]."""
        spans: List[dict] = []
        open_spans: Dict[tuple, dict] = {}
        for f in sorted(self.faults, key=lambda f: f.step):
            if f.kind in self._ENDS:
                key = (f.kind, f.payload.get("slot"))
                span = {"kind": f.kind, "start_step": f.step,
                        "end_step": self.n_steps, **dict(f.payload)}
                spans.append(span)
                open_spans[key] = span
            else:
                for start, end in self._ENDS.items():
                    if f.kind == end:
                        key = (start, f.payload.get("slot"))
                        if key in open_spans:
                            open_spans.pop(key)["end_step"] = f.step
        return spans


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named fault script plus the fleet it runs against."""
    name: str
    description: str
    faults: Tuple = ()                  # injectors primitives (sim side)
    provider: str = "gcp"
    region: Optional[str] = None        # None = provider default region
    gpu: str = "v100"
    n_workers: int = 4
    total_steps: int = 300_000
    max_hours: float = 48.0
    handover: bool = True
    live: Optional[LivePlan] = None
    #: a `repro.serving.ServingScript`: the scenario scripts faults over
    #: a serving ReplicaSet instead of a training fleet (docs/serving.md)
    serving: Optional[object] = None
    expect: Mapping = dataclasses.field(default_factory=dict)

    def timeline(self, roster, seed: int = 0) -> FaultTimeline:
        """Compile the fault script against a launch roster. The seed is
        the *scenario* seed: both engines must hand `FaultTimeline` the
        same value or their hazard draws diverge."""
        return FaultTimeline(self.faults, roster, seed=seed)


_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(fn: Callable[[], Scenario]) -> Callable[[], Scenario]:
    """Decorator: evaluate `fn` once and file its `Scenario` by name."""
    sc = fn()
    if sc.name in _REGISTRY:
        raise ValueError(f"scenario {sc.name!r} already registered")
    _REGISTRY[sc.name] = sc
    return fn


def get_scenario(name: str) -> Scenario:
    if name not in _REGISTRY:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------- built-ins
@register_scenario
def regional_wave() -> Scenario:
    """Correlated preemption wave through one region (§V: revocations are
    not independent when the provider reclaims a zone's capacity)."""
    return Scenario(
        name="regional_wave",
        description="GCP reclaims us-central1 capacity for one hour: "
                    "+6/h revocation hazard on every worker in the region",
        faults=(PreemptionWave(0.5, 1.0, 6.0, region="us-central1"),),
        provider="gcp", region="us-central1",
        expect={"min_extra_revocations": 1.0, "min_extra_time_s": 60.0,
                # armed runs (--quorum 0.75): the wave must push the fleet
                # below quorum long enough to register real pause time
                "resilient_min_paused_s": 60.0})


@register_scenario
def price_spike() -> Scenario:
    """Provider-wide spot-price rise through the fleet's bid (the AWS
    price-signal hazard regime, market-wide rather than zonal)."""
    return Scenario(
        name="price_spike",
        description="AWS spot price rises through the bid for 4 h: "
                    "+2/h hazard on the whole fleet",
        faults=(PriceSpike(0.25, 4.0, 2.0),),
        provider="aws", region="us-east-1",
        expect={"min_extra_revocations": 1.0})


@register_scenario
def dead_ps() -> Scenario:
    """Hard PS crash: capacity 0 for an hour — training fully stalls, and
    the run must resume when the window ends (the engines' sp=0 +
    pending-boundary path)."""
    return Scenario(
        name="dead_ps",
        description="parameter server hard-down for 1 h mid-run",
        faults=(PSCrash(0.5, 1.0, 0.0),),
        expect={"min_extra_time_s": 3000.0, "max_extra_revocations": 20.0})


@register_scenario
def ps_crash() -> Scenario:
    """Throttled PS. The live plan starts PS-bound (healthy capacity =
    0.2x demand, the §VI-B regime) and silently cuts PS bandwidth to
    10 %: the controller must notice from measurement alone and walk the
    full compression ladder (none -> int8 -> topk), at which point the
    50x payload shrink restores full worker-bound speed."""
    return Scenario(
        name="ps_crash",
        description="PS capacity quietly drops to 25 % (sim) / 10 % (live)",
        faults=(PSCrash(0.5, 2.0, 0.25),),
        live=LivePlan(
            n_steps=60, check_every=5,
            ps_capacity_over_demand=0.2,
            faults=(LiveFault(20, "ps_crash", {"capacity_factor": 0.1}),)),
        expect={"min_extra_time_s": 60.0,
                "live_detected_all": True,
                "live_max_latency_steps": 10,
                "live_actions": ["enable_compression", "enable_compression"],
                "live_final_compression": "topk",
                "live_max_false_alarms": 0})


@register_scenario
def straggler() -> Scenario:
    """Degraded-NIC worker: one roster slot silently runs at 30 % speed.
    Live, the cluster is worker-bound, so the right attribution is a
    worker replacement — not a PS mitigation."""
    return Scenario(
        name="straggler",
        description="slot 1 silently throttled to 30 % for 3 h (sim) / "
                    "40 steps (live)",
        faults=(StragglerFault(0.5, 3.0, slot=1, speed_factor=0.3),),
        live=LivePlan(
            n_steps=80, check_every=5,
            faults=(LiveFault(25, "straggler",
                              {"slot": 1, "speed_factor": 0.3}),
                    LiveFault(65, "straggler_end", {"slot": 1}))),
        expect={"min_extra_time_s": 60.0,
                "live_detected_all": True,
                "live_max_latency_steps": 10,
                "live_actions": [],        # no PS lever fits a straggler
                "live_max_wrong_actions": 0,
                "live_max_false_alarms": 0,
                # armed runs (--recalibrate): no lever fits a straggler the
                # cluster keeps, so the *model* must adapt — CUSUM confirms
                # the drift, the refit relearns the degraded speed from
                # profiler history, and the next check lands back inside
                # the controller's 6.7 % threshold
                "recalib_min_drift_events": 1,
                "recalib_min_refits": 1,
                "recalib_max_post_refit_deviation": 0.067})


@register_scenario
def ckpt_outage() -> Scenario:
    """Checkpoint-store outage: saves fail fast, so a post-window stock
    revocation rolls back to a checkpoint from before the outage."""
    return Scenario(
        name="ckpt_outage",
        description="checkpoint store down for 2 h (sim) / 25 steps "
                    "(live, saves every 5 steps fail fast)",
        faults=(CheckpointOutage(0.25, 2.0),),
        handover=False,                 # stock chief: lost steps visible
        live=LivePlan(
            n_steps=60, check_every=5, checkpoint_interval=5,
            faults=(LiveFault(20, "ckpt_outage"),
                    LiveFault(45, "ckpt_recover"))),
        expect={"live_min_ckpt_failures": 3,
                "live_max_false_alarms": 0,
                # armed runs (--retry-attempts 4): saves inside the outage
                # must be retried, at least one must recover on a later
                # attempt, and the post-run corruption drill must restore
                # from the previous valid generation (no torn-state loads)
                "resilient_live_min_retries": 5,
                "resilient_live_min_recovered_saves": 1,
                "resilient_drill_ok": True})


@register_scenario
def recorded_trace() -> Scenario:
    """Replay of a *recorded* eviction/price trace (docs/calibration.md
    §traces): the bundled sample afternoon — an eviction cluster riding a
    spot-price excursion in us-central1 — compiled into standard hazard
    primitives by `TraceInjector`, so the replay inherits keyed draws,
    engine parity and the smoke gates."""
    import os

    from repro.chaos.trace_injector import TraceInjector

    inj = TraceInjector.from_file(
        os.path.join(os.path.dirname(__file__), "data",
                     "sample_trace.jsonl"),
        n_workers=4, bid=0.10)
    return Scenario(
        name="recorded_trace",
        description="replay of the bundled us-central1 afternoon trace: "
                    "a 1 h eviction cluster (~3/h empirical hazard) inside "
                    "a 1 h price excursion over the $0.10 bid",
        faults=inj.faults(),
        provider="gcp", region="us-central1",
        expect={"min_extra_revocations": 1.0, "min_extra_time_s": 60.0})


@register_scenario
def serve_wave() -> Scenario:
    """Preemption wave over a *serving* ReplicaSet (docs/serving.md): a
    4-replica continuous-batching fleet on AWS (2-minute revocation
    warnings) takes a minutes-scale wave through an open-loop request
    stream. The runner scores an armed-vs-stock delta: armed, warned
    replicas drain and hand unfinished requests to survivors (zero
    in-flight drops — the headline gate) while admission control bounds
    the p99 inflation; stock drops whatever the wave catches in-flight."""
    from repro.serving import (ServingDegradationPolicy, ServingScript,
                               ServingWorkload)

    return Scenario(
        name="serve_wave",
        description="AWS us-east-1 serving fleet: +60/h revocation hazard "
                    "for 3 min through a 400-request stream at 2 req/s",
        faults=(PreemptionWave(0.01, 0.05, 60.0),),
        provider="aws", region="us-east-1",
        serving=ServingScript(
            replicas=4, batch_ceiling=8, token_time_s=0.05,
            horizon_s=1800.0,
            workload=ServingWorkload(
                n_requests=400, arrival_rate_per_s=2.0, prompt_tokens=32,
                min_tokens=8, max_tokens=32, high_priority_frac=0.25,
                queue_capacity=64, queue_budget_s=15.0,
                hedge_timeout_s=20.0),
            policy=ServingDegradationPolicy(
                reduce_tokens_below=1.0, shrink_batch_below=0.75,
                shed_below=0.5)),
        expect={"serving_zero_dropped_warned": True,
                "serving_min_armed_drop_delta": 1.0,
                "serving_max_p99_inflation": 20.0,
                "serving_min_degraded_cycles": 1.0})


@register_scenario
def wave_price_combo() -> Scenario:
    """Composition: a regional wave inside a provider-wide price spike,
    with a straggler and a checkpoint outage overlapping — the
    worst-afternoon-ever script."""
    return Scenario(
        name="wave_price_combo",
        description="us-central1 wave + fleet-wide spike + straggler + "
                    "checkpoint outage, overlapping",
        faults=(PriceSpike(0.25, 3.0, 1.5),
                PreemptionWave(0.5, 1.0, 5.0, region="us-central1"),
                StragglerFault(0.5, 2.0, slot=0, speed_factor=0.5),
                CheckpointOutage(0.75, 1.0)),
        provider="gcp", region="us-central1",
        expect={"min_extra_revocations": 1.0, "min_extra_time_s": 60.0})
