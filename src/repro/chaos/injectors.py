"""Chaos fault primitives and the `FaultTimeline` the fleet engines consume.

The chaos subsystem (docs/DESIGN.md §7, docs/chaos.md) injects faults with
known ground truth into all three execution paths. This module owns the
*primitives* — each a frozen dataclass with a start, a duration and a
magnitude, all relative to launch (hours of elapsed sim time) — and the
`FaultTimeline` that compiles a list of them against one launch roster:

  * `PreemptionWave` / `PriceSpike` — *hazard* faults: extra revocation
    hazard over a window (a correlated regional capacity reclaim, or a
    spot-price rise through the fleet's bid on AWS/Azure-style markets).
    They act on *lifetimes*, not on the clock: every drawn lifetime is
    deterministically transformed by an inverse-CDF thinning of the
    window overlap, using draws keyed on (seed, fault, trajectory, slot,
    generation) — so the batched and event engines see bit-identical
    revocation timelines no matter in which order they consume them.
  * `StragglerFault` — silently scales one roster slot's step speed
    (degraded NIC / thermal throttling; Table III heterogeneity gone bad).
  * `PSCrash` — scales the PS capacity ceiling (0 = hard down).
  * `CheckpointOutage` — the checkpoint store fails saves: steps produce
    no checkpoint-boundary pauses and `last_ckpt` stops advancing, so a
    stock chief revocation after the window rolls further back.

Speed/PS/ckpt faults are piecewise-constant in time; `boundaries_s` lists
every instant a factor changes, and both engines treat those instants as
(no-op) events so constant-speed advancement never spans a factor change.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

# domain-separation tags for the keyed hazard draws (arbitrary constants,
# fixed forever so recorded scorecards stay reproducible)
_TAG_INITIAL = 0xC4A05
_TAG_JOIN = 0xC4A15


@dataclasses.dataclass(frozen=True)
class PreemptionWave:
    """Correlated preemption wave: `hazard_per_h` of *extra* revocation
    hazard over [start, start+duration), hitting every roster worker in
    `region` (None = all regions) that is alive during the window."""
    start_h: float
    duration_h: float
    hazard_per_h: float
    region: Optional[str] = None
    kind: str = dataclasses.field(default="preemption_wave", repr=False)

    @property
    def end_h(self) -> float:
        return self.start_h + self.duration_h


@dataclasses.dataclass(frozen=True)
class PriceSpike:
    """Market price rises through the fleet's bid: same mechanics as a
    wave (extra hazard over a window) but provider-wide by default —
    demand spikes hit every region's spot pool at once."""
    start_h: float
    duration_h: float
    hazard_per_h: float
    region: Optional[str] = None
    kind: str = dataclasses.field(default="price_spike", repr=False)

    @property
    def end_h(self) -> float:
        return self.start_h + self.duration_h


@dataclasses.dataclass(frozen=True)
class StragglerFault:
    """One roster slot silently runs at `speed_factor` x its speed."""
    start_h: float
    duration_h: float
    slot: int
    speed_factor: float
    kind: str = dataclasses.field(default="straggler", repr=False)

    @property
    def end_h(self) -> float:
        return self.start_h + self.duration_h


@dataclasses.dataclass(frozen=True)
class PSCrash:
    """PS capacity scaled by `capacity_factor` (0 = the server is down
    and training stalls until the window ends)."""
    start_h: float
    duration_h: float
    capacity_factor: float
    kind: str = dataclasses.field(default="ps_crash", repr=False)

    @property
    def end_h(self) -> float:
        return self.start_h + self.duration_h


@dataclasses.dataclass(frozen=True)
class CheckpointOutage:
    """Checkpoint saves fail fast during the window."""
    start_h: float
    duration_h: float
    kind: str = dataclasses.field(default="ckpt_outage", repr=False)

    @property
    def end_h(self) -> float:
        return self.start_h + self.duration_h


_HAZARD_KINDS = (PreemptionWave, PriceSpike)
Fault = object  # any of the dataclasses above


class FaultTimeline:
    """A scenario's faults compiled against one launch roster.

    `roster` is `FleetSim._roster` — tuples of (wid, gpu, region, speed)
    in slot order; `seed` is the *scenario* seed (hazard draws must not
    depend on the per-trajectory engine seeds, or the engines would
    diverge). All times are seconds of elapsed sim time; fault fields are
    hours of elapsed sim time.
    """

    def __init__(self, faults: Iterable[Fault],
                 roster: Sequence[Tuple], seed: int = 0):
        self.faults: Tuple[Fault, ...] = tuple(faults)
        self.seed = int(seed) % (2 ** 32)
        self.regions = tuple(r for _, _, r, _ in roster)
        self.n_slots = len(self.regions)
        self.hazards = tuple((i, f) for i, f in enumerate(self.faults)
                             if isinstance(f, _HAZARD_KINDS)
                             and f.hazard_per_h > 0)
        self.stragglers = tuple(f for f in self.faults
                                if isinstance(f, StragglerFault))
        self.ps = tuple(f for f in self.faults if isinstance(f, PSCrash))
        self.outages = tuple(f for f in self.faults
                             if isinstance(f, CheckpointOutage))
        for f in self.stragglers:
            if not 0 <= f.slot < self.n_slots:
                raise ValueError(f"straggler slot {f.slot} outside the "
                                 f"{self.n_slots}-slot roster")
        # every instant a piecewise factor changes (hazard faults act on
        # lifetimes, not on clocked factors, so they add no boundaries)
        bounds = sorted({b * 3600.0
                         for f in (*self.stragglers, *self.ps, *self.outages)
                         for b in (f.start_h, f.end_h) if b > 0})
        self.boundaries_s = np.asarray(bounds, float)

    # ------------------------------------------------- piecewise factors
    def speed_mults(self, t_s: np.ndarray) -> np.ndarray:
        """(m, slots) per-worker speed multipliers at each time (seconds).
        Factors are evaluated at the *start* of a constant-speed segment;
        windows are half-open [start, end)."""
        t = np.asarray(t_s, float)
        out = np.ones((t.size, self.n_slots))
        for f in self.stragglers:
            active = (t >= f.start_h * 3600.0) & (t < f.end_h * 3600.0)
            out[active, f.slot] *= f.speed_factor
        return out

    def ps_factor(self, t_s: np.ndarray) -> np.ndarray:
        """(m,) PS capacity multipliers at each time (seconds)."""
        t = np.asarray(t_s, float)
        out = np.ones(t.size)
        for f in self.ps:
            active = (t >= f.start_h * 3600.0) & (t < f.end_h * 3600.0)
            out[active] *= f.capacity_factor
        return out

    def ckpt_blocked(self, t_s: np.ndarray) -> np.ndarray:
        """(m,) bool: is the checkpoint store down at each time."""
        t = np.asarray(t_s, float)
        out = np.zeros(t.size, bool)
        for f in self.outages:
            out[(t >= f.start_h * 3600.0) & (t < f.end_h * 3600.0)] = True
        return out

    def next_boundary(self, t_s: np.ndarray) -> np.ndarray:
        """(m,) the next factor-change instant strictly after each time
        (seconds; inf when none remain)."""
        t = np.asarray(t_s, float)
        if self.boundaries_s.size == 0:
            return np.full(t.size, np.inf)
        idx = np.searchsorted(self.boundaries_s, t, side="right")
        padded = np.append(self.boundaries_s, np.inf)
        return padded[idx]

    def factor_tables(self) -> Tuple[np.ndarray, np.ndarray,
                                     np.ndarray, np.ndarray]:
        """Piecewise-constant factor tables for device-resident engines
        (`fleet_jit`): `(boundaries_s, speed_mults, ps_factor,
        ckpt_blocked)` where segment i covers `[b_{i-1}, b_i)` (b_{-1}=0,
        b_m=inf) and the three tables hold each segment's factors,
        evaluated at its start — shapes `(m,)`, `(m+1, slots)`, `(m+1,)`,
        `(m+1,)`. `searchsorted(boundaries_s, t, 'right')` is the segment
        index at time t, the same half-open [start, end) semantics the
        callable factor methods implement."""
        starts = np.concatenate([[0.0], self.boundaries_s])
        return (self.boundaries_s, self.speed_mults(starts),
                self.ps_factor(starts), self.ckpt_blocked(starts))

    def hazard_tables(self) -> Tuple[np.ndarray, np.ndarray,
                                     np.ndarray, np.ndarray]:
        """The hazard faults as arrays for device-resident engines:
        `(start_h, end_h, hazard_per_h, cols)` with shapes `(F,)` x3 and
        `(F, slots)` (bool: does fault f hit slot s's region), in
        `self.hazards` order — the order `transform_*` applies them."""
        F = len(self.hazards)
        starts = np.array([f.start_h for _, f in self.hazards], float)
        ends = np.array([f.end_h for _, f in self.hazards], float)
        rates = np.array([f.hazard_per_h for _, f in self.hazards], float)
        cols = (np.array([self._cols(f.region) for _, f in self.hazards],
                         bool) if F else np.zeros((0, self.n_slots), bool))
        return starts, ends, rates, cols

    def join_uniform_matrix(self, n: int, gen: int) -> np.ndarray:
        """The keyed join-transform uniforms for one generation level as
        an `(n, slots, F)` matrix — element [traj, slot, fi] is exactly
        the `(seed, _TAG_JOIN, fault, traj, slot, gen)` draw
        `transform_joins` makes, pre-materialized so a device-resident
        engine can apply the hazard thinning without host callbacks."""
        F = len(self.hazards)
        out = np.empty((n, self.n_slots, F))
        for k, (fi, _) in enumerate(self.hazards):
            for tj in range(n):
                for sl in range(self.n_slots):
                    out[tj, sl, k] = np.random.default_rng(
                        np.random.SeedSequence(
                            (self.seed, _TAG_JOIN, fi, tj, sl, gen))).random()
        return out

    # ------------------------------------------------ hazard transforms
    def _cols(self, region: Optional[str]) -> np.ndarray:
        return np.array([region is None or r == region
                         for r in self.regions], bool)

    @staticmethod
    def _apply_hazard(lt: np.ndarray, U: np.ndarray, f, h0) -> np.ndarray:
        """Thin one hazard window into drawn lifetimes.

        A worker alive over [h0, h0+lt) overlaps the window for
        `overlap = min(end, h0+lt) - max(start, h0)` hours; an extra
        exponential clock `tau ~ Exp(hazard)` fires inside the overlap
        with exactly the survival probability the added hazard implies,
        and a firing clock moves the revocation earlier — survivors
        (lt = inf) die iff tau lands inside the window."""
        a = np.maximum(f.start_h, h0)
        b = np.minimum(f.end_h, h0 + lt)
        overlap = b - a
        tau = -np.log1p(-U) / f.hazard_per_h
        killed = (overlap > 0) & (tau < overlap)
        return np.where(killed, np.minimum(lt, a + tau - h0), lt)

    def transform_initial(self, lifetimes_h: np.ndarray) -> np.ndarray:
        """Apply every hazard fault to the pre-drawn `(n, slots)`
        initial-lifetime matrix (initial workers launch at elapsed hour
        0). One keyed `(n, slots)` uniform matrix per fault, so the
        transform is a pure function of (seed, fault index)."""
        out = np.array(lifetimes_h, float, copy=True)
        for fi, f in self.hazards:
            cols = self._cols(f.region)
            if not cols.any():
                continue
            rng = np.random.default_rng(np.random.SeedSequence(
                (self.seed, _TAG_INITIAL, fi)))
            U = rng.random(out.shape)
            new = self._apply_hazard(out, U, f, 0.0)
            out = np.where(cols[None, :], new, out)
        return out

    def transform_joins(self, lifetimes_h: np.ndarray, trajs: np.ndarray,
                        slots: np.ndarray, gens: np.ndarray,
                        elapsed_h: np.ndarray) -> np.ndarray:
        """Apply every hazard fault to replacement-join lifetimes.
        `elapsed_h` is each join's elapsed sim time (hours since launch).
        Draws are keyed on (seed, fault, traj, slot, gen): identical no
        matter which engine asks first, or in what batch grouping."""
        lt = np.array(lifetimes_h, float, copy=True)
        if not self.hazards or lt.size == 0:
            return lt
        trajs = np.asarray(trajs, int)
        slots = np.asarray(slots, int)
        gens = np.asarray(gens, int)
        h0 = np.asarray(elapsed_h, float)
        for fi, f in self.hazards:
            cols = self._cols(f.region)
            rows = cols[slots]
            if not rows.any():
                continue
            U = np.array([
                np.random.default_rng(np.random.SeedSequence(
                    (self.seed, _TAG_JOIN, fi, int(tj), int(sl), int(g))
                )).random()
                for tj, sl, g in zip(trajs, slots, gens)])
            new = self._apply_hazard(lt, U, f, h0)
            lt = np.where(rows, new, lt)
        return lt

    # ------------------------------------------------------ ground truth
    def truth_spans(self) -> List[dict]:
        """The recorded ground-truth timeline: one dict per fault with
        its window in seconds — what the evaluator scores against."""
        spans = []
        for f in self.faults:
            span = {"kind": f.kind, "start_s": f.start_h * 3600.0,
                    "end_s": f.end_h * 3600.0}
            for field in ("region", "slot", "hazard_per_h",
                          "speed_factor", "capacity_factor"):
                if hasattr(f, field):
                    span[field] = getattr(f, field)
            spans.append(span)
        return spans
