"""Chaos subsystem: scripted fault scenarios with ground-truth-scored
detection & mitigation (docs/chaos.md, docs/DESIGN.md §7).

Three layers:

* `injectors` — fault primitives (preemption waves, price spikes,
  stragglers, PS crashes, checkpoint outages) and the `FaultTimeline`
  both fleet engines consume;
* `scenarios` — the named, seeded, composable scenario registry
  (`@register_scenario`, `get_scenario`, `list_scenarios`);
* `evaluator` / `runner` — ground-truth scoring of EventBus histories
  and the scenario runner behind `Session.chaos` /
  `python -m repro chaos`.
"""
from repro.chaos.evaluator import EXPECTED_ACTIONS, score_history
from repro.chaos.injectors import (CheckpointOutage, FaultTimeline, PSCrash,
                                   PreemptionWave, PriceSpike,
                                   StragglerFault)
from repro.chaos.runner import VirtualClock, run_scenario, run_scenarios
from repro.chaos.scenarios import (LiveFault, LivePlan, Scenario,
                                   get_scenario, list_scenarios,
                                   register_scenario)

__all__ = [
    "CheckpointOutage", "EXPECTED_ACTIONS", "FaultTimeline", "LiveFault",
    "LivePlan", "PSCrash", "PreemptionWave", "PriceSpike", "Scenario",
    "StragglerFault", "VirtualClock", "get_scenario", "list_scenarios",
    "register_scenario", "run_scenario", "run_scenarios", "score_history",
]
