"""Resumable (arch x shape) dry-run sweep — `python -m repro dryrun --sweep`.

Fans the full compile matrix out as parallel *subprocesses*: the XLA
host-device count must be pinned before jax is imported, so each cell gets
a fresh interpreter, and a crash (or OOM) in one cell cannot take down the
sweep. This module therefore never imports jax itself.

The sweep is resumable by construction: each cell writes one artifact
`<out-dir>/<arch>__<shape>.json` and cells whose artifact already exists
are skipped, so re-running after an interruption only compiles the
missing cells. Failures leave a `.json.err` tombstone (tail of the child's
output) next to the missing artifact; inapplicable (arch, shape) cells are
recorded as explicit skip artifacts so the matrix is always complete on
disk.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed
from typing import List, Optional, Tuple

import repro

#: default artifact root, relative to the working directory
DEFAULT_OUT_DIR = os.path.join("artifacts", "dryrun")


def cells() -> List[Tuple[str, str, bool]]:
    """The full (arch, shape, applicable?) matrix."""
    from repro.configs import ALL_SHAPES, ARCH_IDS, get_config, valid_cells

    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        valid = {s.name for s in valid_cells(cfg)}
        for s in ALL_SHAPES:
            out.append((arch, s.name, s.name in valid))
    return out


def artifact_path(out_dir: str, arch: str, shape: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}.json")


def write_skip(out_dir: str, arch: str, shape: str) -> None:
    """Record an inapplicable cell so the on-disk matrix stays complete."""
    with open(artifact_path(out_dir, arch, shape), "w") as f:
        json.dump([{"arch": arch, "shape": shape, "ok": False,
                    "skipped": True,
                    "reason": "inapplicable cell (docs/DESIGN.md §4)"}], f)


def run_one(out_dir: str, arch: str, shape: str, mesh: str,
            timeout: int) -> Tuple[str, str, str]:
    """One cell in a child interpreter; returns (arch, shape, status)."""
    path = artifact_path(out_dir, arch, shape)
    if os.path.exists(path):
        return arch, shape, "cached"
    env = dict(os.environ)
    # make sure the child resolves the same `repro` package as the parent,
    # whether the sweep was launched from a checkout or an install
    # (`repro` is a namespace package: __file__ is None, use __path__)
    pkg_root = os.path.dirname(os.path.abspath(next(iter(repro.__path__))))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (pkg_root, env.get("PYTHONPATH")) if p)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", path]
    t0 = time.time()
    try:
        p = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=timeout)
        status = "ok" if p.returncode == 0 else "FAIL"
        if p.returncode != 0:
            with open(path + ".err", "w") as f:
                f.write(p.stdout[-5000:] + "\n--stderr--\n"
                        + p.stderr[-10000:])
    except subprocess.TimeoutExpired:
        status = "TIMEOUT"
        with open(path + ".err", "w") as f:
            f.write("timeout\n")
    return arch, shape, f"{status} ({time.time() - t0:.0f}s)"


def sweep(out_dir: str = DEFAULT_OUT_DIR, jobs: int = 3,
          mesh: str = "both", timeout: int = 3000,
          progress=print) -> int:
    """Run the matrix; returns the number of cells that FAILED/TIMED OUT."""
    os.makedirs(out_dir, exist_ok=True)
    todo = cells()
    progress(f"{len(todo)} cells total -> {out_dir}")
    failures = 0
    with ThreadPoolExecutor(max_workers=jobs) as ex:
        futs = {}
        for arch, shape, valid in todo:
            if not valid:
                if not os.path.exists(artifact_path(out_dir, arch, shape)):
                    write_skip(out_dir, arch, shape)
                progress(f"SKIP {arch} {shape}")
                continue
            futs[ex.submit(run_one, out_dir, arch, shape, mesh,
                           timeout)] = (arch, shape)
        for fut in as_completed(futs):
            arch, shape, status = fut.result()
            if "FAIL" in status or "TIMEOUT" in status:
                failures += 1
            progress(f"{arch:24s} {shape:12s} {status}")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    from repro.launch.cli import make_parser

    ap = make_parser("repro dryrun --sweep",
                     "parallel (arch x shape) dry-run sweep, resumable")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--timeout", type=int, default=3000,
                    help="seconds per cell before a TIMEOUT tombstone")
    ap.add_argument("--out-dir", default=DEFAULT_OUT_DIR,
                    help="artifact directory (existing artifacts are "
                         "skipped: re-run to resume)")
    args = ap.parse_args(argv)
    failures = sweep(out_dir=args.out_dir, jobs=args.jobs, mesh=args.mesh,
                     timeout=args.timeout,
                     progress=lambda m: print(m, flush=True))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
