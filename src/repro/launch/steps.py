"""Step factories: jit-able train_step / prefill_step / serve_step with
NamedShardings derived from the models' logical axes. Used by the launcher,
the multi-pod dry-run, and the examples.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.dist import sharding as sh
from repro.dist.compression import ErrorFeedback, payload_bytes
from repro.models import api
from repro.optim import clip_by_global_norm, cosine_warmup, make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jnp.ndarray
    # error-feedback residual tree for grad compression (§VI-B); the empty
    # tuple is a leafless pytree, so uncompressed runs carry no extra state
    # and pre-compression checkpoints/specs stay structurally identical
    residual: Any = ()


# ---------------------------------------------------------------------------
# sharding derivation
# ---------------------------------------------------------------------------
def param_shardings(mesh, cfg: ModelConfig, rules=sh.MEGATRON_RULES):
    axes = api.param_axes(cfg)
    shapes = api.param_shapes(cfg)
    return sh.tree_shardings(mesh, axes, rules, shapes)


def _zero1(mesh, sharding: jax.sharding.NamedSharding, shape, rules):
    """Additionally shard the first unsharded divisible dim over 'data'
    (ZeRO-1: optimizer state partitioned across the data axis)."""
    if "data" not in mesh.axis_names:
        return sharding
    spec = list(sharding.spec) + [None] * (len(shape) - len(sharding.spec))
    used = {a for e in spec if e is not None
            for a in (e if isinstance(e, tuple) else (e,))}
    if "data" in used:
        return sharding
    sizes = dict(mesh.shape)
    dsize = sizes["data"]
    for i, e in enumerate(spec):
        if e is None and shape[i] % dsize == 0 and shape[i] >= dsize:
            spec[i] = "data"
            return jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(*spec))
        if e is not None:
            axes = e if isinstance(e, tuple) else (e,)
            cur = 1
            for a in axes:
                cur *= sizes[a]
            if shape[i] % (cur * dsize) == 0:
                spec[i] = tuple(axes) + ("data",)
                return jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(*spec))
    return sharding


def opt_shardings(mesh, cfg: ModelConfig, run: RunConfig, p_shardings,
                  rules=sh.MEGATRON_RULES):
    """Optimizer-state shardings: mirror params, optionally ZeRO-1 over data.

    Opt state is {} (sgd) or {"m": params-like[, "v": params-like]}.
    """
    opt = make_optimizer(run.optimizer, run.lr, run.weight_decay,
                         master=run.master_weights)
    shapes = _live_param_shapes(cfg, run)
    opt_shape = jax.eval_shape(opt.init, shapes)
    if not opt_shape:
        return opt_shape

    def map_like(subtree):
        return jax.tree.map(
            lambda sdg, shp: (_zero1(mesh, sdg, shp.shape, rules)
                              if run.zero1 else sdg),
            p_shardings, subtree)

    return {k: map_like(v) for k, v in opt_shape.items()}


def batch_shardings(mesh, cfg: ModelConfig, shape: ShapeConfig,
                    rules=sh.MEGATRON_RULES):
    specs, axes = api.batch_specs(cfg, shape)
    return sh.tree_shardings(mesh, axes, rules, specs), specs


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------
def _live_param_shapes(cfg: ModelConfig, run: RunConfig):
    """Shapes of the LIVE params (bf16 when master_weights)."""
    shapes = api.param_shapes(cfg)
    if run.master_weights:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            shapes)
    return shapes


def make_train_step(cfg: ModelConfig, run: RunConfig, mesh=None,
                    rules=sh.MEGATRON_RULES):
    """Returns train_step(state, batch) -> (state, metrics).

    With ``run.grad_compression`` in {"bf16", "int8", "topk"}, the clipped
    gradients take the §VI-B wire round-trip before the optimizer sees
    them: the error-feedback residual carried in ``state.residual`` is
    folded in, the sum is quantize-decompressed, and the quantization
    error becomes the next step's residual. Metrics then include
    ``payload_bytes`` — the actual compressed push size the trainer
    reports on the event bus.
    """
    lr = cosine_warmup(run.lr, run.warmup_steps, run.total_steps)
    opt = make_optimizer(run.optimizer, lr, run.weight_decay,
                         master=run.master_weights)
    ef = (ErrorFeedback(run.grad_compression)
          if run.grad_compression != "none" else None)

    def train_step(state: TrainState, batch):
        def loss_of(p):
            return api.loss_fn(p, cfg, batch)

        if run.microbatch and run.microbatch > 1:
            n = run.microbatch
            split = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:])
                if x.ndim >= 1 and x.shape[0] % n == 0 else x, batch)

            def micro(acc, mb):
                l, g = jax.value_and_grad(
                    lambda p: api.loss_fn(p, cfg, mb))(state.params)
                return (acc[0] + l / n,
                        jax.tree.map(lambda a, b: a + b / n, acc[1], g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(micro, (0.0, zeros), split)
        else:
            loss, grads = jax.value_and_grad(loss_of)(state.params)

        grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        residual = state.residual
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "step": state.step}
        if ef is not None:
            grads, residual = ef.roundtrip(grads, residual)
            metrics["payload_bytes"] = jnp.asarray(
                payload_bytes(grads, run.grad_compression), jnp.float32)
        new_params, new_opt = opt.update(grads, state.opt, state.params,
                                         state.step)
        return TrainState(new_params, new_opt, state.step + 1,
                          residual), metrics

    return train_step, opt


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return api.prefill(params, cfg, batch)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One-token decode against a KV cache / SSM state."""
    def serve_step(params, state, tokens, index):
        logits, new_state = api.decode_step(params, cfg, state, tokens, index)
        return logits, new_state
    return serve_step


def init_residual(params, run: RunConfig):
    """Zero error-feedback residual when compression is on, else the empty
    (leafless) tree."""
    if run.grad_compression == "none":
        return ()
    return ErrorFeedback(run.grad_compression).init(params)


def init_train_state(cfg: ModelConfig, run: RunConfig, key=None) -> TrainState:
    params, _ = api.init(cfg, key)
    if run.master_weights:
        params = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
            params)
    lr = cosine_warmup(run.lr, run.warmup_steps, run.total_steps)
    opt = make_optimizer(run.optimizer, lr, run.weight_decay,
                         master=run.master_weights)
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32),
                      init_residual(params, run))


def train_state_specs(cfg: ModelConfig, run: RunConfig):
    """(ShapeDtypeStruct tree, shardings fn) for AOT lowering without alloc."""
    pshapes = _live_param_shapes(cfg, run)
    lr = cosine_warmup(run.lr, run.warmup_steps, run.total_steps)
    opt = make_optimizer(run.optimizer, lr, run.weight_decay,
                         master=run.master_weights)
    opt_shapes = jax.eval_shape(opt.init, pshapes)
    res_shapes = ()
    if run.grad_compression != "none":
        res_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pshapes)
    return TrainState(pshapes, opt_shapes,
                      jax.ShapeDtypeStruct((), jnp.int32), res_shapes)


def train_state_shardings(mesh, cfg: ModelConfig, run: RunConfig,
                          rules=sh.MEGATRON_RULES):
    ps = param_shardings(mesh, cfg, rules)
    os_ = opt_shardings(mesh, cfg, run, ps, rules)
    scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    # the residual is params-shaped (f32), so it shards exactly like params
    rs = ps if run.grad_compression != "none" else ()
    return TrainState(ps, os_, scalar, rs)
