"""Production meshes. Functions (never module-level constants) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod outer axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    n_data = min(n_data, n)
    n_model = max(1, min(n_model, n // max(1, n_data)))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
