import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks device
# count on first init). Placeholder host devices exist ONLY for the dry-run.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Multi-pod dry-run: .lower().compile() every (arch x shape) cell on the
# production meshes, emit memory/cost/collective analysis for §Roofline.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
#       --shape train_4k --mesh single --out artifacts/q3_train.json

import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, SHAPES, get_config, valid_cells
from repro.dist import sharding as sh
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh
from repro.models import api

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# ring-cost multipliers applied to the op's result bytes ((n-1)/n ~= 1)
_RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _bytes_of_shapes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Sum result-operand bytes of every collective op in optimized HLO."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match "= <shape> kind(" including "-start" variants
            if (f" {kind}(" in stripped or f" {kind}-start(" in stripped) \
                    and "=" in stripped:
                lhs = stripped.split(f" {kind}")[0]
                nbytes = _bytes_of_shapes(lhs.split("=", 1)[-1])
                stats[kind]["count"] += 1
                stats[kind]["bytes"] += nbytes
                break
    total = sum(v["bytes"] * _RING_FACTOR[k] for k, v in stats.items())
    stats["weighted_total_bytes"] = int(total)
    return stats


def _spec_sharding(mesh, axes_tree, specs_tree, rules):
    return sh.tree_shardings(mesh, axes_tree, rules, specs_tree)


def lower_cell(arch: str, shape_name: str, mesh, rules=sh.MEGATRON_RULES,
               run: Optional[RunConfig] = None, donate: bool = True,
               cfg=None):
    """Build + lower one (arch x shape) cell on `mesh`. Returns (lowered, meta)."""
    cfg = cfg if cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train" and cfg.remat == "none":
        # activation checkpointing is mandatory at these shapes (temp memory
        # otherwise exceeds HBM by >10x); probes inherit the same policy
        cfg = cfg.with_(remat="full")
    run = run or RunConfig()
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    with sh.use_sharding(mesh, rules):
        if shape.kind in ("train",):
            step, _ = st.make_train_step(cfg, run, mesh, rules)
            state_specs = st.train_state_specs(cfg, run)
            state_sh = st.train_state_shardings(mesh, cfg, run, rules)
            b_sh, b_specs = st.batch_shardings(mesh, cfg, shape, rules)
            fn = jax.jit(step,
                         in_shardings=(state_sh, b_sh),
                         out_shardings=(state_sh,
                                        {"loss": repl, "grad_norm": repl,
                                         "step": repl}),
                         donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state_specs, b_specs)
        elif shape.kind == "prefill":
            step = st.make_prefill_step(cfg)
            p_specs = api.param_shapes(cfg)
            p_sh = sh.tree_shardings(mesh, api.param_axes(cfg), rules, p_specs)
            b_sh, b_specs = st.batch_shardings(mesh, cfg, shape, rules)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = fn.lower(p_specs, b_specs)
        else:  # decode / long_decode
            step = st.make_serve_step(cfg)
            p_specs = api.param_shapes(cfg)
            p_sh = sh.tree_shardings(mesh, api.param_axes(cfg),
                                     rules, p_specs)
            s_specs, s_axes = api.decode_state_specs(cfg, shape.global_batch,
                                                     shape.seq_len)
            s_sh = sh.tree_shardings(mesh, s_axes, rules, s_specs)
            tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            idx = jax.ShapeDtypeStruct((), jnp.int32)
            tok_sh = sh.named_sharding(mesh, ("batch",), rules, tok.shape)
            fn = jax.jit(step,
                         in_shardings=(p_sh, s_sh, tok_sh, repl),
                         donate_argnums=(1,) if donate else ())
            lowered = fn.lower(p_specs, s_specs, tok, idx)
    return lowered, {"cfg": cfg, "shape": shape}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D, D = tokens processed."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() returns a dict on recent jax and a
    per-program list on jax<0.5 — normalize to one dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    return ca


def _cost_of(compiled) -> Dict[str, float]:
    ca = cost_analysis_dict(compiled)
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["weighted_total_bytes"]),
    }


def _probe_cost(arch, shape_name, mesh, rules, run, cfg_variant):
    lowered, _ = lower_cell(arch, shape_name, mesh, rules, run, donate=False,
                            cfg=cfg_variant)
    return _cost_of(lowered.compile())


def probe_corrected_cost(arch: str, shape_name: str, mesh, rules,
                         run: RunConfig, remat: str = "none") -> Dict[str, Any]:
    """XLA cost_analysis counts while(=scan) bodies ONCE. Measure per-layer
    body cost with small UNROLLED probe compiles and reconstruct the true
    total: total = overhead + sum_i trip_i * body_i.
    """
    cfg = get_config(arch).with_(remat=remat) if remat != "none" \
        else get_config(arch)
    u = lambda **kw: cfg.with_(unroll_layers=True, **kw)  # noqa: E731
    out: Dict[str, Any] = {"probes": 0}

    def lin(c0, c1):  # body = c1 - c0 per key
        return {k: c1[k] - c0[k] for k in c0}

    if cfg.family == "hybrid":
        e = cfg.shared_attn_every
        n_groups = cfg.n_layers // e
        rem = cfg.n_layers - n_groups * e
        c_g1 = _probe_cost(arch, shape_name, mesh, rules, run, u(n_layers=e))
        c_g2 = _probe_cost(arch, shape_name, mesh, rules, run,
                           u(n_layers=2 * e))
        body_g = lin(c_g1, c_g2)
        overhead = lin(body_g, c_g1)
        if rem:
            c_t = _probe_cost(arch, shape_name, mesh, rules, run,
                              u(n_layers=e + 1))
            body_m = lin(c_g1, c_t)
        else:
            body_m = {k: 0.0 for k in c_g1}
        total = {k: overhead[k] + n_groups * body_g[k] + rem * body_m[k]
                 for k in c_g1}
        out["probes"] = 3 if rem else 2
    else:
        fkd = cfg.first_k_dense
        s_full = cfg.n_layers - fkd
        c1 = _probe_cost(arch, shape_name, mesh, rules, run,
                         u(n_layers=1, first_k_dense=0))
        c2 = _probe_cost(arch, shape_name, mesh, rules, run,
                         u(n_layers=2, first_k_dense=0))
        body_s = lin(c1, c2)
        overhead = lin(body_s, c1)
        if fkd:
            cd = _probe_cost(arch, shape_name, mesh, rules, run,
                             u(n_layers=2, first_k_dense=1))
            body_d = lin(c2, cd)
            out["probes"] = 3
        else:
            body_d = {k: 0.0 for k in c1}
            out["probes"] = 2
        total = {k: overhead[k] + fkd * body_d[k] + s_full * body_s[k]
                 for k in c1}
    out["corrected"] = total
    return out


_RULESETS = {"megatron": sh.MEGATRON_RULES, "decode": sh.DECODE_RULES,
             "ep": sh.EP_RULES, "dp": sh.DP_RULES, "dpep": sh.DPEP_RULES,
             "fsdp": sh.FSDP_RULES}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules_name: str = "megatron", donate: bool = True,
             zero1: bool = True, probes: bool = True,
             master_weights: bool = False,
             remat: str = "none", microbatch: int = 0,
             kv_quant: bool = False) -> Dict[str, Any]:
    rules = _RULESETS[rules_name]
    shape = SHAPES[shape_name]
    if shape.is_decode and rules_name == "megatron":
        rules = sh.DECODE_RULES
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    run = RunConfig(zero1=zero1, master_weights=master_weights,
                    microbatch=microbatch)
    cfg_override = None
    if remat != "none" or kv_quant:
        cfg_override = get_config(arch).with_(
            **({"remat": remat} if remat != "none" else {}),
            **({"kv_quant": True} if kv_quant else {}))
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "rules": rules_name, "chips": int(n_chips), "ok": False,
        "master_weights": master_weights, "remat": remat,
    }
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, mesh, rules, run, donate,
                               cfg=cfg_override)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ca = cost_analysis_dict(compiled)
    rec["flops_per_device"] = float(ca.get("flops", 0.0))
    rec["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes":
                int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # CPU backend may not implement it
        rec["memory"] = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    rec["collectives"] = coll
    rec["hlo_ops"] = {
        "fusion": hlo.count(" fusion("),
        "while": hlo.count(" while("),
    }

    cfg, shp = meta["cfg"], meta["shape"]
    mf = model_flops(cfg, shp)
    rec["model_flops_total"] = mf
    rec["model_flops_per_device"] = mf / n_chips

    # scan-corrected costs (XLA costs while bodies once) via unrolled probes
    flops_dev = rec["flops_per_device"]
    bytes_dev = rec["bytes_per_device"]
    coll_dev = float(coll["weighted_total_bytes"])
    if probes:
        try:
            pc = probe_corrected_cost(
                arch, shape_name, mesh,
                rules if not shape.is_decode else sh.DECODE_RULES,
                run, remat=remat)
            rec["probe"] = pc
            flops_dev = pc["corrected"]["flops"]
            bytes_dev = pc["corrected"]["bytes"]
            coll_dev = pc["corrected"]["coll_bytes"]
        except Exception as e:
            rec["probe"] = {"error": repr(e)[:500]}
    rec["flops_per_device_corrected"] = flops_dev
    rec["bytes_per_device_corrected"] = bytes_dev
    rec["collective_bytes_corrected"] = coll_dev
    rec["useful_flops_ratio"] = (mf / n_chips) / flops_dev if flops_dev else 0.0

    rec["roofline"] = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / LINK_BW,
    }
    terms = rec["roofline"]
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["ok"] = True
    return rec


def main(argv=None) -> None:
    from repro.launch import cli
    ap = cli.make_parser("repro.launch.dryrun",
                         "AOT lower/compile (arch x shape) cells on the "
                         "production meshes")
    cli.add_arch_arg(ap, required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--rules", default="megatron")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--master-weights", action="store_true")
    ap.add_argument("--remat", default="none",
                    choices=("none", "full", "dots"))
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if SHAPES[args.shape] not in valid_cells(cfg):
        rec = {"arch": args.arch, "shape": args.shape, "ok": False,
               "skipped": True,
               "reason": "cell skipped per DESIGN.md §4 (arch-applicability)"}
        print(json.dumps(rec))
        if args.out:
            with open(args.out, "w") as f:
                json.dump([rec], f, indent=1)
        return

    recs = []
    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    for multi in meshes[args.mesh]:
        # roofline probes only on the single-pod mesh (per spec the roofline
        # table is single-pod; the multi-pod pass proves shardability)
        rec = run_cell(args.arch, args.shape, multi, args.rules,
                       zero1=not args.no_zero1, probes=not multi,
                       master_weights=args.master_weights, remat=args.remat,
                       microbatch=args.microbatch, kv_quant=args.kv_quant)
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "ok", "compile_s",
                           "flops_per_device", "bottleneck")}))
        recs.append(rec)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1)


if __name__ == "__main__":
    main()
