"""Shared argparse wiring for every entry point.

Before the API redesign each launcher/benchmark/example re-declared the same
arch/batch/seq/seed/smoke flags with drifting defaults; this module is the
single source of truth, used by `python -m repro` (repro/__main__.py), the
`repro.launch.*` deprecation shims, `benchmarks/run.py` and the examples.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Optional

from repro.configs import ARCH_IDS, RunConfig


def make_parser(prog: str, description: str) -> argparse.ArgumentParser:
    return argparse.ArgumentParser(prog=prog, description=description)


# --------------------------------------------------------------- arg groups
def add_arch_arg(p: argparse.ArgumentParser, required: bool = False,
                 default: Optional[str] = "qwen3-1.7b") -> None:
    p.add_argument("--arch", choices=ARCH_IDS,
                   required=required,
                   default=None if required else default,
                   help="architecture id (see repro.configs.registry)")


def add_scale_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("--full", action="store_true",
                   help="production config (TPU-sized); default is the "
                        "reduced smoke config that runs on CPU")
    p.add_argument("--seed", type=int, default=0)


def add_batch_args(p: argparse.ArgumentParser, batch_default: int = 8,
                   seq_default: int = 64) -> None:
    p.add_argument("--global-batch", type=int, default=batch_default)
    p.add_argument("--seq", type=int, default=seq_default)


def add_train_args(p: argparse.ArgumentParser,
                   steps_default: int = 50) -> None:
    p.add_argument("--steps", type=int, default=steps_default)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--optimizer", default="adamw")
    # None = let Session pick the arch-namespaced default; an explicit
    # value (even the default path) is honored verbatim
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--checkpoint-interval", type=int, default=20)
    p.add_argument("--members", type=int, default=2)
    p.add_argument("--revoke-at", type=int, default=0,
                   help="inject a revocation at this step (0 = none)")
    p.add_argument("--master-weights", action="store_true")
    p.add_argument("--mode", default="sync", choices=("sync", "async_ps"),
                   help="sync elastic runtime (default) or the §II "
                        "asynchronous-PS emulation with staleness "
                        "telemetry")
    p.add_argument("--grad-compression", default="none",
                   choices=("none", "bf16", "int8", "topk"),
                   help="§VI-B wire compression with error feedback; "
                        "also rescales the predicted PS capacity")
    p.add_argument("--compilation-cache-dir", default=None,
                   help="persistent JAX compilation cache directory — "
                        "repeated runs skip re-jitting identical steps")


def add_resilience_args(p: argparse.ArgumentParser) -> None:
    """Recovery-policy flags (docs/resilience.md). All default to unset;
    `resilience_from_args` returns None (legacy fail-fast behavior)
    unless at least one is given."""
    g = p.add_argument_group("resilience")
    g.add_argument("--retry-attempts", type=int, default=None,
                   help="max attempts per fallible op (save/restore/join)")
    g.add_argument("--retry-base", type=float, default=None,
                   help="first backoff delay, seconds")
    g.add_argument("--retry-max-delay", type=float, default=None,
                   help="backoff ceiling, seconds")
    g.add_argument("--retry-deadline", type=float, default=None,
                   help="total backoff budget per op, seconds")
    g.add_argument("--quorum", type=float, default=None,
                   help="pause training below this alive fraction")
    g.add_argument("--shrink-below", type=float, default=None,
                   help="shrink the global batch below this alive "
                        "fraction (but above --quorum)")
    g.add_argument("--shrink-factor", type=float, default=None,
                   help="global-batch factor while shrunk (default 0.5)")
    g.add_argument("--restore-fail-p", type=float, default=None,
                   help="simulated per-attempt restore failure "
                        "probability (fleet sim stall model)")


def resilience_from_args(args: argparse.Namespace):
    """`ResilienceConfig` from the add_resilience_args namespace, or None
    when no resilience flag was passed (exact legacy behavior)."""
    names = ("retry_attempts", "retry_base", "retry_max_delay",
             "retry_deadline", "quorum", "shrink_below", "shrink_factor",
             "restore_fail_p")
    vals = {n: getattr(args, n, None) for n in names}
    if all(v is None for v in vals.values()):
        return None
    from repro.resilience import (DegradationPolicy, ResilienceConfig,
                                  RetryPolicy)
    retry = RetryPolicy()
    if vals["retry_attempts"] is not None:
        retry = dataclasses.replace(retry,
                                    max_attempts=vals["retry_attempts"])
    if vals["retry_base"] is not None:
        retry = dataclasses.replace(retry, base_delay_s=vals["retry_base"])
    if vals["retry_max_delay"] is not None:
        retry = dataclasses.replace(retry,
                                    max_delay_s=vals["retry_max_delay"])
    if vals["retry_deadline"] is not None:
        retry = dataclasses.replace(retry,
                                    deadline_s=vals["retry_deadline"])
    degr = DegradationPolicy(
        quorum=vals["quorum"] or 0.0,
        shrink_below=vals["shrink_below"] or 0.0,
        shrink_factor=(0.5 if vals["shrink_factor"] is None
                       else vals["shrink_factor"]))
    return ResilienceConfig(retry=retry, degradation=degr,
                            restore_fail_p=vals["restore_fail_p"] or 0.0,
                            seed=getattr(args, "seed", 0) or 0)


def add_recalib_args(p: argparse.ArgumentParser) -> None:
    """Online-recalibration flags (docs/calibration.md). Unarmed unless
    `--recalibrate` is passed; `recalib_from_args` then returns None and
    every static calibration stays bit-identical."""
    g = p.add_argument_group("recalibration")
    g.add_argument("--recalibrate", action="store_true",
                   help="arm CUSUM drift detection + online refit of the "
                        "cluster-speed model from profiler history")
    g.add_argument("--drift-threshold", type=float, default=None,
                   help="CUSUM alarm level on accumulated deviation "
                        "(default 0.15)")
    g.add_argument("--drift-allowance", type=float, default=None,
                   help="per-check deviation slack before the CUSUM "
                        "statistic accumulates (default 0.05)")
    g.add_argument("--refit-window", type=int, default=None,
                   help="trailing profiler records a refit consumes "
                        "(default 6)")
    g.add_argument("--recalib-trace", default=None,
                   help="recorded provider trace (JSONL) to refit "
                        "lifetime laws from at startup")


def recalib_from_args(args: argparse.Namespace):
    """`RecalibrationConfig` from the add_recalib_args namespace, or None
    when --recalibrate was not passed (exact static behavior)."""
    if not getattr(args, "recalibrate", False):
        return None
    from repro.calibration import RecalibrationConfig
    cfg = RecalibrationConfig()
    picked = {}
    if getattr(args, "drift_threshold", None) is not None:
        picked["drift_threshold"] = args.drift_threshold
    if getattr(args, "drift_allowance", None) is not None:
        picked["drift_allowance"] = args.drift_allowance
    if getattr(args, "refit_window", None) is not None:
        picked["refit_window"] = args.refit_window
    if getattr(args, "recalib_trace", None) is not None:
        picked["trace_path"] = args.recalib_trace
    return dataclasses.replace(cfg, **picked)


def add_serve_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--tokens", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)


def add_serve_fleet_args(p: argparse.ArgumentParser) -> None:
    """`serve --fleet` planning flags (docs/serving.md)."""
    g = p.add_argument_group("fleet planning (--fleet)")
    g.add_argument("--fleet", action="store_true",
                   help="plan an SLO-aware serving fleet across transient "
                        "markets instead of decoding locally")
    g.add_argument("--gpu", default="v100", choices=("k80", "p100", "v100"))
    g.add_argument("--providers", default="gcp,aws",
                   help="comma-separated transient markets to score")
    g.add_argument("--replica-counts", default="2,4,8",
                   help="comma-separated fleet sizes to score")
    g.add_argument("--requests", type=int, default=200,
                   help="workload size (open-loop Poisson stream)")
    g.add_argument("--rate", type=float, default=2.0,
                   help="mean arrivals per second")
    g.add_argument("--slo-p99", type=float, default=10.0,
                   help="p99 end-to-end latency SLO, seconds")
    g.add_argument("--plan-samples", type=int, default=8,
                   help="simulation trajectories per fleet cell")


def add_fleet_args(p: argparse.ArgumentParser,
                   workers_default: int = 4) -> None:
    from repro.providers import available_providers

    # only the paper's measured GPUs have calibrated speed/revocation
    # models (v5e is the TPU serving/training chip, not a fleet offering)
    p.add_argument("--gpu", default="v100", choices=("k80", "p100", "v100"))
    p.add_argument("--provider", default="gcp",
                   choices=available_providers(),
                   help="transient market to plan/simulate/predict on "
                        "(docs/providers.md)")
    p.add_argument("--region", default=None,
                   help="constrain to one region (default: the provider's "
                        "default region; `plan` scores all regions)")
    p.add_argument("--workers", type=int, default=workers_default)
    p.add_argument("--n-ps", type=int, default=1)


# ------------------------------------------------------------- constructors
def run_config_from_args(args: argparse.Namespace) -> RunConfig:
    """RunConfig from the add_train_args/add_scale_args namespace; absent
    attributes fall back to RunConfig defaults."""
    base = RunConfig()
    fields = {f.name for f in dataclasses.fields(RunConfig)}
    picked = {}
    # checkpoint_dir is intentionally NOT mapped: handlers pass
    # args.checkpoint_dir to Session.train directly, so None (unset) and an
    # explicit path — even one equal to the RunConfig default — stay distinct
    mapping = {
        "optimizer": "optimizer", "lr": "lr",
        "total_steps": "steps", "checkpoint_interval": "checkpoint_interval",
        "master_weights": "master_weights", "seed": "seed",
        "grad_compression": "grad_compression",
        "compilation_cache_dir": "compilation_cache_dir",
    }
    for field, attr in mapping.items():
        if field in fields and getattr(args, attr, None) is not None:
            picked[field] = getattr(args, attr)
    if "total_steps" in picked:
        picked["warmup_steps"] = max(1, picked["total_steps"] // 10)
    picked["zero1"] = False  # single-host CPU path; dryrun covers zero1
    res = resilience_from_args(args)
    if res is not None:
        picked["resilience"] = res
    recal = recalib_from_args(args)
    if recal is not None:
        picked["recalibration"] = recal
    return dataclasses.replace(base, **picked)


def session_from_args(args: argparse.Namespace):
    """Build a `repro.api.Session` from a parsed namespace."""
    from repro.api import Session
    return Session.from_arch(args.arch,
                             smoke=not getattr(args, "full", False),
                             run=run_config_from_args(args))
