"""Serving launcher: batched prefill + token-by-token decode against the KV
cache / SSM state for any `--arch` (reduced config on CPU).

PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode path")
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.tokens
    state, _ = api.init_decode_state(cfg, args.batch, max_len)

    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)

    step = jax.jit(lambda p, s, t, i: api.decode_step(p, cfg, s, t, i))

    # prefill via repeated decode (cache-consistent for every family)
    t0 = time.monotonic()
    logits = None
    for i in range(args.prompt_len):
        logits, state = step(params, state, prompt[:, i], jnp.int32(i))
    prefill_s = time.monotonic() - t0

    toks = jnp.argmax(logits, -1)
    out = [toks]
    t0 = time.monotonic()
    for i in range(args.tokens - 1):
        logits, state = step(params, state, toks,
                             jnp.int32(args.prompt_len + i))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            toks = jax.random.categorical(sub, logits / args.temperature, -1)
        else:
            toks = jnp.argmax(logits, -1)
        out.append(toks)
    decode_s = time.monotonic() - t0
    gen = jnp.stack(out, 1)
    print(f"arch={args.arch} batch={args.batch} "
          f"prefill {args.prompt_len} tok in {prefill_s:.2f}s; "
          f"decode {args.tokens} tok in {decode_s:.2f}s "
          f"({args.tokens * args.batch / max(decode_s, 1e-9):.1f} tok/s)")
    print("sample tokens:", gen[0, :10].tolist())


if __name__ == "__main__":
    main()
