"""DEPRECATED serving launcher — prefer ``python -m repro serve``.

Thin shim over `repro.api.serving.generate` (shared with `Session.serve`);
kept so ``python -m repro.launch.serve --arch mamba2-1.3b`` keeps working.
"""
from __future__ import annotations

import sys

from repro.launch import cli


def main() -> None:
    p = cli.make_parser("repro.launch.serve",
                        "DEPRECATED: use `python -m repro serve`")
    cli.add_arch_arg(p, required=True)
    cli.add_scale_args(p)
    cli.add_serve_args(p)
    args = p.parse_args()
    print("note: `python -m repro.launch.serve` is deprecated; "
          "use `python -m repro serve`", file=sys.stderr)

    from repro.api import Session
    session = Session.from_arch(args.arch, smoke=not args.full)
    try:
        rep = session.serve(args.tokens, batch=args.batch,
                            prompt_len=args.prompt_len,
                            temperature=args.temperature, seed=args.seed)
    except ValueError as e:  # e.g. encoder-only arch has no decode path
        raise SystemExit(f"error: {e}")
    print(f"arch={args.arch} batch={rep.batch} "
          f"prefill {rep.prompt_len} tok in {rep.prefill_seconds:.2f}s; "
          f"decode {rep.tokens_generated} tok in {rep.decode_seconds:.2f}s "
          f"({rep.tokens_per_second:.1f} tok/s)")
    print("sample tokens:", rep.sample_tokens)


if __name__ == "__main__":
    main()
