"""DEPRECATED training launcher — prefer ``python -m repro train``.

Kept as a thin shim over `repro.api.Session` so existing invocations of
``python -m repro.launch.train --arch qwen3-1.7b --steps 50`` keep working;
all argument wiring lives in `repro.launch.cli` and the run itself in the
Session facade.
"""
from __future__ import annotations

import sys

from repro.core.trainer import MembershipEvent
from repro.launch import cli


def main() -> None:
    p = cli.make_parser("repro.launch.train",
                        "DEPRECATED: use `python -m repro train`")
    cli.add_arch_arg(p, required=True)
    cli.add_scale_args(p)
    cli.add_batch_args(p)
    cli.add_train_args(p)
    args = p.parse_args()
    print("note: `python -m repro.launch.train` is deprecated; "
          "use `python -m repro train`", file=sys.stderr)

    session = cli.session_from_args(args)
    if session.cfg.family == "audio":
        print("note: encoder arch trains masked-prediction on frame stubs")
    events = []
    if args.revoke_at and args.members > 1:
        events.append(MembershipEvent(step=args.revoke_at, kind="revoke",
                                      member_id=args.members - 1))
    rep = session.train(args.steps, global_batch=args.global_batch,
                        seq_len=args.seq, members=args.members,
                        events=events, checkpoint_dir=args.checkpoint_dir)
    if session.bus.of_kind("restore"):
        print(f"resumed from checkpoint at step "
              f"{session.bus.of_kind('restore')[0].payload['step']}")
    print(f"arch={args.arch} steps={rep.steps_run} "
          f"loss {rep.losses[0]:.3f}->{rep.losses[-1]:.3f} "
          f"speed={rep.speed or 0:.2f} steps/s epochs={rep.epochs} "
          f"checkpoints={rep.checkpoints}")


if __name__ == "__main__":
    main()
