"""Training launcher: `--arch <id>` + run hyperparameters -> transient-aware
elastic training with checkpointing, profiling and bottleneck detection.

On this CPU container it trains the REDUCED (smoke) config by default;
`--full` selects the production config (for real TPU pods).

PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 50
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, RunConfig, get_config
from repro.core.trainer import MembershipEvent, TransientTrainer
from repro.data.pipeline import ShardedLoader, SyntheticTokenSource
from repro.dist.elastic import Member


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-interval", type=int, default=20)
    ap.add_argument("--members", type=int, default=2)
    ap.add_argument("--revoke-at", type=int, default=0,
                    help="inject a revocation at this step (0 = none)")
    ap.add_argument("--master-weights", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="production config (TPU-sized)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    if cfg.family == "audio":
        print("note: encoder arch trains masked-prediction on frame stubs")
    run = RunConfig(optimizer=args.optimizer, lr=args.lr,
                    warmup_steps=max(1, args.steps // 10),
                    total_steps=args.steps,
                    checkpoint_interval=args.checkpoint_interval,
                    checkpoint_dir=args.checkpoint_dir, zero1=False,
                    master_weights=args.master_weights)
    if cfg.family == "audio":
        from repro.models import api

        class AudioSource:
            def __init__(self, cfg, seq):
                self.cfg, self.seq = cfg, seq

            def batch(self, step, shard, n_shards, per):
                import numpy as np
                rng = np.random.default_rng((step, shard))
                return {
                    "features": rng.normal(
                        0, 1, (per, self.seq, self.cfg.frontend_dim)
                    ).astype(np.float32),
                    "labels": rng.integers(
                        0, self.cfg.vocab_size, (per, self.seq)
                    ).astype(np.int32),
                }
        src = AudioSource(cfg, args.seq)
    else:
        src = SyntheticTokenSource(cfg.vocab_size, args.seq)
    trainer = TransientTrainer(cfg, run, ShardedLoader(src, args.global_batch),
                               members=[Member(i) for i in range(args.members)])
    state, start = trainer.restore_or_init()
    if start:
        print(f"resumed from checkpoint at step {start}")
    events = []
    if args.revoke_at and args.members > 1:
        events.append(MembershipEvent(step=args.revoke_at, kind="revoke",
                                      member_id=args.members - 1))
    state, rep = trainer.run_steps(state, args.steps, events=events)
    print(f"arch={args.arch} steps={rep.steps_run} "
          f"loss {rep.losses[0]:.3f}->{rep.losses[-1]:.3f} "
          f"speed={rep.speed or 0:.2f} steps/s epochs={rep.epochs} "
          f"checkpoints={rep.checkpoints}")


if __name__ == "__main__":
    main()
