"""Minimal optimizer library (no optax in container): SGD / momentum / Adam /
AdamW with gradient clipping; optimizer state mirrors the param pytree so it
shards with the same rules (and can be ZeRO-1 sharded over the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def _sched(lr) -> Callable[[jnp.ndarray], jnp.ndarray]:
    return lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))


def sgd(lr) -> Optimizer:
    lr_fn = _sched(lr)

    def init(params):
        return ()

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        new = jax.tree.map(lambda p, g: p - lr_t * g.astype(p.dtype),
                           params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9) -> Optimizer:
    lr_fn = _sched(lr)

    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        m = jax.tree.map(lambda m_, g: beta * m_ + g.astype(m_.dtype),
                         state["m"], grads)
        new = jax.tree.map(lambda p, m_: p - lr_t * m_, params, m)
        return new, {"m": m}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, master: bool = False) -> Optimizer:
    """Adam/AdamW. With master=True the live params are bf16 (so gradients —
    and their data-axis all-reduce — are bf16, HALVING collective bytes) and
    an fp32 master copy lives in the optimizer state (ZeRO-1-shardable)."""
    lr_fn = _sched(lr)

    def init(params):
        st = {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }
        if master:
            st["w32"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return st

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v, w32):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            mhat = m / c1
            vhat = v / c2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            src = w32 if w32 is not None else p.astype(jnp.float32)
            if weight_decay:
                step_ = step_ + weight_decay * src
            new32 = src - lr_t * step_
            return new32.astype(p.dtype), m, v, new32

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_w = (treedef.flatten_up_to(state["w32"]) if master
                  else [None] * len(flat_p))
        out = [upd(p, g, m, v, w) for p, g, m, v, w in
               zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_state = {"m": treedef.unflatten([o[1] for o in out]),
                     "v": treedef.unflatten([o[2] for o in out])}
        if master:
            new_state["w32"] = treedef.unflatten([o[3] for o in out])
        return new_p, new_state

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, master: bool = False) -> Optimizer:
    return adam(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
                master=master)


def make_optimizer(name: str, lr, weight_decay: float = 0.0,
                   master: bool = False) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr)
    if name == "adam":
        return adam(lr, master=master)
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay, master=master)
    raise KeyError(name)
