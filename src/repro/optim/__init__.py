from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adam, adamw, make_optimizer, momentum, sgd, global_norm,
    clip_by_global_norm,
)
from repro.optim.schedules import constant, cosine_warmup, linear_warmup  # noqa: F401
