"""§V-B — transient server startup time: provisioning → staging → running
stages (Fig 6), revocation-adjacency effects (Fig 7).

Calibrated to the paper's findings: total < 100 s; transient slower than
on-demand by ~11 s (K80) / ~21 s (P100); staging dominates the K80/P100 gap;
immediate-after-revocation requests have ~4x the variance but the same mean.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

# (provision_mean, staging_mean, running_mean) seconds, transient servers
_STAGE_MEANS: Dict[str, Tuple[float, float, float]] = {
    "k80": (21.0, 38.0, 14.0),      # longer, more variable staging
    "p100": (23.0, 44.5, 14.0),     # ~8.7% slower overall than k80
    "v100": (24.0, 46.0, 14.0),
    "v5e": (30.0, 55.0, 20.0),      # TPU slice analogue
}
_ONDEMAND_DISCOUNT = {"k80": 11.14, "p100": 21.38, "v100": 21.0, "v5e": 25.0}
BASE_COV = 0.03
#: 4x higher CoV right after a revocation (Fig 7) — shared with the
#: batched engine's pre-drawn delay pools (fleet_batched.FleetDraws)
POST_REVOCATION_COV = 0.12


@dataclasses.dataclass
class StartupModel:
    """Per-stage startup sampler; `provider` selects whose stage-mean table
    is used (the default is the paper's GCP calibration, bit-for-bit)."""
    seed: int = 0
    provider: object = "gcp"

    def __post_init__(self):
        from repro.providers import get_provider
        self.rng = np.random.default_rng(self.seed)
        self.provider = get_provider(self.provider)

    def stage_means(self, gpu: str, transient: bool = True):
        return self.provider.startup_stages(gpu).means(transient)

    def mean_total(self, gpu: str, transient: bool = True) -> float:
        return float(sum(self.stage_means(gpu, transient)))

    def sample(self, gpu: str, transient: bool = True,
               after_revocation: bool = False) -> Dict[str, float]:
        cov = POST_REVOCATION_COV if after_revocation else BASE_COV
        out = {}
        for name, mean in zip(("provisioning", "staging", "running"),
                              self.stage_means(gpu, transient)):
            out[name] = float(max(1.0, self.rng.normal(mean, cov * mean)))
        out["total"] = sum(out.values())
        return out
