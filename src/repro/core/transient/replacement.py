"""§V-D/E — worker replacement overhead (cold vs warm start, Fig 10) and the
stock-framework recomputation pathology (Fig 11).

Cold start = new server: framework start + join + dataset download + graph
setup. Warm start = existing server rejoining: framework restart only.
Both grow with model complexity (graph-setup dominated). The recomputation
overhead of re-using the revoked chief's identity is bounded by the
checkpoint interval; CM-DARE's handover removes it (core/checkpoint lease).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

# Fig 10 anchors (seconds) for ResNet-15 and Shake-Shake-Big on K80
_COLD_BASE = 75.6
_WARM_BASE = 14.8
_COMPLEXITY_SLOPE = 0.72   # s per GFLOP of model complexity (graph setup)


@dataclasses.dataclass
class ReplacementModel:
    """Rejoin-time sampler; `provider` selects whose cold/warm anchors are
    used (the default is the paper's Fig 10 GCP calibration)."""
    seed: int = 0
    provider: object = "gcp"

    def __post_init__(self):
        from repro.providers import get_provider
        self.rng = np.random.default_rng(self.seed)
        self._anchors = get_provider(self.provider).replacement_anchors()

    def cold_start_s(self, c_m_gflops: float) -> float:
        return self._anchors.cold_start_s(c_m_gflops)

    def warm_start_s(self, c_m_gflops: float) -> float:
        return self._anchors.warm_start_s(c_m_gflops)

    def sample(self, c_m_gflops: float, cold: bool = True) -> float:
        mean = (self.cold_start_s if cold else self.warm_start_s)(c_m_gflops)
        return float(max(1.0, self.rng.normal(mean, 0.05 * mean)))


def recomputation_overhead_s(steps_since_checkpoint: int,
                             cluster_speed_steps_per_s: float,
                             reuse_chief_identity: bool) -> float:
    """Fig 11: stock TF discards progress since the last checkpoint when the
    replacement inherits the chief identity; with CM-DARE-style handover the
    overhead is 0 (another worker already holds the checkpoint lease)."""
    if not reuse_chief_identity:
        return 0.0
    return steps_since_checkpoint / max(cluster_speed_steps_per_s, 1e-9)
