"""Discrete-event transient-fleet simulator — the stand-in for the paper's
cloud measurement fleet (docs/DESIGN.md §2). Drives training-loop simulations:
revocations (per region/GPU/time-of-day), replacement startup, PS bottleneck,
checkpoint overhead — everything Eq (4) predicts, so predicted-vs-simulated
error is a meaningful §VI-A validation.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.perf_model.cluster_model import (PSBottleneckModel, WorkerSpec,
                                                 cluster_speed)
from repro.core.transient.replacement import ReplacementModel
from repro.core.transient.revocation import RevocationSampler
from repro.core.transient.startup import StartupModel


@dataclasses.dataclass(order=True)
class FleetEvent:
    t: float
    kind: str = dataclasses.field(compare=False)
    payload: dict = dataclasses.field(compare=False, default_factory=dict)


@dataclasses.dataclass
class SimWorker:
    wid: int
    gpu: str
    region: str
    speed: float           # steps/s on the target model
    alive: bool = True
    is_chief: bool = False
    #: launch-roster slot this worker (or its replacement chain) occupies;
    #: chaos straggler faults target slots, not wids
    slot: int = -1


@dataclasses.dataclass
class SimResult:
    total_time_s: float
    steps_done: int
    revocations: int
    replacements: int
    checkpoint_time_s: float
    recompute_time_s: float
    lost_steps: float
    events: List[Tuple[float, str]]
    monetary_cost: float
    provider: str = "gcp"
    region: str = ""
    #: quorum-pause wall-clock (resilience degradation; docs/resilience.md)
    paused_s: float = 0.0
    #: restore-retry stall wall-clock after stock-chief revocations
    restore_delay_s: float = 0.0


def _percentiles(xs: List[float]) -> Tuple[float, float, float]:
    a = np.asarray(xs, float)
    return (float(np.percentile(a, 50)), float(np.percentile(a, 90)),
            float(a.mean()))


@dataclasses.dataclass
class SimStats:
    """Distribution summary of a `FleetEnsemble` (§VI-A, beyond the paper's
    single-trajectory validation): p50/p90/mean of wall-clock, cost and
    revocations across trajectories, plus the standard error of the means.

    `finished` counts trajectories that completed every requested step;
    when `finished < n` the rest were censored (hit `max_hours`, or died
    with `replace=False`), so the time/cost percentiles understate the
    true distribution — check it before trusting the summary."""
    n: int
    time_p50_s: float
    time_p90_s: float
    time_mean_s: float
    time_stderr_s: float
    cost_p50: float
    cost_p90: float
    cost_mean: float
    cost_stderr: float
    revocations_p50: float
    revocations_p90: float
    revocations_mean: float
    replacements_mean: float
    finished: int = 0
    revocations_stderr: float = 0.0

    @classmethod
    def from_results(cls, results: List["SimResult"],
                     total_steps: Optional[int] = None) -> "SimStats":
        times = [r.total_time_s for r in results]
        costs = [r.monetary_cost for r in results]
        revs = [float(r.revocations) for r in results]
        n = len(results)
        finished = (n if total_steps is None else
                    sum(1 for r in results if r.steps_done >= total_steps))
        t50, t90, tm = _percentiles(times)
        c50, c90, cm = _percentiles(costs)
        r50, r90, rm = _percentiles(revs)

        def sem(xs):  # unbiased (ddof=1) standard error of the mean
            if n <= 1:
                return 0.0
            return float(np.std(xs, ddof=1)) / math.sqrt(n)

        return cls(n, t50, t90, tm, sem(times),
                   c50, c90, cm, sem(costs),
                   r50, r90, rm,
                   float(np.mean([r.replacements for r in results])),
                   finished=finished, revocations_stderr=sem(revs))


@dataclasses.dataclass
class FleetEnsemble:
    """`FleetSim.run_many` output: every trajectory plus summary stats."""
    results: List[SimResult]
    stats: SimStats
    provider: str = "gcp"
    region: str = ""

    def __len__(self) -> int:
        return len(self.results)


class FleetSim:
    """Simulate one training run on a transient cluster.

    Policies: `replace` (request a new transient server on revocation),
    `handover` (CM-DARE checkpoint-lease handover vs stock chief-IP restart).
    `provider` selects the market (revocation/startup/replacement laws from
    `repro.providers`); with a provider whose revocation notice is long
    enough to flush a checkpoint (`graceful_checkpoint_on_warning` and
    `warning_seconds >= T_c`, e.g. AWS's 2-minute notice), a revoked chief
    checkpoints before dying, so stock identity-reuse loses no steps.

    `n_tensors` / `grad_compression` feed the Fig 4 PS capacity term the
    same way `Session.predict` does (§VI-B): the network share of the PS
    service time shrinks by `compression_ratio(scheme)` while the
    per-tensor RPC share stays, so predicted-vs-simulated error is
    meaningful for compressed runs too. Defaults reproduce the historic
    uncompressed, RPC-free capacity model.
    """

    def __init__(self, workers: List[SimWorker], *, model_gflops: float,
                 model_bytes: float, step_speed_of: Callable[[str], float],
                 checkpoint_interval_steps: int, checkpoint_time_s: float,
                 n_ps: int = 1, seed: int = 0, replace: bool = True,
                 handover: bool = True, price_of: Optional[Dict] = None,
                 provider: object = "gcp", n_tensors: int = 0,
                 grad_compression: str = "none", chaos: object = None,
                 resilience: object = None):
        from repro.providers import get_provider
        self.workers = {w.wid: w for w in workers}
        if workers:
            workers[0].is_chief = True
        for idx, w in enumerate(workers):
            w.slot = idx
        # immutable launch roster, so `run_many` can respawn trajectories
        # after `run` has mutated self.workers
        self._roster = tuple((w.wid, w.gpu, w.region, w.speed)
                             for w in workers)
        self.model_gflops = model_gflops
        self.model_bytes = model_bytes
        self.speed_of = step_speed_of
        self.i_c = checkpoint_interval_steps
        self.t_c = checkpoint_time_s
        self.n_ps = n_ps
        self.n_tensors = n_tensors
        self.grad_compression = grad_compression
        self.replace = replace
        self.handover = handover
        self.provider = get_provider(provider)
        self.seed = seed
        self.rev = RevocationSampler(seed, self.provider)
        self.startup = StartupModel(seed + 1, self.provider)
        self.repl = ReplacementModel(seed + 2, self.provider)
        self.rng = np.random.default_rng(seed + 3)
        self.price_of = price_of or {}
        # a chaos.FaultTimeline compiled against this roster (or None):
        # hazard faults transform the FleetDraws lifetime streams, while
        # speed/PS/ckpt faults make the cluster piecewise-time-varying
        self.chaos = chaos
        # a repro.resilience.ResilienceConfig (or None): quorum-tier
        # degradation gates effective speed, and stock-chief restores
        # stall for the keyed retry schedule — honored identically by
        # all three engines (docs/resilience.md)
        self.resilience = resilience

    def _respawn(self, seed: int) -> "FleetSim":
        """A fresh simulator over the same launch roster and physics, with
        its own seed — one ensemble trajectory."""
        workers = [SimWorker(wid, gpu, region, speed)
                   for wid, gpu, region, speed in self._roster]
        return FleetSim(workers, model_gflops=self.model_gflops,
                        model_bytes=self.model_bytes,
                        step_speed_of=self.speed_of,
                        checkpoint_interval_steps=self.i_c,
                        checkpoint_time_s=self.t_c, n_ps=self.n_ps,
                        seed=seed, replace=self.replace,
                        handover=self.handover, price_of=self.price_of,
                        provider=self.provider, n_tensors=self.n_tensors,
                        grad_compression=self.grad_compression,
                        chaos=self.chaos, resilience=self.resilience)

    def _cluster_speed(self, t: Optional[float] = None) -> float:
        """Cluster steps/s; with a chaos timeline and a sim clock `t`,
        straggler multipliers and the PS capacity factor at `t` apply
        (factors are constant within any span the run loop advances —
        chaos boundaries are scheduled as events)."""
        if self.chaos is None or t is None:
            alive = [WorkerSpec(w.gpu, w.speed)
                     for w in self.workers.values() if w.alive]
            if not alive:
                return 0.0
            ps = PSBottleneckModel(self.model_bytes, self.n_ps,
                                   n_tensors=self.n_tensors,
                                   compression=self.grad_compression)
            return cluster_speed(alive, ps)
        alive = [w for w in self.workers.values() if w.alive]
        if not alive:
            return 0.0
        ts = np.array([t])
        mults = self.chaos.speed_mults(ts)[0]
        raw = sum(w.speed * (mults[w.slot] if 0 <= w.slot < mults.size
                             else 1.0) for w in alive)
        ps = PSBottleneckModel(self.model_bytes, self.n_ps,
                               n_tensors=self.n_tensors,
                               compression=self.grad_compression)
        capacity = (ps.capacity_steps_per_s()
                    * float(self.chaos.ps_factor(ts)[0]))
        return min(raw, capacity)

    def run(self, total_steps: int, max_hours: float = 48.0,
            start_hour: float = 0.0, *,
            initial_lifetimes: Optional[Sequence[float]] = None,
            draws: Optional[object] = None, traj: int = 0) -> SimResult:
        """`start_hour`: local launch hour, so diurnal lifetime laws (GCP
        Fig 9, AWS price signal) see the planned launch cell.
        `initial_lifetimes`: pre-drawn lifetimes (hours, launch-roster
        order, np.inf = survived) — `run_many` injects one batched draw
        per trajectory; the default draws from `self.rev` as before.
        `draws` (a `fleet_batched.FleetDraws`) + `traj` switch every
        replacement-chain draw (startup, cold start, join lifetime) onto
        the counter-based per-(trajectory, slot, generation) streams the
        batched engine consumes, making this event loop the exact parity
        oracle for `run_many(engine="batched")`; the default `None`
        keeps the historic sequential streams bit-for-bit."""
        if self.chaos is not None and draws is None:
            # standalone chaos run: route all randomness through the
            # shared-draws streams (n=1), so hazard-transformed lifetimes
            # are identical to run_many(n=1) on either engine
            from repro.core.transient.fleet_batched import FleetDraws
            draws = FleetDraws(self, 1, start_hour)
            traj = 0
            if initial_lifetimes is None:
                initial_lifetimes = draws.initial[0]
        q: List[FleetEvent] = []
        next_wid = max(self.workers) + 1
        # wid -> (roster slot, generation) for the shared-draws contract
        slot_of: Dict[int, Tuple[int, int]] = {
            w.wid: (idx, 0) for idx, w in enumerate(self.workers.values())}
        # resilience (docs/resilience.md): restore-retry stalls keyed on
        # (seed, traj, slot, gen) — through the shared draws when present
        # (parity with the batched/jit engines), else a local n=1 pool
        res = self.resilience
        n_slots = len(self._roster)
        if res is not None and res.restore_fail_p > 0.0:
            from repro.resilience.policy import stall_pool
            _local_stalls: Dict[int, np.ndarray] = {}

            def restore_stall(slot: int, gen: int) -> float:
                if draws is not None:
                    return draws.restore_stall(res, traj, slot, gen)
                pool = _local_stalls.get(gen)
                if pool is None:
                    pool = _local_stalls[gen] = stall_pool(
                        res, self.seed, 1, n_slots, gen)
                return float(pool[0, slot])
        else:
            restore_stall = None

        def degr_factor() -> float:
            if res is None:
                return 1.0
            n_alive = sum(1 for w in self.workers.values() if w.alive)
            return res.degradation.speed_factor(n_alive, n_slots)
        # schedule revocations
        for idx, w in enumerate(self.workers.values()):
            lt = (float(initial_lifetimes[idx])
                  if initial_lifetimes is not None
                  else self.rev.lifetime(w.region, w.gpu,
                                         start_hour=start_hour))
            if math.isfinite(lt):
                heapq.heappush(q, FleetEvent(lt * 3600.0, "revoke",
                                             {"wid": w.wid}))
        if self.chaos is not None:
            # factor-change instants as no-op events: `advance` spans then
            # never cross a speed/PS/ckpt change, so its constant-speed
            # piecewise walk stays exact under faults
            for b in self.chaos.boundaries_s:
                if b < max_hours * 3600.0:
                    heapq.heappush(q, FleetEvent(float(b), "chaos"))
        t = 0.0
        steps = 0.0
        last_ckpt_step = 0
        ckpt_time = recompute = lost = 0.0
        paused_s = restore_s = 0.0
        stall_until = 0.0
        revocations = replacements = 0
        events: List[Tuple[float, str]] = []
        gpu_seconds: Dict[str, float] = {}

        def advance(to_t: float):
            """Advance wall-clock to `to_t`, producing steps at the current
            cluster speed with SEQUENTIAL checkpoint pauses (§IV-B) at every
            i_c boundary — exact piecewise simulation, no Zeno refinement."""
            nonlocal steps, t, ckpt_time, last_ckpt_step, paused_s, restore_s
            sp = self._cluster_speed(t)
            span = to_t - t
            for w in self.workers.values():
                if w.alive:
                    gpu_seconds[w.gpu] = gpu_seconds.get(w.gpu, 0.0) + span
            remaining = span
            blocked = (self.chaos is not None
                       and bool(self.chaos.ckpt_blocked(np.array([t]))[0]))
            if res is not None:
                # stall/pause gating: spans never cross a stall end (the
                # "resume" heap entry) or a membership event, so both
                # conditions are constant within this segment
                stalled = t < stall_until
                factor = degr_factor()
                if stalled:
                    restore_s += span
                elif factor == 0.0:
                    paused_s += span
                sp = 0.0 if stalled else sp * factor
            if sp > 0:
                if blocked:
                    # checkpoint-store outage: steps keep flowing but no
                    # save happens — no pause, and last_ckpt_step freezes
                    steps += sp * remaining
                    remaining = 0.0
                while remaining > 1e-12:
                    to_boundary = self.i_c - (steps % self.i_c)
                    if to_boundary <= 1e-9:
                        to_boundary = self.i_c
                    dt_needed = to_boundary / sp
                    if dt_needed <= remaining:
                        steps += to_boundary
                        remaining -= dt_needed
                        pause = min(self.t_c, remaining)
                        ckpt_time += pause
                        remaining -= pause
                        last_ckpt_step = int(round(steps))
                    else:
                        steps += sp * remaining
                        remaining = 0.0
            t = to_t

        def time_to_finish() -> float:
            """Wall-clock needed to reach total_steps from (steps, t),
            including future checkpoint pauses. Projects the *current*
            conditions forward — a pending chaos boundary is an event, so
            the projection is recomputed whenever conditions change."""
            sp = self._cluster_speed(t)
            if res is not None:
                sp = 0.0 if t < stall_until else sp * degr_factor()
            if sp <= 0:
                return float("inf")
            remaining_steps = total_steps - steps
            if (self.chaos is not None
                    and bool(self.chaos.ckpt_blocked(np.array([t]))[0])):
                return remaining_steps / sp
            n_ckpts = int(total_steps // self.i_c) - int(steps // self.i_c)
            return remaining_steps / sp + n_ckpts * self.t_c

        while steps < total_steps - 1e-6 and t < max_hours * 3600.0:
            sp = self._cluster_speed(t)
            if res is not None:
                sp = 0.0 if t < stall_until else sp * degr_factor()
            if sp <= 0.0 and not q:
                break
            t_finish = t + time_to_finish()
            if q and q[0].t < t_finish:
                ev = heapq.heappop(q)
                advance(max(ev.t, t))
                if ev.kind == "revoke":
                    w = self.workers.get(ev.payload["wid"])
                    if w is None or not w.alive:
                        continue
                    w.alive = False
                    revocations += 1
                    events.append((t, f"revoke w{w.wid} ({w.gpu})"))
                    if w.is_chief:
                        if self.handover:
                            # lease handover: another worker checkpoints
                            for o in self.workers.values():
                                if o.alive:
                                    o.is_chief = True
                                    break
                            events.append((t, "chief handover (no recompute)"))
                        elif (self.provider.graceful_checkpoint_on_warning
                                and self.provider.warning_seconds >= self.t_c):
                            # the market's revocation notice is long enough
                            # for the chief to flush a checkpoint before
                            # dying: nothing to recompute even without
                            # lease handover. The write overlaps the notice
                            # window (wall-clock already counted), so it
                            # does NOT accrue checkpoint pause time.
                            last_ckpt_step = int(round(steps))
                            events.append(
                                (t, "warning checkpoint (no recompute)"))
                        else:
                            # stock behavior: recompute from last checkpoint
                            lost_now = steps - last_ckpt_step
                            steps = float(last_ckpt_step)
                            lost += lost_now
                            # raw cluster speed on purpose: recompute runs
                            # once the fleet recovers, so the quorum gate
                            # does not inflate its conversion
                            rec = lost_now / max(self._cluster_speed(t), 1e-9)
                            recompute += rec
                            events.append(
                                (t, f"chief lost: recompute {lost_now:.0f} steps"))
                            if restore_stall is not None:
                                # restore-retry stall, keyed on the revoked
                                # occupant's generation (before the
                                # replacement bumps it); a later stall
                                # overwrites an active one
                                r_slot, r_gen = slot_of[w.wid]
                                delay = restore_stall(r_slot, r_gen)
                                stall_until = t + delay
                                if delay > 0.0:
                                    heapq.heappush(q, FleetEvent(
                                        stall_until, "resume"))
                                    events.append(
                                        (t, f"restore retries: stall "
                                            f"{delay:.1f}s"))
                    if self.replace:
                        slot, gen = slot_of[w.wid]
                        if draws is not None:
                            delay = draws.replacement_delay(
                                traj, slot, gen + 1)
                        else:
                            su = self.startup.sample(w.gpu,
                                                     after_revocation=True)
                            delay = su["total"] + self.repl.sample(
                                self.model_gflops, cold=True)
                        ready = t + delay
                        # stock mode (Fig 11): the replacement inherits the
                        # revoked chief's identity, so later chief
                        # revocations keep costing recompute; with handover
                        # a survivor was already promoted above
                        heapq.heappush(q, FleetEvent(
                            ready, "join",
                            {"gpu": w.gpu, "region": w.region,
                             "speed": w.speed, "slot": slot, "gen": gen + 1,
                             "chief": w.is_chief and not self.handover}))
                elif ev.kind == "chaos":
                    # factor-change boundary: advancing to it was the work
                    events.append((t, "chaos boundary"))
                elif ev.kind == "resume":
                    # restore-retry stall end: advancing to it was the work
                    events.append((t, "restore retries complete"))
                elif ev.kind == "join":
                    w = SimWorker(next_wid, ev.payload["gpu"],
                                  ev.payload["region"], ev.payload["speed"],
                                  is_chief=ev.payload.get("chief", False),
                                  slot=ev.payload.get("slot", -1))
                    next_wid += 1
                    self.workers[w.wid] = w
                    slot_of[w.wid] = (ev.payload.get("slot", -1),
                                      ev.payload.get("gen", 0))
                    replacements += 1
                    events.append((t, f"join w{w.wid} ({w.gpu})"))
                    if draws is not None:
                        slot, gen = slot_of[w.wid]
                        lt = draws.join_lifetime(
                            traj, slot, gen, start_hour + t / 3600.0)
                    else:
                        lt = self.rev.lifetime(
                            w.region, w.gpu,
                            start_hour=start_hour + t / 3600.0)
                    if math.isfinite(lt):
                        heapq.heappush(q, FleetEvent(
                            t + lt * 3600.0, "revoke", {"wid": w.wid}))
            else:
                advance(t_finish)

        cost = sum(secs / 3600.0 * self.price_of.get(g, 0.0)
                   for g, secs in gpu_seconds.items())
        regions = {w.region for w in self.workers.values()}
        # steps accumulates float increments, so a completed run can sit
        # an ulp below total_steps — the same epsilon the batched engine
        # applies keeps steps_done (and SimStats.finished) truthful
        return SimResult(t, int(steps + 1e-6), revocations, replacements,
                         ckpt_time, recompute, lost, events, cost,
                         provider=self.provider.name,
                         region=regions.pop() if len(regions) == 1 else "",
                         paused_s=paused_s, restore_delay_s=restore_s)

    def run_many(self, total_steps: int, n: int, max_hours: float = 48.0,
                 start_hour: float = 0.0, *,
                 engine: str = "batched") -> FleetEnsemble:
        """Simulate `n` independent trajectories of the same launch.

        All randomness comes from one `fleet_batched.FleetDraws`: initial
        lifetimes are pre-drawn as a single (n, slots) matrix (one batched
        `RevocationSampler.lifetimes` call per (region, gpu) roster group,
        seeded with `self.seed` — the scheme this method has always used),
        and replacement-chain draws come from counter-based streams keyed
        on (seed, trajectory, slot, generation). Both engines therefore
        simulate the *same* trajectories:

        * ``engine="batched"`` (default) — the lockstep array engine
          (`fleet_batched.run_batched`): all trajectories advance
          simultaneously, next events found by vectorized min-reductions.
        * ``engine="event"`` — the per-trajectory discrete-event loop
          (`run`), kept as the parity oracle; identical
          revocation/replacement counts, times equal up to float
          association order.
        * ``engine="jit"`` — the same lockstep rounds compiled into one
          jitted JAX program (`fleet_jit.run_jit`): state on device,
          draws pre-materialized, trajectories sharded across visible
          devices. Same parity contract; requires a provider whose
          lifetime law has a jittable port (gcp/aws/azure).

        `run(...)` with the same seed remains the single-trajectory path;
        `run_many` never perturbs its streams.
        """
        from repro.core.transient.fleet_batched import FleetDraws, run_batched
        if n < 1:
            raise ValueError(f"need at least one trajectory, got {n}")
        if engine not in ("batched", "event", "jit"):
            raise ValueError(f"unknown engine {engine!r}; "
                             f"known: ('batched', 'event', 'jit')")
        draws = FleetDraws(self, n, start_hour)
        if engine == "batched":
            results = run_batched(self, total_steps, n, max_hours,
                                  start_hour, draws=draws)
        elif engine == "jit":
            from repro.core.transient.fleet_jit import run_jit
            results = run_jit(self, total_steps, n, max_hours,
                              start_hour, draws=draws)
        else:
            results = []
            for j in range(n):
                sim = self._respawn(self.seed + 1 + 4 * j)
                results.append(sim.run(total_steps, max_hours, start_hour,
                                       initial_lifetimes=draws.initial[j],
                                       draws=draws, traj=j))
        regions = {r.region for r in results}
        return FleetEnsemble(results,
                             SimStats.from_results(results, total_steps),
                             provider=self.provider.name,
                             region=regions.pop() if len(regions) == 1
                             else "")


#: Long-form alias used by the docs and the provider layer.
FleetSimulator = FleetSim
