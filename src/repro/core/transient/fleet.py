"""Discrete-event transient-fleet simulator — the stand-in for the paper's
cloud measurement fleet (docs/DESIGN.md §2). Drives training-loop simulations:
revocations (per region/GPU/time-of-day), replacement startup, PS bottleneck,
checkpoint overhead — everything Eq (4) predicts, so predicted-vs-simulated
error is a meaningful §VI-A validation.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.perf_model.cluster_model import (PSBottleneckModel, WorkerSpec,
                                                 cluster_speed)
from repro.core.transient.replacement import ReplacementModel
from repro.core.transient.revocation import RevocationSampler
from repro.core.transient.startup import StartupModel


@dataclasses.dataclass(order=True)
class FleetEvent:
    t: float
    kind: str = dataclasses.field(compare=False)
    payload: dict = dataclasses.field(compare=False, default_factory=dict)


@dataclasses.dataclass
class SimWorker:
    wid: int
    gpu: str
    region: str
    speed: float           # steps/s on the target model
    alive: bool = True
    is_chief: bool = False


@dataclasses.dataclass
class SimResult:
    total_time_s: float
    steps_done: int
    revocations: int
    replacements: int
    checkpoint_time_s: float
    recompute_time_s: float
    lost_steps: float
    events: List[Tuple[float, str]]
    monetary_cost: float
    provider: str = "gcp"
    region: str = ""


class FleetSim:
    """Simulate one training run on a transient cluster.

    Policies: `replace` (request a new transient server on revocation),
    `handover` (CM-DARE checkpoint-lease handover vs stock chief-IP restart).
    `provider` selects the market (revocation/startup/replacement laws from
    `repro.providers`); with a provider whose revocation notice is long
    enough to flush a checkpoint (`graceful_checkpoint_on_warning` and
    `warning_seconds >= T_c`, e.g. AWS's 2-minute notice), a revoked chief
    checkpoints before dying, so stock identity-reuse loses no steps.
    """

    def __init__(self, workers: List[SimWorker], *, model_gflops: float,
                 model_bytes: float, step_speed_of: Callable[[str], float],
                 checkpoint_interval_steps: int, checkpoint_time_s: float,
                 n_ps: int = 1, seed: int = 0, replace: bool = True,
                 handover: bool = True, price_of: Optional[Dict] = None,
                 provider: object = "gcp"):
        from repro.providers import get_provider
        self.workers = {w.wid: w for w in workers}
        if workers:
            workers[0].is_chief = True
        self.model_gflops = model_gflops
        self.model_bytes = model_bytes
        self.speed_of = step_speed_of
        self.i_c = checkpoint_interval_steps
        self.t_c = checkpoint_time_s
        self.n_ps = n_ps
        self.replace = replace
        self.handover = handover
        self.provider = get_provider(provider)
        self.rev = RevocationSampler(seed, self.provider)
        self.startup = StartupModel(seed + 1, self.provider)
        self.repl = ReplacementModel(seed + 2, self.provider)
        self.rng = np.random.default_rng(seed + 3)
        self.price_of = price_of or {}

    def _cluster_speed(self) -> float:
        alive = [WorkerSpec(w.gpu, w.speed)
                 for w in self.workers.values() if w.alive]
        if not alive:
            return 0.0
        ps = PSBottleneckModel(self.model_bytes, self.n_ps)
        return cluster_speed(alive, ps)

    def run(self, total_steps: int, max_hours: float = 48.0,
            start_hour: float = 0.0) -> SimResult:
        """`start_hour`: local launch hour, so diurnal lifetime laws (GCP
        Fig 9, AWS price signal) see the planned launch cell."""
        q: List[FleetEvent] = []
        next_wid = max(self.workers) + 1
        # schedule revocations
        for w in self.workers.values():
            lt = self.rev.lifetime(w.region, w.gpu, start_hour=start_hour)
            if math.isfinite(lt):
                heapq.heappush(q, FleetEvent(lt * 3600.0, "revoke",
                                             {"wid": w.wid}))
        t = 0.0
        steps = 0.0
        last_ckpt_step = 0
        ckpt_time = recompute = lost = 0.0
        revocations = replacements = 0
        events: List[Tuple[float, str]] = []
        gpu_seconds: Dict[str, float] = {}

        def advance(to_t: float):
            """Advance wall-clock to `to_t`, producing steps at the current
            cluster speed with SEQUENTIAL checkpoint pauses (§IV-B) at every
            i_c boundary — exact piecewise simulation, no Zeno refinement."""
            nonlocal steps, t, ckpt_time, last_ckpt_step
            sp = self._cluster_speed()
            span = to_t - t
            for w in self.workers.values():
                if w.alive:
                    gpu_seconds[w.gpu] = gpu_seconds.get(w.gpu, 0.0) + span
            remaining = span
            if sp > 0:
                while remaining > 1e-12:
                    to_boundary = self.i_c - (steps % self.i_c)
                    if to_boundary <= 1e-9:
                        to_boundary = self.i_c
                    dt_needed = to_boundary / sp
                    if dt_needed <= remaining:
                        steps += to_boundary
                        remaining -= dt_needed
                        pause = min(self.t_c, remaining)
                        ckpt_time += pause
                        remaining -= pause
                        last_ckpt_step = int(round(steps))
                    else:
                        steps += sp * remaining
                        remaining = 0.0
            t = to_t

        def time_to_finish() -> float:
            """Wall-clock needed to reach total_steps from (steps, t),
            including future checkpoint pauses."""
            sp = self._cluster_speed()
            if sp <= 0:
                return float("inf")
            remaining_steps = total_steps - steps
            n_ckpts = int(total_steps // self.i_c) - int(steps // self.i_c)
            return remaining_steps / sp + n_ckpts * self.t_c

        while steps < total_steps - 1e-6 and t < max_hours * 3600.0:
            sp = self._cluster_speed()
            if sp <= 0.0 and not q:
                break
            t_finish = t + time_to_finish()
            if q and q[0].t < t_finish:
                ev = heapq.heappop(q)
                advance(max(ev.t, t))
                if ev.kind == "revoke":
                    w = self.workers.get(ev.payload["wid"])
                    if w is None or not w.alive:
                        continue
                    w.alive = False
                    revocations += 1
                    events.append((t, f"revoke w{w.wid} ({w.gpu})"))
                    if w.is_chief:
                        if self.handover:
                            # lease handover: another worker checkpoints
                            for o in self.workers.values():
                                if o.alive:
                                    o.is_chief = True
                                    break
                            events.append((t, "chief handover (no recompute)"))
                        elif (self.provider.graceful_checkpoint_on_warning
                                and self.provider.warning_seconds >= self.t_c):
                            # the market's revocation notice is long enough
                            # for the chief to flush a checkpoint before
                            # dying: nothing to recompute even without
                            # lease handover. The write overlaps the notice
                            # window (wall-clock already counted), so it
                            # does NOT accrue checkpoint pause time.
                            last_ckpt_step = int(round(steps))
                            events.append(
                                (t, "warning checkpoint (no recompute)"))
                        else:
                            # stock behavior: recompute from last checkpoint
                            lost_now = steps - last_ckpt_step
                            steps = float(last_ckpt_step)
                            lost += lost_now
                            rec = lost_now / max(self._cluster_speed(), 1e-9)
                            recompute += rec
                            events.append(
                                (t, f"chief lost: recompute {lost_now:.0f} steps"))
                    if self.replace:
                        su = self.startup.sample(w.gpu, after_revocation=True)
                        cold = self.repl.sample(self.model_gflops, cold=True)
                        ready = t + su["total"] + cold
                        # stock mode (Fig 11): the replacement inherits the
                        # revoked chief's identity, so later chief
                        # revocations keep costing recompute; with handover
                        # a survivor was already promoted above
                        heapq.heappush(q, FleetEvent(
                            ready, "join",
                            {"gpu": w.gpu, "region": w.region,
                             "speed": w.speed,
                             "chief": w.is_chief and not self.handover}))
                elif ev.kind == "join":
                    w = SimWorker(next_wid, ev.payload["gpu"],
                                  ev.payload["region"], ev.payload["speed"],
                                  is_chief=ev.payload.get("chief", False))
                    next_wid += 1
                    self.workers[w.wid] = w
                    replacements += 1
                    events.append((t, f"join w{w.wid} ({w.gpu})"))
                    lt = self.rev.lifetime(w.region, w.gpu,
                                           start_hour=start_hour + t / 3600.0)
                    if math.isfinite(lt):
                        heapq.heappush(q, FleetEvent(
                            t + lt * 3600.0, "revoke", {"wid": w.wid}))
            else:
                advance(t_finish)

        cost = sum(secs / 3600.0 * self.price_of.get(g, 0.0)
                   for g, secs in gpu_seconds.items())
        regions = {w.region for w in self.workers.values()}
        return SimResult(t, int(steps), revocations, replacements, ckpt_time,
                         recompute, lost, events, cost,
                         provider=self.provider.name,
                         region=regions.pop() if len(regions) == 1 else "")


#: Long-form alias used by the docs and the provider layer.
FleetSimulator = FleetSim
