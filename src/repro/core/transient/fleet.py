"""Discrete-event transient-fleet simulator — the stand-in for the paper's
cloud measurement fleet (docs/DESIGN.md §2). Drives training-loop simulations:
revocations (per region/GPU/time-of-day), replacement startup, PS bottleneck,
checkpoint overhead — everything Eq (4) predicts, so predicted-vs-simulated
error is a meaningful §VI-A validation.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.perf_model.cluster_model import (PSBottleneckModel, WorkerSpec,
                                                 cluster_speed)
from repro.core.transient.replacement import ReplacementModel
from repro.core.transient.revocation import RevocationSampler
from repro.core.transient.startup import StartupModel


@dataclasses.dataclass(order=True)
class FleetEvent:
    t: float
    kind: str = dataclasses.field(compare=False)
    payload: dict = dataclasses.field(compare=False, default_factory=dict)


@dataclasses.dataclass
class SimWorker:
    wid: int
    gpu: str
    region: str
    speed: float           # steps/s on the target model
    alive: bool = True
    is_chief: bool = False


@dataclasses.dataclass
class SimResult:
    total_time_s: float
    steps_done: int
    revocations: int
    replacements: int
    checkpoint_time_s: float
    recompute_time_s: float
    lost_steps: float
    events: List[Tuple[float, str]]
    monetary_cost: float
    provider: str = "gcp"
    region: str = ""


def _percentiles(xs: List[float]) -> Tuple[float, float, float]:
    a = np.asarray(xs, float)
    return (float(np.percentile(a, 50)), float(np.percentile(a, 90)),
            float(a.mean()))


@dataclasses.dataclass
class SimStats:
    """Distribution summary of a `FleetEnsemble` (§VI-A, beyond the paper's
    single-trajectory validation): p50/p90/mean of wall-clock, cost and
    revocations across trajectories, plus the standard error of the means.

    `finished` counts trajectories that completed every requested step;
    when `finished < n` the rest were censored (hit `max_hours`, or died
    with `replace=False`), so the time/cost percentiles understate the
    true distribution — check it before trusting the summary."""
    n: int
    time_p50_s: float
    time_p90_s: float
    time_mean_s: float
    time_stderr_s: float
    cost_p50: float
    cost_p90: float
    cost_mean: float
    cost_stderr: float
    revocations_p50: float
    revocations_p90: float
    revocations_mean: float
    replacements_mean: float
    finished: int = 0

    @classmethod
    def from_results(cls, results: List["SimResult"],
                     total_steps: Optional[int] = None) -> "SimStats":
        times = [r.total_time_s for r in results]
        costs = [r.monetary_cost for r in results]
        revs = [float(r.revocations) for r in results]
        n = len(results)
        finished = (n if total_steps is None else
                    sum(1 for r in results if r.steps_done >= total_steps))
        t50, t90, tm = _percentiles(times)
        c50, c90, cm = _percentiles(costs)
        r50, r90, rm = _percentiles(revs)

        def sem(xs):  # unbiased (ddof=1) standard error of the mean
            if n <= 1:
                return 0.0
            return float(np.std(xs, ddof=1)) / math.sqrt(n)

        return cls(n, t50, t90, tm, sem(times),
                   c50, c90, cm, sem(costs),
                   r50, r90, rm,
                   float(np.mean([r.replacements for r in results])),
                   finished=finished)


@dataclasses.dataclass
class FleetEnsemble:
    """`FleetSim.run_many` output: every trajectory plus summary stats."""
    results: List[SimResult]
    stats: SimStats
    provider: str = "gcp"
    region: str = ""

    def __len__(self) -> int:
        return len(self.results)


class FleetSim:
    """Simulate one training run on a transient cluster.

    Policies: `replace` (request a new transient server on revocation),
    `handover` (CM-DARE checkpoint-lease handover vs stock chief-IP restart).
    `provider` selects the market (revocation/startup/replacement laws from
    `repro.providers`); with a provider whose revocation notice is long
    enough to flush a checkpoint (`graceful_checkpoint_on_warning` and
    `warning_seconds >= T_c`, e.g. AWS's 2-minute notice), a revoked chief
    checkpoints before dying, so stock identity-reuse loses no steps.
    """

    def __init__(self, workers: List[SimWorker], *, model_gflops: float,
                 model_bytes: float, step_speed_of: Callable[[str], float],
                 checkpoint_interval_steps: int, checkpoint_time_s: float,
                 n_ps: int = 1, seed: int = 0, replace: bool = True,
                 handover: bool = True, price_of: Optional[Dict] = None,
                 provider: object = "gcp"):
        from repro.providers import get_provider
        self.workers = {w.wid: w for w in workers}
        if workers:
            workers[0].is_chief = True
        # immutable launch roster, so `run_many` can respawn trajectories
        # after `run` has mutated self.workers
        self._roster = tuple((w.wid, w.gpu, w.region, w.speed)
                             for w in workers)
        self.model_gflops = model_gflops
        self.model_bytes = model_bytes
        self.speed_of = step_speed_of
        self.i_c = checkpoint_interval_steps
        self.t_c = checkpoint_time_s
        self.n_ps = n_ps
        self.replace = replace
        self.handover = handover
        self.provider = get_provider(provider)
        self.seed = seed
        self.rev = RevocationSampler(seed, self.provider)
        self.startup = StartupModel(seed + 1, self.provider)
        self.repl = ReplacementModel(seed + 2, self.provider)
        self.rng = np.random.default_rng(seed + 3)
        self.price_of = price_of or {}

    def _respawn(self, seed: int) -> "FleetSim":
        """A fresh simulator over the same launch roster and physics, with
        its own seed — one ensemble trajectory."""
        workers = [SimWorker(wid, gpu, region, speed)
                   for wid, gpu, region, speed in self._roster]
        return FleetSim(workers, model_gflops=self.model_gflops,
                        model_bytes=self.model_bytes,
                        step_speed_of=self.speed_of,
                        checkpoint_interval_steps=self.i_c,
                        checkpoint_time_s=self.t_c, n_ps=self.n_ps,
                        seed=seed, replace=self.replace,
                        handover=self.handover, price_of=self.price_of,
                        provider=self.provider)

    def _cluster_speed(self) -> float:
        alive = [WorkerSpec(w.gpu, w.speed)
                 for w in self.workers.values() if w.alive]
        if not alive:
            return 0.0
        ps = PSBottleneckModel(self.model_bytes, self.n_ps)
        return cluster_speed(alive, ps)

    def run(self, total_steps: int, max_hours: float = 48.0,
            start_hour: float = 0.0, *,
            initial_lifetimes: Optional[Sequence[float]] = None) -> SimResult:
        """`start_hour`: local launch hour, so diurnal lifetime laws (GCP
        Fig 9, AWS price signal) see the planned launch cell.
        `initial_lifetimes`: pre-drawn lifetimes (hours, launch-roster
        order, np.inf = survived) — `run_many` injects one batched draw
        per trajectory; the default draws from `self.rev` as before."""
        q: List[FleetEvent] = []
        next_wid = max(self.workers) + 1
        # schedule revocations
        for idx, w in enumerate(self.workers.values()):
            lt = (float(initial_lifetimes[idx])
                  if initial_lifetimes is not None
                  else self.rev.lifetime(w.region, w.gpu,
                                         start_hour=start_hour))
            if math.isfinite(lt):
                heapq.heappush(q, FleetEvent(lt * 3600.0, "revoke",
                                             {"wid": w.wid}))
        t = 0.0
        steps = 0.0
        last_ckpt_step = 0
        ckpt_time = recompute = lost = 0.0
        revocations = replacements = 0
        events: List[Tuple[float, str]] = []
        gpu_seconds: Dict[str, float] = {}

        def advance(to_t: float):
            """Advance wall-clock to `to_t`, producing steps at the current
            cluster speed with SEQUENTIAL checkpoint pauses (§IV-B) at every
            i_c boundary — exact piecewise simulation, no Zeno refinement."""
            nonlocal steps, t, ckpt_time, last_ckpt_step
            sp = self._cluster_speed()
            span = to_t - t
            for w in self.workers.values():
                if w.alive:
                    gpu_seconds[w.gpu] = gpu_seconds.get(w.gpu, 0.0) + span
            remaining = span
            if sp > 0:
                while remaining > 1e-12:
                    to_boundary = self.i_c - (steps % self.i_c)
                    if to_boundary <= 1e-9:
                        to_boundary = self.i_c
                    dt_needed = to_boundary / sp
                    if dt_needed <= remaining:
                        steps += to_boundary
                        remaining -= dt_needed
                        pause = min(self.t_c, remaining)
                        ckpt_time += pause
                        remaining -= pause
                        last_ckpt_step = int(round(steps))
                    else:
                        steps += sp * remaining
                        remaining = 0.0
            t = to_t

        def time_to_finish() -> float:
            """Wall-clock needed to reach total_steps from (steps, t),
            including future checkpoint pauses."""
            sp = self._cluster_speed()
            if sp <= 0:
                return float("inf")
            remaining_steps = total_steps - steps
            n_ckpts = int(total_steps // self.i_c) - int(steps // self.i_c)
            return remaining_steps / sp + n_ckpts * self.t_c

        while steps < total_steps - 1e-6 and t < max_hours * 3600.0:
            sp = self._cluster_speed()
            if sp <= 0.0 and not q:
                break
            t_finish = t + time_to_finish()
            if q and q[0].t < t_finish:
                ev = heapq.heappop(q)
                advance(max(ev.t, t))
                if ev.kind == "revoke":
                    w = self.workers.get(ev.payload["wid"])
                    if w is None or not w.alive:
                        continue
                    w.alive = False
                    revocations += 1
                    events.append((t, f"revoke w{w.wid} ({w.gpu})"))
                    if w.is_chief:
                        if self.handover:
                            # lease handover: another worker checkpoints
                            for o in self.workers.values():
                                if o.alive:
                                    o.is_chief = True
                                    break
                            events.append((t, "chief handover (no recompute)"))
                        elif (self.provider.graceful_checkpoint_on_warning
                                and self.provider.warning_seconds >= self.t_c):
                            # the market's revocation notice is long enough
                            # for the chief to flush a checkpoint before
                            # dying: nothing to recompute even without
                            # lease handover. The write overlaps the notice
                            # window (wall-clock already counted), so it
                            # does NOT accrue checkpoint pause time.
                            last_ckpt_step = int(round(steps))
                            events.append(
                                (t, "warning checkpoint (no recompute)"))
                        else:
                            # stock behavior: recompute from last checkpoint
                            lost_now = steps - last_ckpt_step
                            steps = float(last_ckpt_step)
                            lost += lost_now
                            rec = lost_now / max(self._cluster_speed(), 1e-9)
                            recompute += rec
                            events.append(
                                (t, f"chief lost: recompute {lost_now:.0f} steps"))
                    if self.replace:
                        su = self.startup.sample(w.gpu, after_revocation=True)
                        cold = self.repl.sample(self.model_gflops, cold=True)
                        ready = t + su["total"] + cold
                        # stock mode (Fig 11): the replacement inherits the
                        # revoked chief's identity, so later chief
                        # revocations keep costing recompute; with handover
                        # a survivor was already promoted above
                        heapq.heappush(q, FleetEvent(
                            ready, "join",
                            {"gpu": w.gpu, "region": w.region,
                             "speed": w.speed,
                             "chief": w.is_chief and not self.handover}))
                elif ev.kind == "join":
                    w = SimWorker(next_wid, ev.payload["gpu"],
                                  ev.payload["region"], ev.payload["speed"],
                                  is_chief=ev.payload.get("chief", False))
                    next_wid += 1
                    self.workers[w.wid] = w
                    replacements += 1
                    events.append((t, f"join w{w.wid} ({w.gpu})"))
                    lt = self.rev.lifetime(w.region, w.gpu,
                                           start_hour=start_hour + t / 3600.0)
                    if math.isfinite(lt):
                        heapq.heappush(q, FleetEvent(
                            t + lt * 3600.0, "revoke", {"wid": w.wid}))
            else:
                advance(t_finish)

        cost = sum(secs / 3600.0 * self.price_of.get(g, 0.0)
                   for g, secs in gpu_seconds.items())
        regions = {w.region for w in self.workers.values()}
        return SimResult(t, int(steps), revocations, replacements, ckpt_time,
                         recompute, lost, events, cost,
                         provider=self.provider.name,
                         region=regions.pop() if len(regions) == 1 else "")

    def run_many(self, total_steps: int, n: int, max_hours: float = 48.0,
                 start_hour: float = 0.0) -> FleetEnsemble:
        """Simulate `n` independent trajectories of the same launch.

        All initial lifetimes are pre-drawn here in one batched call per
        (region, gpu) group of the roster — an (n, count) matrix from
        `RevocationSampler.lifetimes` seeded with `self.seed` — and each
        trajectory then runs on its own decorrelated seed block
        (`seed + 1 + 4*j`, leaving room for the simulator's internal
        seed/seed+1/seed+2/seed+3 streams), consumed only by replacement
        joins and startup draws. `run(...)` with the same seed remains the
        single-trajectory path; `run_many` never perturbs its streams.
        """
        if n < 1:
            raise ValueError(f"need at least one trajectory, got {n}")
        groups: Dict[Tuple[str, str], List[int]] = {}
        for idx, (_, gpu, region, _) in enumerate(self._roster):
            groups.setdefault((region, gpu), []).append(idx)
        ens_samp = RevocationSampler(self.seed, self.provider)
        pre = np.empty((n, len(self._roster)))
        for (region, gpu), idxs in groups.items():
            draws = ens_samp.lifetimes(region, gpu, n * len(idxs),
                                       start_hour)
            pre[:, idxs] = draws.reshape(n, len(idxs))
        results = []
        for j in range(n):
            sim = self._respawn(self.seed + 1 + 4 * j)
            results.append(sim.run(total_steps, max_hours, start_hour,
                                   initial_lifetimes=pre[j]))
        regions = {r.region for r in results}
        return FleetEnsemble(results,
                             SimStats.from_results(results, total_steps),
                             provider=self.provider.name,
                             region=regions.pop() if len(regions) == 1
                             else "")


#: Long-form alias used by the docs and the provider layer.
FleetSimulator = FleetSim
