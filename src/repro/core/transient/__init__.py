from repro.core.transient.revocation import (  # noqa: F401
    LifetimeModel, REGION_GPU_PARAMS, RevocationSampler,
)
from repro.core.transient.startup import StartupModel  # noqa: F401
from repro.core.transient.replacement import ReplacementModel  # noqa: F401
from repro.core.transient.fleet import (FleetEvent, FleetSim,  # noqa: F401
                                        FleetSimulator)
from repro.core.transient.fleet_batched import (FleetDraws,  # noqa: F401
                                                run_batched)
