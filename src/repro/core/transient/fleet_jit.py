"""Jitted mega-ensemble fleet engine — the lockstep simulator as ONE
compiled JAX program (`engine="jit"`).

`fleet_batched.run_batched` advances all trajectories per lockstep round
but pays NumPy's interpreter tax per round: dozens of temporaries, fancy
indexing, per-round Python grouping in the draw batchers. This module
compiles the identical round into a `lax.while_loop` body — trajectory
state as stacked `(n,)`/`(n, slots)` device arrays, the next-event select
as a fused masked min+argmin (the Pallas kernel in
`repro/kernels/event_select.py` on TPU, its XLA reference elsewhere), and
every draw the engines share pre-materialized on device:

* the `(n, slots)` initial-lifetime matrix is `FleetDraws.initial`
  verbatim (chaos hazard transforms already applied on host);
* generation-level replacement pools (`FleetDraws._level`) are stacked to
  `(G, n, slots)` delays + `(G, n, slots, K)` uniforms. The per-slot
  `LifetimeLaw.sample_from_uniforms` samplers are ported to jittable form
  (GCP truncated-Weibull + 16-round Fig 9 diurnal thinning, AWS inverse
  cumulative hazard on the per-launch-hour grids, Azure inverse
  exponential), so the keyed-draw contract holds unchanged: all three
  engines consume identical uniforms and agree exactly on
  revocation/replacement counts (tests/test_engine_parity.py);
* chaos `FaultTimeline` factors become piecewise-constant device tables
  (`factor_tables`) indexed by `searchsorted(boundaries, t)`, and the
  keyed join-hazard uniforms a `(G, n, slots, F)` matrix
  (`join_uniform_matrix`) — all seven scripted scenarios run under this
  engine bit-identically to the other two.

Generation pools are *level-paged*: G levels are materialized up front;
a trajectory whose next revocation needs a deeper replacement chain
freezes (`stalled`) BEFORE mutating anything, the loop drains everyone
else, and the host doubles G and re-enters with the carried state — the
frozen trajectory replays its pending round against the grown pools, so
results are independent of the paging schedule.

Everything runs under `jax.experimental.enable_x64` with explicit f64
state regardless of the global `jax_enable_x64` flag, and the math is
elementwise per trajectory, so results are byte-identical whatever the
flag or the trajectory sharding (`_shard` splits the trajectory axis
across `jax.devices()` when more than one is visible —
`xla_force_host_platform_device_count` in the multidevice CI job).
docs/DESIGN.md §2 has the state layout; docs/performance.md the
engine-selection matrix and the `bench_jit_engine` gate.
"""
from __future__ import annotations

import functools
from typing import TYPE_CHECKING, List, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.perf_model.cluster_model import PSBottleneckModel
from repro.kernels.ops import event_select

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.transient.fleet import FleetSim, SimResult
    from repro.core.transient.fleet_batched import FleetDraws

#: generation levels materialized before the first entry; doubled on
#: every stall re-entry
INITIAL_LEVELS = 4

#: widths at or below this run to completion without compaction; above
#: it the loop exits once the active set halves, the host pages finished
#: trajectories out (the device analogue of the NumPy engine's shrinking
#: boolean-mask active set) and re-enters at the next power of two
COMPACT_MIN = 4096

_GPU_CODES = {"k80": 0, "v100": 1}  # 2 = the p100-family default weight
_ENVELOPE_INV = 1.0 / 2.5           # 1 / _DIURNAL_MAX_WEIGHT
_GCP_CAP_H = 24.0                   # revocation.MAX_LIFETIME_H


# ---------------------------------------------------------------------------
# jittable ports of the three `sample_from_uniforms` laws
# ---------------------------------------------------------------------------
def _diurnal_weight(code, h):
    """`revocation._diurnal_weight` with the gpu string as a code array."""
    h = h % 24.0
    wk = 1.0 + 1.5 * jnp.exp(-((h - 10.0) ** 2) / (2 * 2.0 ** 2))
    wv = jnp.where((h >= 16.0) & (h < 20.0), 0.0,
                   1.0 + 0.6 * jnp.exp(-((h - 9.0) ** 2) / (2 * 3.0 ** 2)))
    wp = 1.0 + 0.8 * jnp.exp(-((h - 13.0) ** 2) / (2 * 4.0 ** 2))
    return jnp.where(code == 0, wk, jnp.where(code == 1, wv, wp))


def _sample_gcp(U, hours, p24, k, lam, raw24, code):
    """`LifetimeModel.sample_from_uniforms`, params gathered per row:
    column 0 decides the 24 h survival mass, then 16 (candidate, accept)
    pairs run the diurnal thinning, with the hard-zero +4 h push."""
    def inv_cdf(u):
        return lam * (-jnp.log(1.0 - u * raw24)) ** (1.0 / k)

    revoked = U[:, 0] < p24
    cand = inv_cdf(U[:, 1])
    pending = U[:, 2] >= _diurnal_weight(code, hours + cand) * _ENVELOPE_INV
    for j in range(1, 16):
        c2 = inv_cdf(U[:, 1 + 2 * j])
        cand = jnp.where(pending, c2, cand)
        acc = (U[:, 2 + 2 * j]
               < _diurnal_weight(code, hours + c2) * _ENVELOPE_INV)
        pending = pending & ~acc
    w = _diurnal_weight(code, hours + cand)
    cand = jnp.where(pending & (w == 0.0), cand + 4.0, cand)
    return jnp.where(revoked, jnp.minimum(cand, _GCP_CAP_H), jnp.inf)


def _sample_aws(U, hours, slot, ts_all, cum_all):
    """`PriceSignalLifetime.sample_from_uniforms`: inverse cumulative
    hazard of column 0 on the slot's 15-min-quantized launch-hour grid.

    `ts_all`: (S, P) time grids; `cum_all`: (S, 96, P) cumulative-hazard
    grids per quantized hour key. The interpolation runs as an
    elementwise bisection (12 gathered probes per row) instead of
    materializing the `(n, P)` gathered grid rows `jnp.interp` would
    need — per round, joins are rare but every row computes."""
    P = ts_all.shape[-1]
    target = -jnp.log(1.0 - U[:, 0])
    key = (jnp.round(hours % 24.0 * 4.0)).astype(jnp.int32) % 96
    cum2 = cum_all.reshape(-1, P)
    row = slot * 96 + key
    lo = jnp.zeros(row.shape, jnp.int32)
    hi = jnp.full(row.shape, P, jnp.int32)
    for _ in range(12):  # 2^12 >= P + 1 outcomes
        mid = (lo + hi) // 2
        v = cum2[row, jnp.minimum(mid, P - 1)]
        upd = lo < hi
        right = upd & (v <= target)
        lo = jnp.where(right, mid + 1, lo)
        hi = jnp.where(upd & ~right, mid, hi)
    j = jnp.clip(lo, 1, P - 1)          # searchsorted(cum, target, 'right')
    c0, c1 = cum2[row, j - 1], cum2[row, j]
    t0, t1 = ts_all[slot, j - 1], ts_all[slot, j]
    out = t0 + (target - c0) * ((t1 - t0) / (c1 - c0))
    return jnp.where(target > cum2[row, P - 1], jnp.inf, out)


def _sample_azure(U, hazard, horizon):
    """`TieredEvictionLifetime.sample_from_uniforms`: inverse-transform
    exponential; inf beyond the sampling horizon."""
    t = -jnp.log(1.0 - U[:, 0]) / hazard
    return jnp.where(t > horizon, jnp.inf, t)


def _law_spec(sim: "FleetSim"):
    """Classify the roster's lifetime laws into one jittable kind plus
    stacked per-slot parameter arrays. Raises for laws the compiled
    samplers cannot reproduce (custom providers): those rosters need
    `engine="batched"`, whose per-key fallback streams handle any law."""
    from repro.core.transient.revocation import LifetimeModel
    from repro.providers.aws import PriceSignalLifetime
    from repro.providers.azure import TieredEvictionLifetime

    laws = [sim.provider.lifetime_model(region, gpu)
            for _, gpu, region, _ in sim._roster]
    if all(isinstance(l, LifetimeModel) for l in laws):
        import math
        raw24 = [1.0 - math.exp(-((_GCP_CAP_H / l.lam) ** l.k))
                 for l in laws]
        return "gcp", {
            "law_p24": np.array([l.p24 for l in laws]),
            "law_k": np.array([l.k for l in laws]),
            "law_lam": np.array([l.lam for l in laws]),
            "law_raw24": np.array(raw24),
            "law_code": np.array([_GPU_CODES.get(l.gpu, 2) for l in laws],
                                 np.int32)}
    if all(isinstance(l, PriceSignalLifetime) for l in laws):
        ts_all, cum_all = [], []
        for l in laws:
            grids = [l._grid(kq / 4.0) for kq in range(96)]
            ts_all.append(grids[0][0])
            cum_all.append(np.stack([c for _, c in grids]))
        return "aws", {"law_ts": np.stack(ts_all),
                       "law_cum": np.stack(cum_all)}
    if all(isinstance(l, TieredEvictionLifetime) for l in laws):
        return "azure", {
            "law_hazard": np.array([l.hazard_per_h for l in laws]),
            "law_horizon": np.array([l.horizon_h for l in laws])}
    raise ValueError(
        "engine='jit' compiles the provider's lifetime law into the "
        "device program and supports the gcp/aws/azure law families; "
        f"this roster's laws ({sorted({type(l).__name__ for l in laws})}) "
        "have no jittable port — use engine='batched' instead")


# ---------------------------------------------------------------------------
# the compiled program
# ---------------------------------------------------------------------------
def _gather_slot(arr2d, slot):
    """arr2d[(i, slot[i])] without a cross-trajectory gather (stays
    elementwise under trajectory sharding)."""
    return jnp.take_along_axis(arr2d, slot[:, None], axis=1)[:, 0]


@functools.lru_cache(maxsize=None)
def _compiled(law_kind: str, handover: bool, graceful: bool,
              replace: bool, resilient: bool):
    """One jitted lockstep program per (law family, chief policy,
    replacement policy, resilience). Shapes (n, S, K, G, F, chaos
    segments) re-trace automatically; every scalar knob is a traced
    operand. `resilient` gates the quorum-degradation/restore-stall
    state and math entirely out of the trace — a run without a
    `ResilienceConfig` compiles the exact pre-resilience program."""

    def simulate(st, ar):
        S = ar["slot_speed"].shape[0]
        G = ar["delays"].shape[0] // S       # pools fold (level, slot)
        P_INF = jnp.inf

        def seg_factors(t):
            seg = jnp.searchsorted(ar["boundaries"], t, side="right")
            return (ar["speed_table"][seg], ar["ps_table"][seg],
                    ar["blk_table"][seg])

        def cluster_speed(t, alive):
            mults, psf, _ = seg_factors(t)
            return jnp.minimum(jnp.sum(alive * mults * ar["slot_speed"],
                                       axis=1), ar["cap"] * psf)

        def join_lifetimes(U, hours, slot):
            if law_kind == "gcp":
                return _sample_gcp(U, hours, ar["law_p24"][slot],
                                   ar["law_k"][slot], ar["law_lam"][slot],
                                   ar["law_raw24"][slot],
                                   ar["law_code"][slot])
            if law_kind == "aws":
                return _sample_aws(U, hours, slot, ar["law_ts"],
                                   ar["law_cum"])
            return _sample_azure(U, ar["law_hazard"][slot],
                                 ar["law_horizon"][slot])

        def chaos_join(lt, Uj, slot, elapsed_h):
            """`FaultTimeline.transform_joins` on the pre-keyed uniform
            matrix: fault windows thin each lifetime in fault order."""
            F = ar["hz_start"].shape[0]
            cols = ar["hz_cols"]                      # (F, S) bool
            for f in range(F):
                a = jnp.maximum(ar["hz_start"][f], elapsed_h)
                b = jnp.minimum(ar["hz_end"][f], elapsed_h + lt)
                tau = -jnp.log1p(-Uj[:, f]) / ar["hz_rate"][f]
                killed = ((b - a) > 0) & (tau < (b - a))
                new = jnp.where(killed,
                                jnp.minimum(lt, a + tau - elapsed_h), lt)
                lt = jnp.where(cols[f][slot], new, lt)
            return lt

        def cond(st):
            act = ~st["done"] & ~st["stalled"]
            w = act.shape[0]
            if w <= COMPACT_MIN:
                return jnp.any(act)
            # wide ensembles hand control back once the active set halves
            # so the host can compact; the body math never sees the width
            a = jnp.sum(act)
            return (a > 0) & (2 * a > w)

        def body(st):
            t, steps = st["t"], st["steps"]
            n = t.shape[0]
            act = ~st["done"] & ~st["stalled"]
            ev_all = jnp.concatenate([st["revoke_t"], st["join_t"]],
                                     axis=1)
            ev_all = jnp.where(act[:, None], ev_all, P_INF)
            ev_t, ev_arg = event_select(ev_all)
            mults, psf, blk = seg_factors(t)
            sp = jnp.minimum(jnp.sum(st["alive"] * mults
                                     * ar["slot_speed"], axis=1),
                             ar["cap"] * psf)
            nb = jnp.append(ar["boundaries"], P_INF)[
                jnp.searchsorted(ar["boundaries"], t, side="right")]
            nb = jnp.where(nb < ar["tmax"], nb, P_INF)
            if resilient:
                # a pending restore-retry stall end is a pure-advancement
                # boundary (the event engine's no-op "resume" heap entry,
                # never clipped at tmax); effective speed is gated to 0
                # meanwhile, and otherwise by the quorum tier on the
                # alive fraction (fleet_batched._degr_factor)
                stall_ev = jnp.where(st["stall_t"] > t, st["stall_t"],
                                     P_INF)
                nb = jnp.minimum(nb, stall_ev)
                frac = jnp.sum(st["alive"], axis=1) / S
                factor = jnp.where(
                    frac < ar["quorum"], 0.0,
                    jnp.where(frac < ar["shrink_below"],
                              ar["shrink_factor"], 1.0))
                sp = jnp.where(jnp.isfinite(stall_ev), 0.0, sp * factor)
            i_c, t_c, total = ar["i_c"], ar["t_c"], ar["total"]
            rel = jnp.where(
                sp > 0,
                (total - steps) / jnp.where(sp > 0, sp, 1.0)
                + jnp.where(blk, 0.0, (jnp.floor(total / i_c)
                                       - jnp.floor(steps / i_c)) * t_c),
                P_INF)
            t_fin = t + rel
            stuck = act & jnp.isinf(ev_t) & (sp <= 0) & jnp.isinf(nb)
            nxt = jnp.minimum(ev_t, nb)
            ev = act & ~stuck & (nxt < t_fin)      # strict: event first
            fin = act & ~stuck & ~ev
            slot = (ev_arg % S).astype(jnp.int32)
            real = ev & (ev_t <= nxt)              # vs a chaos boundary
            is_rev = real & (ev_arg < S)
            gen_at = _gather_slot(st["gen"], slot)
            # level paging: a revoke whose replacement needs a pool level
            # beyond G freezes the trajectory BEFORE any mutation; the
            # host grows the pools and re-enters
            if replace:
                stall_now = is_rev & (gen_at + 1 > G)
            else:
                stall_now = jnp.zeros_like(is_rev)
            stalled = st["stalled"] | stall_now
            move = (ev | fin) & ~stall_now
            target = jnp.where(ev, jnp.maximum(nxt, t), t_fin)
            # ---- closed-form advance to `target` (fleet_batched._advance)
            span = jnp.where(move, target - t, 0.0)
            if resilient:
                # exclusive accrual per span: a stall span is restore
                # delay; a quorum pause (not stalled, factor 0) is
                # paused time. `sp` is already gated above, so the
                # stepping math below produces nothing for either.
                seg_stall = st["stall_t"] > t
                restore_s = (st["restore_s"]
                             + jnp.where(seg_stall, span, 0.0))
                paused = (st["paused"]
                          + jnp.where(~seg_stall & (factor == 0.0),
                                      span, 0.0))
            alive_seconds = (st["alive_seconds"]
                             + st["alive"] * span[:, None])
            pos = move & (sp > 0) & (span > 1e-12)
            spp = jnp.where(sp > 0, sp, 1.0)
            s0 = steps
            b0 = i_c - s0 % i_c
            b0 = jnp.where(b0 <= 1e-9, i_c, b0)
            d0 = b0 / spp
            cycle = i_c / spp + t_c
            k = jnp.where(span >= d0,
                          jnp.floor((span - d0) / cycle) + 1.0, 0.0)
            r = span - d0 - (k - 1.0) * cycle
            pause = jnp.minimum(t_c, r)
            boundary = s0 + b0 + (k - 1.0) * i_c
            stepped = jnp.where(k > 0,
                                boundary + spp * jnp.maximum(0.0, r - pause),
                                s0 + spp * span)
            new_ck = jnp.where(k > 0, (k - 1.0) * t_c + pause, 0.0)
            stepped = jnp.where(blk, s0 + spp * span, stepped)
            new_ck = jnp.where(blk, 0.0, new_ck)
            steps = jnp.where(pos, stepped, s0)
            ckpt_time = st["ckpt_time"] + jnp.where(pos, new_ck, 0.0)
            last_ckpt = jnp.where(pos & (k > 0) & ~blk,
                                  jnp.round(boundary), st["last_ckpt"])
            t = jnp.where(move, target, t)
            done = st["done"] | stuck | (fin & ~stall_now)
            # ------------------------------------------------- revokes
            is_rev = is_rev & ~stall_now
            is_join = real & (ev_arg >= S)
            onehot = jnp.arange(S)[None, :] == slot[:, None]
            rev2d = onehot & is_rev[:, None]
            was_chief = jnp.any(st["chief"] & rev2d, axis=1)
            alive = st["alive"] & ~rev2d
            revoke_t = jnp.where(rev2d, P_INF, st["revoke_t"])
            revocations = st["revocations"] + is_rev
            chief, lost, recompute = st["chief"], st["lost"], st["recompute"]
            if resilient:
                stall_t = st["stall_t"]
            if handover:
                chief = chief & ~rev2d
                keys = jnp.where(alive, st["order_key"], P_INF)
                best = jnp.argmin(keys, axis=1)
                promote = (is_rev & was_chief
                           & jnp.isfinite(jnp.min(keys, axis=1)))
                best2d = jnp.arange(S)[None, :] == best[:, None]
                chief = chief | (best2d & promote[:, None])
            elif graceful:
                gm = is_rev & was_chief
                last_ckpt = jnp.where(gm, jnp.round(steps), last_ckpt)
            else:
                sm = is_rev & was_chief
                lost_now = jnp.where(sm, steps - last_ckpt, 0.0)
                steps = jnp.where(sm, last_ckpt, steps)
                lost = lost + lost_now
                sp_after = cluster_speed(t, alive)   # post-revoke fleet
                # raw cluster speed on purpose: recompute happens after
                # the fleet recovers, so degradation never inflates it
                recompute = recompute + jnp.where(
                    sm, lost_now / jnp.maximum(sp_after, 1e-9), 0.0)
                if resilient:
                    # restore-retry stall, keyed on the revoked
                    # occupant's generation (pre-bump — the replace
                    # block below bumps it); a later stall overwrites an
                    # active one, even shortening it
                    lvl_s = jnp.clip(gen_at, 0, G - 1)
                    sdelay = ar["stalls"][lvl_s * S + slot, st["orig"]]
                    stall_t = jnp.where(sm, t + sdelay, stall_t)
            gen, join_t = st["gen"], st["join_t"]
            orig = st["orig"]        # row in the full-width pools
            if replace:
                lvl = jnp.clip(gen_at, 0, G - 1)     # level new_gen - 1
                delay = ar["delays"][lvl * S + slot, orig]
                join_t = jnp.where(rev2d, (t + delay)[:, None], join_t)
                gen = gen + rev2d
            # --------------------------------------------------- joins
            join2d = onehot & is_join[:, None]
            alive = alive | join2d
            join_t = jnp.where(join2d, P_INF, join_t)
            replacements = st["replacements"] + is_join
            order_key = jnp.where(join2d, st["next_key"][:, None],
                                  st["order_key"])
            next_key = st["next_key"] + is_join

            def _sample_joins(revoke_t):
                # one fused (level, slot, trajectory) gather per pool
                # (pools stay full-width and device-resident; compaction
                # only permutes `orig`), then the law sampler — guarded
                # by the `lax.cond` below so rounds with no join (notably
                # the full-width first round, where every event is an
                # initial revocation) skip it entirely
                li = (jnp.clip(gen_at - 1, 0, G - 1) * S + slot)
                U = ar["uniforms"][li, orig, :]              # (n, K)
                lts = join_lifetimes(U, ar["start_hour"] + t / 3600.0,
                                     slot)
                if ar["hz_start"].shape[0]:
                    Uj = ar["join_U"][li, orig, :]           # (n, F)
                    lts = chaos_join(lts, Uj, slot, t / 3600.0)
                return jnp.where(
                    join2d,
                    jnp.where(jnp.isfinite(lts), t + lts * 3600.0,
                              P_INF)[:, None],
                    revoke_t)

            revoke_t = lax.cond(jnp.any(is_join), _sample_joins,
                                lambda r: r, revoke_t)
            done = done | (steps >= total - 1e-6) | (t >= ar["tmax"])
            out = {"t": t, "steps": steps, "last_ckpt": last_ckpt,
                   "ckpt_time": ckpt_time, "recompute": recompute,
                   "lost": lost, "revocations": revocations,
                   "replacements": replacements, "alive": alive,
                   "chief": chief, "gen": gen, "order_key": order_key,
                   "next_key": next_key, "revoke_t": revoke_t,
                   "join_t": join_t, "alive_seconds": alive_seconds,
                   "done": done, "stalled": stalled, "orig": orig}
            if resilient:
                out["stall_t"] = stall_t
                out["paused"] = paused
                out["restore_s"] = restore_s
            return out

        return lax.while_loop(cond, body, st)

    return jax.jit(simulate)


# ---------------------------------------------------------------------------
# host driver: pools, sharding, level paging
# ---------------------------------------------------------------------------
def _shard(n_pad: int):
    """NamedSharding over the trajectory axis when >1 device is visible
    (multi-host-device CPU via xla_force_host_platform_device_count, or
    real accelerators); None on a single device."""
    devs = jax.devices()
    if len(devs) <= 1:
        return None, None
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    mesh = Mesh(np.array(devs), ("traj",))
    return (NamedSharding(mesh, PartitionSpec("traj")),
            NamedSharding(mesh, PartitionSpec()))


def _put(x, sharding, axis=0):
    if sharding is None:
        return jnp.asarray(x)
    from jax.sharding import NamedSharding, PartitionSpec
    if axis == 0:
        return jax.device_put(jnp.asarray(x), sharding)
    spec = [None] * np.ndim(x)
    spec[axis] = "traj"
    return jax.device_put(jnp.asarray(x), NamedSharding(
        sharding.mesh, PartitionSpec(*spec)))


def _pools(draws: "FleetDraws", G: int, has_chaos: bool, res=None):
    """FleetDraws generation levels 1..G as device arrays in the folded
    `(level * S + slot, trajectory, ...)` layout the body's single
    `take_along_axis` per pool expects. Cached on the draws object — the
    pools are pure functions of (draws, G, res), so repeat calls
    (planner re-scoring, `_best_of` benchmark reps) reuse the device
    copies. With a `ResilienceConfig` the restore-retry stall levels
    ride along, indexed by the revoked occupant's generation (0..G-1 —
    level paging freezes any revoke whose occupant reached G before it
    mutates state, so the index never pages off the pool)."""
    key = (G, bool(has_chaos), res)
    cache = draws.__dict__.setdefault("_jit_pool_cache", {})
    if key in cache:
        return cache[key]
    n, S, K = draws.n, draws.n_slots, draws._K
    delays = np.empty((G, S, n))
    uniforms = np.empty((G, S, n, K))
    for g in range(1, G + 1):
        d, u = draws._level(g)
        delays[g - 1] = d.T
        uniforms[g - 1] = np.swapaxes(u, 0, 1)
    out = {"delays": jnp.asarray(delays.reshape(G * S, n)),
           "uniforms": jnp.asarray(uniforms.reshape(G * S, n, K))}
    if res is not None:
        stalls = np.empty((G, S, n))
        for g in range(G):
            stalls[g] = draws.restore_stall_level(res, g).T
        out["stalls"] = jnp.asarray(stalls.reshape(G * S, n))
    if has_chaos:
        F = len(draws.chaos.hazards)
        ju = np.empty((G, S, n, F))
        for g in range(1, G + 1):
            ju[g - 1] = np.swapaxes(
                draws.chaos.join_uniform_matrix(n, g), 0, 1)
        out["join_U"] = jnp.asarray(ju.reshape(G * S, n, F))
    else:
        out["join_U"] = jnp.zeros((G * S, n, 0))
    cache.clear()            # keep at most one (the deepest) G resident
    cache[key] = out
    return out


def _pow2ceil(x: int) -> int:
    return 1 << (max(1, x) - 1).bit_length()


#: state fields pulled to host at every loop exit (the result fields plus
#: the done/stalled masks driving compaction and pool paging)
_HARVEST = ("t", "steps", "ckpt_time", "recompute", "lost", "revocations",
            "replacements", "alive_seconds", "done", "stalled")


def run_jit(sim: "FleetSim", total_steps: int, n: int,
            max_hours: float = 48.0, start_hour: float = 0.0,
            draws: Optional["FleetDraws"] = None, raw: bool = False):
    """Advance `n` trajectories of `sim`'s roster as one jitted program.

    Same contract as `fleet_batched.run_batched` (which documents the
    round semantics): one `SimResult` per trajectory, exact
    revocation/replacement parity with both other engines under the
    shared `FleetDraws`, times/costs to float tolerance. With
    `raw=True` the per-trajectory stats come back as a dict of arrays
    instead (same keys as `run_batched(raw=True)`) — the
    `bench_jit_engine` engine-core measurement and array consumers skip
    the 65k-`SimResult` Python object construction.

    Above `COMPACT_MIN` trajectories the driver pages finished
    trajectories out between `lax.while_loop` entries: the loop hands
    control back once the active set halves, finished rows' stats are
    scattered to host buffers, and the survivors re-enter at the next
    power-of-two width (fresh trace per width, cached across calls).
    Compaction only permutes rows between entries — the body math is
    width-blind and elementwise per trajectory, so results are
    bit-identical whatever the compaction (or shard) schedule.
    """
    from repro.core.transient.fleet import SimResult
    from repro.core.transient.fleet_batched import FleetDraws

    if n < 1:
        raise ValueError(f"need at least one trajectory, got {n}")
    spec_kind, law_arrays = _law_spec(sim)
    if draws is None:
        draws = FleetDraws(sim, n, start_hour)
    roster = sim._roster
    S = len(roster)
    slot_speed = np.array([speed for _, _, _, speed in roster], float)
    cap = PSBottleneckModel(sim.model_bytes, sim.n_ps,
                            n_tensors=sim.n_tensors,
                            compression=sim.grad_compression
                            ).capacity_steps_per_s()
    chaos = getattr(sim, "chaos", None)
    has_chaos = chaos is not None
    has_haz = has_chaos and len(chaos.hazards) > 0
    graceful = (sim.provider.graceful_checkpoint_on_warning
                and sim.provider.warning_seconds >= sim.t_c)
    resil = getattr(sim, "resilience", None)
    resilient = resil is not None
    fn = _compiled(spec_kind, bool(sim.handover), bool(graceful),
                   bool(sim.replace), resilient)

    with enable_x64():
        traj_sh, rep_sh = _shard(n)
        n_dev = len(jax.devices())
        n_pad = n if traj_sh is None else -(-n // n_dev) * n_dev

        if has_chaos:
            bounds, sp_tab, ps_tab, blk_tab = chaos.factor_tables()
            hz_s, hz_e, hz_r, hz_c = chaos.hazard_tables()
        else:
            bounds = np.zeros(0)
            sp_tab, ps_tab = np.ones((1, S)), np.ones(1)
            blk_tab = np.zeros(1, bool)
            hz_s = hz_e = hz_r = np.zeros(0)
            hz_c = np.zeros((0, S), bool)
        ar = {"slot_speed": _put(slot_speed, rep_sh),
              "cap": jnp.asarray(float(cap)),
              "i_c": jnp.asarray(float(sim.i_c)),
              "t_c": jnp.asarray(float(sim.t_c)),
              "total": jnp.asarray(float(total_steps)),
              "tmax": jnp.asarray(max_hours * 3600.0),
              "start_hour": jnp.asarray(float(start_hour)),
              "boundaries": _put(bounds, rep_sh),
              "speed_table": _put(sp_tab, rep_sh),
              "ps_table": _put(ps_tab, rep_sh),
              "blk_table": _put(blk_tab, rep_sh),
              "hz_start": _put(hz_s, rep_sh),
              "hz_end": _put(hz_e, rep_sh),
              "hz_rate": _put(hz_r, rep_sh),
              "hz_cols": _put(hz_c, rep_sh)}
        if resilient:
            ar["quorum"] = jnp.asarray(float(resil.degradation.quorum))
            ar["shrink_below"] = jnp.asarray(
                float(resil.degradation.shrink_below))
            ar["shrink_factor"] = jnp.asarray(
                float(resil.degradation.shrink_factor))
        for name, arr in law_arrays.items():
            ar[name] = _put(arr, rep_sh)

        pad = n_pad - n
        init_rt = np.where(np.isfinite(draws.initial),
                           draws.initial * 3600.0, np.inf)
        if pad:
            init_rt = np.pad(init_rt, ((0, pad), (0, 0)),
                             constant_values=np.inf)
        chief0 = np.zeros((n_pad, S), bool)
        chief0[:, 0] = True                 # FleetSim marks workers[0]
        done0 = np.zeros(n_pad, bool)
        done0[n:] = True                    # padding rows never run
        st = {"t": np.zeros(n_pad), "steps": np.zeros(n_pad),
              "last_ckpt": np.zeros(n_pad), "ckpt_time": np.zeros(n_pad),
              "recompute": np.zeros(n_pad), "lost": np.zeros(n_pad),
              "revocations": np.zeros(n_pad, np.int32),
              "replacements": np.zeros(n_pad, np.int32),
              "alive": np.ones((n_pad, S), bool), "chief": chief0,
              "gen": np.zeros((n_pad, S), np.int32),
              "order_key": np.tile(np.arange(S, dtype=float), (n_pad, 1)),
              "next_key": np.full(n_pad, float(S)),
              "revoke_t": init_rt,
              "join_t": np.full((n_pad, S), np.inf),
              "alive_seconds": np.zeros((n_pad, S)),
              "done": done0, "stalled": np.zeros(n_pad, bool),
              "orig": np.concatenate([np.arange(n, dtype=np.int32),
                                      np.zeros(pad, np.int32)])}
        if resilient:
            st["stall_t"] = np.zeros(n_pad)
            st["paused"] = np.zeros(n_pad)
            st["restore_s"] = np.zeros(n_pad)
        st = {key: _put(v, traj_sh) for key, v in st.items()}

        if sim.replace:
            # start deep enough for every level a previous call on these
            # draws already materialized — warm calls take one entry
            G = INITIAL_LEVELS
            while G < max(draws._levels, default=0):
                G *= 2
        else:
            G = 1

        # lane -> original trajectory map plus host result buffers rows
        # are scattered into as compaction drops them from the device
        sel = np.concatenate([np.arange(n), np.zeros(pad, np.int64)])
        valid = np.zeros(n_pad, bool)
        valid[:n] = True
        harvest = _HARVEST + (("paused", "restore_s")
                              if resilient else ())
        res = {key: np.zeros(n, np.int64 if key in
                             ("revocations", "replacements") else float)
               for key in harvest if key not in
               ("alive_seconds", "done", "stalled")}
        res["alive_seconds"] = np.zeros((n, S))
        if not resilient:     # raw output always carries both keys
            res["paused"] = np.zeros(n)
            res["restore_s"] = np.zeros(n)
        res_keys = [key for key in harvest
                    if key not in ("done", "stalled")]

        def _scatter(lanes: np.ndarray):
            """Pull `lanes`' stats off the device into the result
            buffers (a device-side gather first, so the transfer is
            proportional to the rows leaving, not the loop width)."""
            if not lanes.size:
                return
            # plain (unsharded) index vector: its length is however many
            # rows happen to finish, rarely divisible by the device count
            idx_d = jnp.asarray(lanes.astype(np.int32))
            sub = jax.device_get({key: jnp.take(st[key], idx_d, axis=0)
                                  for key in res_keys})
            rows = sel[lanes]
            for key in res_keys:
                res[key][rows] = np.asarray(sub[key])

        ar_g = dict(ar)

        def _mount_pools():
            for name, arr in _pools(draws, G, has_haz, resil).items():
                ar_g[name] = (arr if traj_sh is None
                              else jax.device_put(arr, rep_sh))

        _mount_pools()
        while True:
            st = fn(st, ar_g)
            h = jax.device_get({"done": st["done"],
                                "stalled": st["stalled"]})
            if np.any(h["stalled"] & valid):
                # deepest replacement chains outgrew the pools: double
                # them and replay the frozen trajectories' pending rounds
                G *= 2
                _mount_pools()
                st = dict(st)
                st["stalled"] = _put(np.zeros(len(sel), bool), traj_sh)
            keep = valid & ~np.asarray(h["done"])
            a = int(keep.sum())
            if a == 0:
                _scatter(np.flatnonzero(valid))
                break
            w2 = max(COMPACT_MIN, _pow2ceil(a))
            if n_dev > 1:
                w2 = -(-w2 // n_dev) * n_dev
            if w2 < len(sel):
                _scatter(np.flatnonzero(valid & ~keep))
                idx = np.zeros(w2, np.int32)
                idx[:a] = np.flatnonzero(keep)
                idx_d = _put(idx, traj_sh)
                padmask = np.zeros(w2, bool)
                padmask[a:] = True
                st = {key: _put(jnp.take(v, idx_d, axis=0), traj_sh)
                      for key, v in st.items()}
                st["done"] = jnp.logical_or(st["done"],
                                            _put(padmask, traj_sh))
                sel = sel[idx]
                valid = ~padmask

    price = np.array([sim.price_of.get(g, 0.0) for _, g, _, _ in roster])
    cost = (res["alive_seconds"] / 3600.0) @ price
    regions = {region for _, _, region, _ in roster}
    region = regions.pop() if len(regions) == 1 else ""
    if raw:
        return {"total_time_s": res["t"],
                "steps_done": (res["steps"] + 1e-6).astype(np.int64),
                "revocations": res["revocations"],
                "replacements": res["replacements"],
                "checkpoint_time_s": res["ckpt_time"],
                "recompute_time_s": res["recompute"],
                "lost_steps": res["lost"], "monetary_cost": cost,
                "paused_s": res["paused"],
                "restore_delay_s": res["restore_s"]}
    return [SimResult(
        total_time_s=float(res["t"][j]),
        steps_done=int(res["steps"][j] + 1e-6),
        revocations=int(res["revocations"][j]),
        replacements=int(res["replacements"][j]),
        checkpoint_time_s=float(res["ckpt_time"][j]),
        recompute_time_s=float(res["recompute"][j]),
        lost_steps=float(res["lost"][j]),
        events=[], monetary_cost=float(cost[j]),
        provider=sim.provider.name, region=region,
        paused_s=float(res["paused"][j]),
        restore_delay_s=float(res["restore_s"][j])) for j in range(n)]
