"""Lockstep batched fleet engine — all N trajectories as one array program.

`FleetSim.run` walks one trajectory through a Python discrete-event loop
(heapq + per-interval stepping); fine for a single §VI-A validation run,
but ensembles and the sim-backed planner want 10k+ trajectories per call.
This module advances the whole ensemble simultaneously: per-trajectory
state lives in `(n,)` arrays, per-worker state in `(n, slots)` arrays, and
each lockstep round advances every live trajectory to its own next event
(a vectorized min-reduction over scheduled revocations/joins and the
Eq (4)-style time-to-finish) and applies at most one event per trajectory
with masked array ops. docs/DESIGN.md §2 documents the state layout and
the parity contract with the event engine.

Randomness is shared with the event engine through `FleetDraws`:

* initial lifetimes are pre-drawn as ONE `(n, slots)` matrix (one batched
  `RevocationSampler.lifetimes` call per (region, gpu) roster group — the
  exact scheme `run_many` has used since the vectorized-MC PR);
* every replacement-chain draw (startup stages after a revocation, the
  cold start, the replacement's own lifetime at its realized join hour)
  comes from a counter-based stream keyed by (seed, trajectory, slot,
  generation), so both engines consume identical values no matter in
  which order they reach each event.

That makes `run_many(engine="batched")` and `run_many(engine="event")`
trajectory-for-trajectory comparable: identical revocation/replacement
counts, and times/costs equal up to float association order (the batched
stepper uses a closed form for the checkpoint-pause walk the event loop
does incrementally). tests/test_fleet_batched.py pins both properties.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List, Optional

import numpy as np

from repro.core.perf_model.cluster_model import PSBottleneckModel
from repro.core.transient.revocation import RevocationSampler
from repro.core.transient.startup import POST_REVOCATION_COV

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.transient.fleet import FleetSim, SimResult


class FleetDraws:
    """Deterministic random draws shared by both fleet engines.

    One instance covers one `run_many` call:

    * `initial` — the pre-drawn `(n, slots)` initial-lifetime matrix
      (hours, np.inf = survived), one batched
      `RevocationSampler.lifetimes` call per (region, gpu) roster group.
    * replacement chains — per *generation level* g (the g-th
      replacement a slot has seen), one pre-drawn pool: an `(n, slots)`
      matrix of join delays (post-revocation §V-B startup + Fig 10 cold
      start, drawn in one vectorized call) and an `(n, slots, K)` block
      of uniforms the lifetime law turns into the replacement's lifetime
      at its realized join hour (`LifetimeLaw.sample_from_uniforms`).
      Pools are keyed on (seed, level) and drawn lazily, so both engines
      read identical values no matter in which order they reach each
      event. Laws without a uniform-block sampler fall back to one
      counter-based stream per (trajectory, slot, generation).
    """

    def __init__(self, sim: "FleetSim", n: int, start_hour: float):
        self.seed = int(sim.seed)
        self.provider = sim.provider
        self.model_gflops = sim.model_gflops
        self.start_hour = float(start_hour)
        # the sim's chaos timeline (hazard faults transform every lifetime
        # this object hands out — both engines therefore share identical
        # post-fault revocation timelines by construction)
        self.chaos = getattr(sim, "chaos", None)
        roster = sim._roster
        self.n = n
        self.n_slots = len(roster)
        groups = {}
        for idx, (_, gpu, region, _) in enumerate(roster):
            groups.setdefault((region, gpu), []).append(idx)
        samp = RevocationSampler(self.seed, self.provider)
        pre = np.empty((n, len(roster)))
        for (region, gpu), idxs in groups.items():
            draws = samp.lifetimes(region, gpu, n * len(idxs), start_hour)
            pre[:, idxs] = draws.reshape(n, len(idxs))
        if self.chaos is not None:
            pre = self.chaos.transform_initial(pre)
        self.initial = pre
        # per-slot laws and delay moments, resolved once
        self._laws = [self.provider.lifetime_model(region, gpu)
                      for _, gpu, region, _ in roster]
        anchors = self.provider.replacement_anchors()
        cold = anchors.cold_start_s(self.model_gflops)
        self._delay_means = np.array(
            [list(self.provider.startup_stages(gpu).means(True)) + [cold]
             for _, gpu, _, _ in roster])                       # (S, 4)
        self._delay_sds = self._delay_means * POST_REVOCATION_COV
        self._delay_sds[:, 3] = 0.05 * self._delay_means[:, 3]
        # laws without a uniform-block sampler draw from per-key fallback
        # streams, so their pool contribution is a single placeholder
        # column, not the default 33
        self._K = max([getattr(law, "SAMPLE_UNIFORMS_K", 33)
                       if getattr(law, "sample_from_uniforms", None)
                       is not None else 1
                       for law in self._laws], default=1)
        self._levels = {}
        # restore-retry stall pools (repro.resilience): keyed like the
        # replacement levels, shared by all three engines
        self._stall_levels = {}

    def _level(self, gen: int):
        """The pre-drawn pool of generation level `gen` (lazy, keyed on
        (seed, gen) — identical whenever and from whichever engine it is
        first requested)."""
        pool = self._levels.get(gen)
        if pool is None:
            rng = np.random.default_rng(np.random.SeedSequence(
                (self.seed % (2 ** 32), 0x6A01, gen)))
            stages = rng.normal(self._delay_means, self._delay_sds,
                                size=(self.n, self.n_slots, 4))
            delays = np.maximum(1.0, stages).sum(axis=-1)
            uniforms = rng.random((self.n, self.n_slots, self._K))
            pool = self._levels[gen] = (delays, uniforms)
        return pool

    def _fallback_rng(self, traj: int, slot: int,
                      gen: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence(
            (self.seed % (2 ** 32), int(traj), int(slot), int(gen))))

    def replacement_delay(self, traj: int, slot: int, gen: int) -> float:
        """Seconds from a revocation to the replacement's join: the §V-B
        post-revocation startup (4x CoV) plus the Fig 10 cold start —
        the same laws `StartupModel.sample(after_revocation=True)` and
        `ReplacementModel.sample(cold=True)` draw from. The draw is fully
        determined by the slot (a replacement inherits its slot's gpu)."""
        return float(self._level(gen)[0][traj, slot])

    def _raw_join_lifetime(self, traj: int, slot: int, gen: int,
                           start_hour_abs: float) -> float:
        law = self._laws[slot]
        if getattr(law, "sample_from_uniforms", None) is None:
            return float(law.sample(self._fallback_rng(traj, slot, gen),
                                    1, start_hour_abs)[0])
        U = self._level(gen)[1][traj, slot][None, :]
        return float(law.sample_from_uniforms(
            U, np.array([start_hour_abs]))[0])

    def join_lifetime(self, traj: int, slot: int, gen: int,
                      start_hour_abs: float) -> float:
        """The replacement's own lifetime (hours; np.inf = survived),
        drawn at its realized local join hour so diurnal laws see it —
        from the slot's own (region, gpu) lifetime law. Chaos hazard
        faults (keyed on (seed, fault, traj, slot, gen)) then thin it."""
        lt = self._raw_join_lifetime(traj, slot, gen, start_hour_abs)
        if self.chaos is not None:
            lt = float(self.chaos.transform_joins(
                np.array([lt]), np.array([traj]), np.array([slot]),
                np.array([gen]),
                np.array([start_hour_abs - self.start_hour]))[0])
        return lt

    def restore_stall_level(self, res, gen: int) -> np.ndarray:
        """The `(n, slots)` restore-retry stall matrix (seconds) for
        generation level `gen` — the keyed-deterministic delay a
        stock-chief trajectory spends retrying its checkpoint reload
        after the slot's generation-`gen` occupant is revoked (lazy,
        keyed on (seed + resilience seed, gen); identical whichever
        engine asks first)."""
        pool = self._stall_levels.get(gen)
        if pool is None:
            from repro.resilience.policy import stall_pool
            pool = self._stall_levels[gen] = stall_pool(
                res, self.seed, self.n, self.n_slots, gen)
        return pool

    def restore_stall(self, res, traj: int, slot: int, gen: int) -> float:
        return float(self.restore_stall_level(res, gen)[traj, slot])

    def restore_stalls_batch(self, res, trajs: np.ndarray,
                             slots: np.ndarray,
                             gens: np.ndarray) -> np.ndarray:
        """Vectorized `restore_stall` over one lockstep round's
        stock-chief revocations, grouped by generation level."""
        out = np.empty(len(trajs))
        for g in np.unique(gens):
            rows = gens == g
            out[rows] = self.restore_stall_level(res, int(g))[trajs[rows],
                                                              slots[rows]]
        return out

    def replacement_delays_batch(self, trajs: np.ndarray, slots: np.ndarray,
                                 gens: np.ndarray) -> np.ndarray:
        """Vectorized `replacement_delay` over one lockstep round's
        revocations, grouped by generation level."""
        out = np.empty(len(trajs))
        for g in np.unique(gens):
            rows = gens == g
            out[rows] = self._level(int(g))[0][trajs[rows], slots[rows]]
        return out

    def join_lifetimes_batch(self, trajs: np.ndarray, slots: np.ndarray,
                             gens: np.ndarray,
                             hours: np.ndarray) -> np.ndarray:
        """Vectorized `join_lifetime` over one lockstep round's joins,
        grouped by roster slot (= by lifetime law)."""
        out = np.empty(len(trajs))
        for s in np.unique(slots):
            rows = np.where(slots == s)[0]
            law = self._laws[s]
            if getattr(law, "sample_from_uniforms", None) is None:
                out[rows] = [self._raw_join_lifetime(int(i), int(s), int(g),
                                                     float(h))
                             for i, g, h in zip(trajs[rows], gens[rows],
                                                hours[rows])]
                continue
            gg = gens[rows]
            U = np.empty((rows.size, self._K))
            for g in np.unique(gg):
                sub = gg == g
                U[sub] = self._level(int(g))[1][trajs[rows[sub]], s]
            out[rows] = law.sample_from_uniforms(U, hours[rows])
        if self.chaos is not None:
            out = self.chaos.transform_joins(
                out, trajs, slots, gens,
                np.asarray(hours, float) - self.start_hour)
        return out


@dataclasses.dataclass
class _State:
    """The lockstep ensemble state: `(n,)` per-trajectory arrays plus
    `(n, slots)` per-worker-slot arrays. A *slot* is one launch-roster
    position; a revoked slot whose replacement is pending has
    `alive=False` and a finite `join_t`, and the joined worker inherits
    the slot's (gpu, region, speed) with `gen` bumped — exactly the
    identity chain the event engine's wid dict builds one object at a
    time."""
    t: np.ndarray              # (n,) sim clock, seconds
    steps: np.ndarray          # (n,) fractional steps done
    last_ckpt: np.ndarray      # (n,) last checkpointed step
    ckpt_time: np.ndarray      # (n,) cumulative checkpoint pause, s
    recompute: np.ndarray      # (n,) cumulative recompute accounting, s
    lost: np.ndarray           # (n,) steps rolled back (stock chief loss)
    revocations: np.ndarray    # (n,) int
    replacements: np.ndarray   # (n,) int
    alive: np.ndarray          # (n, S) bool
    chief: np.ndarray          # (n, S) bool
    gen: np.ndarray            # (n, S) int: generation occupying the slot
    order_key: np.ndarray      # (n, S) dict-insertion rank (chief promotion)
    next_key: np.ndarray       # (n,) next insertion rank to hand out
    revoke_t: np.ndarray       # (n, S) absolute revocation time, s (inf=none)
    join_t: np.ndarray         # (n, S) absolute pending-join time, s (inf=none)
    alive_seconds: np.ndarray  # (n, S) cost integrator: alive wall-clock
    done: np.ndarray           # (n,) bool
    stall_t: np.ndarray        # (n,) restore-retry stall end, s (<=t: none)
    paused: np.ndarray         # (n,) quorum-pause seconds accrued
    restore_s: np.ndarray      # (n,) restore-retry stall seconds accrued


def run_batched(sim: "FleetSim", total_steps: int, n: int,
                max_hours: float = 48.0, start_hour: float = 0.0,
                draws: Optional[FleetDraws] = None, raw: bool = False):
    """Advance `n` trajectories of `sim`'s launch roster in lockstep.

    Returns one `SimResult` per trajectory (in trajectory order). The
    per-event text log is not materialized (`events=[]`) — it is the one
    `SimResult` field that cannot be array-typed; everything else matches
    the event engine under the shared-`draws` contract. `raw=True`
    returns the same stats as a dict of per-trajectory arrays instead of
    `SimResult` objects — the engine-core form `bench_jit_engine` times
    (building n dataclasses costs more than a 65k-trajectory ensemble
    run) and array consumers aggregate directly.
    """
    from repro.core.transient.fleet import SimResult

    if n < 1:
        raise ValueError(f"need at least one trajectory, got {n}")
    if draws is None:
        draws = FleetDraws(sim, n, start_hour)
    roster = sim._roster
    S = len(roster)
    slot_gpu = [gpu for _, gpu, _, _ in roster]
    slot_region = [region for _, _, region, _ in roster]
    slot_speed = np.array([speed for _, _, _, speed in roster], float)
    cap = PSBottleneckModel(sim.model_bytes, sim.n_ps,
                            n_tensors=sim.n_tensors,
                            compression=sim.grad_compression
                            ).capacity_steps_per_s()
    i_c, t_c = float(sim.i_c), float(sim.t_c)
    total = float(total_steps)
    tmax = max_hours * 3600.0
    chaos = getattr(sim, "chaos", None)
    handover, replace = sim.handover, sim.replace
    graceful = (sim.provider.graceful_checkpoint_on_warning
                and sim.provider.warning_seconds >= sim.t_c)
    # resilience (docs/resilience.md): quorum degradation gates effective
    # speed on the alive fraction; stock-chief restores stall for the
    # keyed retry schedule. res_on=False keeps every array op untouched.
    res = getattr(sim, "resilience", None)
    res_on = res is not None
    stall_on = res_on and res.restore_fail_p > 0.0
    if res_on:
        quorum = float(res.degradation.quorum)
        shrink_below = float(res.degradation.shrink_below)
        shrink_factor = float(res.degradation.shrink_factor)

    st = _State(
        t=np.zeros(n), steps=np.zeros(n), last_ckpt=np.zeros(n),
        ckpt_time=np.zeros(n), recompute=np.zeros(n), lost=np.zeros(n),
        revocations=np.zeros(n, int), replacements=np.zeros(n, int),
        alive=np.ones((n, S), bool), chief=np.zeros((n, S), bool),
        gen=np.zeros((n, S), int),
        order_key=np.tile(np.arange(S, dtype=float), (n, 1)),
        next_key=np.full(n, float(S)),
        revoke_t=np.where(np.isfinite(draws.initial),
                          draws.initial * 3600.0, np.inf),
        join_t=np.full((n, S), np.inf),
        alive_seconds=np.zeros((n, S)),
        done=np.zeros(n, bool),
        stall_t=np.zeros(n), paused=np.zeros(n), restore_s=np.zeros(n))
    st.chief[:, 0] = True   # FleetSim.__init__ marks workers[0] chief

    def _cluster_speed(rows: np.ndarray) -> np.ndarray:
        if chaos is None:
            return np.minimum(st.alive[rows] @ slot_speed, cap)
        # chaos factors at the segment start: straggler multipliers per
        # slot plus the PS capacity factor (constant within any advanced
        # span — factor boundaries are lockstep events)
        m = chaos.speed_mults(st.t[rows])
        return np.minimum((st.alive[rows] * m) @ slot_speed,
                          cap * chaos.ps_factor(st.t[rows]))

    def _degr_factor(rows: np.ndarray) -> np.ndarray:
        """Quorum-tier speed factor per row: pause (0) below `quorum`
        alive fraction, `shrink_factor` below `shrink_below`, else 1.
        The factor gates forward progress only — the stock-chief
        recompute conversion stays at raw cluster speed (recompute
        happens after the fleet recovers)."""
        frac = st.alive[rows].sum(axis=1) / S
        return np.where(frac < quorum, 0.0,
                        np.where(frac < shrink_below, shrink_factor, 1.0))

    def _advance(rows: np.ndarray, target: np.ndarray) -> None:
        """Closed form of the event engine's `advance`: walk `rows` from
        their clocks to `target`, producing steps at cluster speed with a
        sequential `t_c` pause at every `i_c` boundary. k boundaries fit
        in a span: the first at `b0/sp`, each further one a full
        `i_c/sp + t_c` cycle later; only the final pause can be partial."""
        span = target - st.t[rows]
        a = st.alive[rows]
        st.alive_seconds[rows] += a * span[:, None]
        if chaos is None:
            sp = np.minimum(a @ slot_speed, cap)
            blk = np.zeros(rows.size, bool)
        else:
            m = chaos.speed_mults(st.t[rows])
            sp = np.minimum((a * m) @ slot_speed,
                            cap * chaos.ps_factor(st.t[rows]))
            blk = chaos.ckpt_blocked(st.t[rows])
        if res_on:
            # stall/pause gating (the event engine's `advance` mirror):
            # spans never cross a stall end or a membership event, so
            # both conditions are constant within this segment
            stalled = st.t[rows] < st.stall_t[rows]
            factor = _degr_factor(rows)
            st.restore_s[rows] += np.where(stalled, span, 0.0)
            st.paused[rows] += np.where(~stalled & (factor == 0.0),
                                        span, 0.0)
            sp = np.where(stalled, 0.0, sp * factor)
        pos = (sp > 0) & (span > 1e-12)
        if pos.any():
            spp = np.where(pos, sp, 1.0)
            s0 = st.steps[rows]
            b0 = i_c - s0 % i_c
            b0 = np.where(b0 <= 1e-9, i_c, b0)
            d0 = b0 / spp
            cycle = i_c / spp + t_c
            k = np.where(span >= d0,
                         np.floor((span - d0) / cycle) + 1.0, 0.0)
            r = span - d0 - (k - 1.0) * cycle
            pause = np.minimum(t_c, r)
            boundary = s0 + b0 + (k - 1.0) * i_c
            stepped = np.where(
                k > 0, boundary + spp * np.maximum(0.0, r - pause),
                s0 + spp * span)
            new_ck = np.where(k > 0, (k - 1.0) * t_c + pause, 0.0)
            # checkpoint-store outage: steps keep flowing, nothing saves —
            # no pause, and last_ckpt freezes (the event engine's blocked
            # branch in `advance`)
            stepped = np.where(blk, s0 + spp * span, stepped)
            new_ck = np.where(blk, 0.0, new_ck)
            st.steps[rows] = np.where(pos, stepped, s0)
            st.ckpt_time[rows] += np.where(pos, new_ck, 0.0)
            st.last_ckpt[rows] = np.where(pos & (k > 0) & ~blk,
                                          np.round(boundary),
                                          st.last_ckpt[rows])
        st.t[rows] = target

    while True:
        act = ~st.done
        if not act.any():
            break
        rows = np.where(act)[0]
        ev_all = np.concatenate([st.revoke_t[rows], st.join_t[rows]], axis=1)
        ev_arg = np.argmin(ev_all, axis=1)
        ev_t = ev_all[np.arange(rows.size), ev_arg]
        sp = _cluster_speed(rows)
        if chaos is None:
            blk = np.zeros(rows.size, bool)
            nb = np.full(rows.size, np.inf)
        else:
            blk = chaos.ckpt_blocked(st.t[rows])
            # factor-change boundaries are (no-op) events, exactly like
            # the heap entries the event engine pushes — and like those,
            # boundaries at/after tmax are never scheduled
            nb = chaos.next_boundary(st.t[rows])
            nb = np.where(nb < tmax, nb, np.inf)
        if res_on:
            # a pending stall end is a pure-advancement boundary, exactly
            # like a chaos factor change (the event engine's no-op
            # "resume" heap entry); effective speed is gated meanwhile
            stall_ev = np.where(st.stall_t[rows] > st.t[rows],
                                st.stall_t[rows], np.inf)
            nb = np.minimum(nb, stall_ev)
            sp = np.where(np.isfinite(stall_ev), 0.0,
                          sp * _degr_factor(rows))
        with np.errstate(divide="ignore", invalid="ignore"):
            rel = np.where(
                sp > 0,
                (total - st.steps[rows]) / np.where(sp > 0, sp, 1.0)
                + np.where(blk, 0.0,
                           (np.floor(total / i_c)
                            - np.floor(st.steps[rows] / i_c)) * t_c),
                np.inf)
        t_fin = st.t[rows] + rel
        # the event loop's `sp <= 0 and not q: break` — all dead, nothing
        # scheduled (not even a chaos boundary that could revive the PS):
        # freeze the trajectory where it stands
        stuck = np.isinf(ev_t) & (sp <= 0) & np.isinf(nb)
        st.done[rows[stuck]] = True
        nxt = np.minimum(ev_t, nb)
        # matches `if q and q[0].t < t_finish` (strict)
        ev = ~stuck & (nxt < t_fin)
        fin = ~stuck & ~ev
        move = rows[ev | fin]
        target = np.where(ev, np.maximum(nxt, st.t[rows]), t_fin)[ev | fin]
        _advance(move, target)
        st.done[rows[fin]] = True   # steps reached total (modulo float fuzz)

        # a chaos boundary (nb < ev_t) is pure advancement — only worker
        # events mutate fleet state
        real = ev & (ev_t <= nxt)
        er = rows[real]
        if er.size:
            slot = ev_arg[real] % S
            is_join = ev_arg[real] >= S
            # ---------------------------------------------------- revokes
            ri, rs = er[~is_join], slot[~is_join]
            if ri.size:
                was_chief = st.chief[ri, rs]
                st.alive[ri, rs] = False
                st.revoke_t[ri, rs] = np.inf
                st.revocations[ri] += 1
                if handover:
                    hri, hrs = ri[was_chief], rs[was_chief]
                    if hri.size:
                        st.chief[hri, hrs] = False
                        # promote the first-inserted alive worker — the
                        # event engine's dict-order scan
                        keys = np.where(st.alive[hri], st.order_key[hri],
                                        np.inf)
                        best = np.argmin(keys, axis=1)
                        has = np.isfinite(
                            keys[np.arange(hri.size), best])
                        st.chief[hri[has], best[has]] = True
                elif graceful:
                    # the market's notice window covers T_c: flush a
                    # checkpoint at the current step, lose nothing
                    gri = ri[was_chief]
                    st.last_ckpt[gri] = np.round(st.steps[gri])
                else:
                    sri = ri[was_chief]
                    if sri.size:
                        lost_now = st.steps[sri] - st.last_ckpt[sri]
                        st.steps[sri] = st.last_ckpt[sri]
                        st.lost[sri] += lost_now
                        sp_after = _cluster_speed(sri)
                        st.recompute[sri] += (lost_now
                                              / np.maximum(sp_after, 1e-9))
                        if stall_on:
                            # restore-retry stall: the trajectory reloads
                            # its checkpoint under the retry schedule —
                            # keyed on the revoked occupant's generation,
                            # drawn BEFORE the replacement bumps it. A
                            # later stall overwrites an active one.
                            srs = rs[was_chief]
                            delay = draws.restore_stalls_batch(
                                res, sri, srs, st.gen[sri, srs])
                            st.stall_t[sri] = st.t[sri] + delay
                if replace:
                    new_gen = st.gen[ri, rs] + 1
                    delay = draws.replacement_delays_batch(ri, rs, new_gen)
                    st.join_t[ri, rs] = st.t[ri] + delay
                    st.gen[ri, rs] = new_gen
                    # stock mode: the replacement inherits the chief
                    # identity (st.chief[slot] is simply left set);
                    # handover already cleared it above
            # ------------------------------------------------------ joins
            ji, js = er[is_join], slot[is_join]
            if ji.size:
                st.alive[ji, js] = True
                st.join_t[ji, js] = np.inf
                st.replacements[ji] += 1
                st.order_key[ji, js] = st.next_key[ji]
                st.next_key[ji] += 1
                lts = draws.join_lifetimes_batch(
                    ji, js, st.gen[ji, js], start_hour + st.t[ji] / 3600.0)
                st.revoke_t[ji, js] = np.where(
                    np.isfinite(lts), st.t[ji] + lts * 3600.0, np.inf)
        st.done |= st.steps >= total - 1e-6
        st.done |= st.t >= tmax

    price = np.array([sim.price_of.get(g, 0.0) for g in slot_gpu])
    cost = (st.alive_seconds / 3600.0) @ price
    regions = set(slot_region)
    region = regions.pop() if len(regions) == 1 else ""
    if raw:
        return {"total_time_s": st.t,
                "steps_done": (st.steps + 1e-6).astype(np.int64),
                "revocations": st.revocations.astype(np.int64),
                "replacements": st.replacements.astype(np.int64),
                "checkpoint_time_s": st.ckpt_time,
                "recompute_time_s": st.recompute,
                "lost_steps": st.lost, "monetary_cost": cost,
                "paused_s": st.paused, "restore_delay_s": st.restore_s}
    return [SimResult(
        total_time_s=float(st.t[j]),
        steps_done=int(st.steps[j] + 1e-6),
        revocations=int(st.revocations[j]),
        replacements=int(st.replacements[j]),
        checkpoint_time_s=float(st.ckpt_time[j]),
        recompute_time_s=float(st.recompute[j]),
        lost_steps=float(st.lost[j]),
        events=[], monetary_cost=float(cost[j]),
        provider=sim.provider.name, region=region,
        paused_s=float(st.paused[j]),
        restore_delay_s=float(st.restore_s[j])) for j in range(n)]
