"""§V-C — revocation characterization: per-(region, GPU) lifetime models with
time-of-day hazard modulation, calibrated to the paper's published fleet data
(Table V revocation rates, Fig 8 lifetime CDFs, Fig 9 diurnal patterns).

Lifetime = Weibull(k, λ) truncated at the 24 h maximum, scaled so
P(revoked < 24h) equals Table V's rate for that (region, GPU). The paper's
empirical CDFs are exposed via `cdf()` / `sample()` / `prob_revoked_within()`
— Eq (5) queries the latter.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

MAX_LIFETIME_H = 24.0

# Table V: revocation % within 24h per (region, gpu); None = not offered.
TABLE5_RATES: Dict[Tuple[str, str], Optional[float]] = {
    ("us-east1", "k80"): 0.4667, ("us-east1", "p100"): 0.70,
    ("us-east1", "v100"): None,
    ("us-central1", "k80"): 0.5625, ("us-central1", "p100"): 0.5333,
    ("us-central1", "v100"): 0.6667,
    ("us-west1", "k80"): 0.2292, ("us-west1", "p100"): 0.6667,
    ("us-west1", "v100"): 0.7333,
    ("europe-west1", "k80"): 0.6667, ("europe-west1", "p100"): 0.2667,
    ("europe-west1", "v100"): None,
    ("europe-west4", "v100"): 0.43,
    ("asia-east1", "v100"): 0.47,
}

# Fig 8-informed shape/scale seeds: (weibull_k, mean_hint_hours).
# k<1 => front-loaded revocations (europe-west1 k80: >50% die in 2h);
# k>1 => later revocations (us-west1 k80: <5% in 2h, MTTR 19.8h).
_SHAPE_HINTS: Dict[Tuple[str, str], Tuple[float, float]] = {
    ("europe-west1", "k80"): (0.3, 10.6),   # >50% die in 2h, long tail
    ("us-west1", "k80"): (2.8, 19.8),
    ("us-central1", "k80"): (1.6, 14.0),
    ("us-east1", "k80"): (1.2, 12.0),
    ("us-central1", "v100"): (0.9, 7.7),
    ("us-west1", "v100"): (1.0, 8.5),
    ("europe-west4", "v100"): (1.3, 13.0),
    ("asia-east1", "v100"): (1.3, 12.5),
    ("us-east1", "p100"): (1.0, 9.0),
    ("us-central1", "p100"): (1.3, 12.0),
    ("us-west1", "p100"): (1.0, 9.5),
    ("europe-west1", "p100"): (1.8, 16.0),
}

# Fig 9: diurnal hazard multipliers (local hour). K80 peaks ~10AM;
# V100 has no revocations 4-8PM; P100 mildly business-hours-loaded.
# Upper bound on every weight, used as the thinning envelope.
_DIURNAL_MAX_WEIGHT = 2.5


def _diurnal_weight(gpu: str, hour) -> np.ndarray:
    """Vectorized over `hour` (scalar in, scalar-shaped array out)."""
    h = np.asarray(hour, float) % 24.0
    if gpu == "k80":
        return 1.0 + 1.5 * np.exp(-((h - 10.0) ** 2) / (2 * 2.0 ** 2))
    if gpu == "v100":
        w = 1.0 + 0.6 * np.exp(-((h - 9.0) ** 2) / (2 * 3.0 ** 2))
        return np.where((h >= 16.0) & (h < 20.0), 0.0, w)
    return 1.0 + 0.8 * np.exp(-((h - 13.0) ** 2) / (2 * 4.0 ** 2))


@dataclasses.dataclass
class LifetimeModel:
    """Truncated-Weibull lifetime with survival mass at 24h."""
    region: str
    gpu: str
    k: float
    lam: float
    p24: float  # P(revoked < 24h)

    #: uniform-block width for `sample_from_uniforms` (LifetimeLaw
    #: contract, repro/providers/base.py): 1 survival column + 16
    #: (candidate, accept) thinning pairs
    SAMPLE_UNIFORMS_K = 33

    @classmethod
    def calibrated(cls, region: str, gpu: str) -> "LifetimeModel":
        key = (region, gpu)
        rate = TABLE5_RATES.get(key)
        if rate is None:
            raise KeyError(f"{key} not offered in the paper's fleet")
        k, mean_hint = _SHAPE_HINTS.get(key, (1.2, 12.0))
        # λ from the mean hint of the *conditional* (revoked) lifetime;
        # Weibull mean = λ Γ(1+1/k)
        lam = mean_hint / math.gamma(1.0 + 1.0 / k)
        return cls(region, gpu, k, lam, rate)

    # CDF of the observable lifetime (with a point mass surviving to 24h)
    def cdf(self, t_hours: np.ndarray) -> np.ndarray:
        t = np.minimum(np.asarray(t_hours, float), MAX_LIFETIME_H)
        raw = 1.0 - np.exp(-((t / self.lam) ** self.k))
        raw24 = 1.0 - math.exp(-((MAX_LIFETIME_H / self.lam) ** self.k))
        return self.p24 * raw / max(raw24, 1e-12)

    def prob_revoked_within(self, t_hours: float) -> float:
        """Pr(R_i) for Eq (5): probability of revocation within t_hours."""
        return float(self.cdf(np.array([t_hours]))[0])

    def sample(self, rng: np.random.Generator, n: int = 1,
               start_hour: float = 0.0) -> np.ndarray:
        """Sample lifetimes in hours; np.inf = survived to the 24h cutoff.
        Thin wrapper over `sample_batch` (identical RNG stream at n=1)."""
        return self.sample_batch(rng, n, start_hour)

    def _inverse_cdf(self, uu: np.ndarray, raw24: float) -> np.ndarray:
        """Candidate revoked lifetimes from uniforms (truncated Weibull)."""
        return self.lam * (-np.log(1.0 - uu * raw24)) ** (1.0 / self.k)

    def sample_batch(self, rng: np.random.Generator, n: int,
                     start_hour: float = 0.0) -> np.ndarray:
        """Vectorized lifetime sampling; np.inf = survived to the 24h cutoff.

        Diurnal modulation is rejection sampling (thinning) on the hazard
        by the local-time weight. For n == 1 the rejection runs in the
        exact per-slot draw order of the pre-vectorization scalar loop, so
        fixed-seed golden values (provider parity tests) stay
        bit-identical. For n > 1 the thinning is *pooled*: candidates for
        every revoked slot are drawn and accept-tested as whole arrays
        (oversampled by the expected rejection rate), and accepted draws
        fill the slots in order — slots are iid, so the pooled scheme
        samples the identical distribution in a bounded handful of rounds
        instead of one Python round per rejection.
        """
        if n == 1:
            return self._sample_scalar(rng, 1, start_hour)
        u = rng.uniform(size=n)
        out = np.full(n, np.inf)
        revoked = u < self.p24
        m = int(np.count_nonzero(revoked))
        if m == 0:
            return out
        raw24 = 1.0 - math.exp(-((MAX_LIFETIME_H / self.lam) ** self.k))
        inv_env = 1.0 / _DIURNAL_MAX_WEIGHT
        vals = np.empty(m)
        got = 0
        for _ in range(16):
            need = m - got
            # ~1/E[w/2.5] candidates per still-empty slot, padded so one
            # round almost always suffices
            k = 3 * need + 16
            cand = self._inverse_cdf(rng.uniform(size=k), raw24)
            w = _diurnal_weight(self.gpu, start_hour + cand)
            acc = cand[rng.uniform(size=k) < w * inv_env]
            take = min(acc.size, need)
            vals[got:got + take] = acc[:take]
            got += take
            if got == m:
                break
        if got < m:
            # pathologically unlucky tail (the slot-wise loop's 64-round
            # cap, ~(1-p)^64): keep the last candidates, pushing any that
            # sit in a hard-zero window past it
            cand = self._inverse_cdf(rng.uniform(size=m - got), raw24)
            w = _diurnal_weight(self.gpu, start_hour + cand)
            vals[got:] = np.where(w == 0.0, cand + 4.0, cand)
        out[revoked] = np.minimum(vals, MAX_LIFETIME_H)
        return out

    def sample_from_uniforms(self, U: np.ndarray,
                             start_hours: np.ndarray) -> np.ndarray:
        """Vectorized lifetimes from a pre-drawn uniform block (the fleet
        engines' replacement-join path; see `LifetimeLaw` in
        repro/providers/base.py for the contract): column 0 decides the
        survival point mass, then up to 16 (candidate, accept) column
        pairs run the Fig 9 diurnal thinning per row — each row has its
        own local start hour, unlike `sample_batch`'s shared one. The
        16-round cap with the hard-zero push fallback mirrors the pooled
        rejection in `sample_batch`."""
        U = np.atleast_2d(np.asarray(U, float))
        hours = np.asarray(start_hours, float)
        m = U.shape[0]
        out = np.full(m, np.inf)
        revoked = U[:, 0] < self.p24
        if not revoked.any():
            return out
        idx = np.where(revoked)[0]
        h = hours[idx]
        raw24 = 1.0 - math.exp(-((MAX_LIFETIME_H / self.lam) ** self.k))
        inv_env = 1.0 / _DIURNAL_MAX_WEIGHT
        cand = self._inverse_cdf(U[idx, 1], raw24)
        pending = U[idx, 2] >= (_diurnal_weight(self.gpu, h + cand)
                                * inv_env)
        for j in range(1, 16):
            if not pending.any():
                break
            rows = np.where(pending)[0]
            c2 = self._inverse_cdf(U[idx[rows], 1 + 2 * j], raw24)
            cand[rows] = c2
            acc = (U[idx[rows], 2 + 2 * j]
                   < _diurnal_weight(self.gpu, h[rows] + c2) * inv_env)
            pending[rows] = ~acc
        if pending.any():
            rows = np.where(pending)[0]
            w = _diurnal_weight(self.gpu, h[rows] + cand[rows])
            cand[rows] = np.where(w == 0.0, cand[rows] + 4.0, cand[rows])
        out[idx] = np.minimum(cand, MAX_LIFETIME_H)
        return out

    def _sample_scalar(self, rng: np.random.Generator, n: int,
                       start_hour: float = 0.0) -> np.ndarray:
        """The pre-vectorization per-slot rejection loop, draw-for-draw:
        per round one acceptance uniform, then (if rejected) one resample
        uniform, 64-round cap with the hard-zero push. Kept verbatim as
        the n=1 dispatch target so fixed-seed goldens and interleaved
        scalar `lifetime()` streams stay bit-identical."""
        u = rng.uniform(size=n)
        out = np.full(n, np.inf)
        revoked = u < self.p24
        # inverse-CDF within the revoked mass
        uu = rng.uniform(size=n)
        raw24 = 1.0 - math.exp(-((MAX_LIFETIME_H / self.lam) ** self.k))
        t = self._inverse_cdf(uu, raw24)
        for i in np.where(revoked)[0]:
            accepted = False
            for _ in range(64):
                w = float(_diurnal_weight(self.gpu, start_hour + t[i]))
                if rng.uniform() < w / _DIURNAL_MAX_WEIGHT:
                    accepted = True
                    break
                t[i] = float(self._inverse_cdf(rng.uniform(), raw24))
            if not accepted and float(_diurnal_weight(
                    self.gpu, start_hour + t[i])) == 0.0:
                t[i] += 4.0  # hard-zero window: push past it
            out[i] = min(t[i], MAX_LIFETIME_H)
        return out

    def mean_time_to_revocation(self) -> float:
        """Conditional mean lifetime of revoked servers (Fig 8 discussion)."""
        ts = np.linspace(0, MAX_LIFETIME_H, 2000)
        c = self.cdf(ts) / max(self.p24, 1e-12)
        return float(np.trapezoid(1.0 - c, ts))

    # Estimator protocol (repro.calibration) ------------------------------
    @classmethod
    def fit(cls, region: str, gpu: str, lifetimes_h,
            k: Optional[float] = None) -> "LifetimeModel":
        """Censored fit from observed lifetimes (np.inf = survived 24h):
        p24 from the finite fraction, λ from the conditional mean of the
        revoked lifetimes, shape k kept from the Fig 8 hint (a Weibull
        shape needs far more data than a mid-run trace provides)."""
        lt = np.asarray(lifetimes_h, float)
        if lt.size == 0:
            raise ValueError("LifetimeModel.fit: no observed lifetimes")
        finite = lt[np.isfinite(lt)]
        p24 = min(max(finite.size / lt.size, 1e-3), 1.0 - 1e-3)
        if k is None:
            k = _SHAPE_HINTS.get((region, gpu), (1.2, 12.0))[0]
        mean_cond = (float(finite.mean()) if finite.size
                     else _SHAPE_HINTS.get((region, gpu), (1.2, 12.0))[1])
        lam = max(mean_cond, 1e-3) / math.gamma(1.0 + 1.0 / k)
        return cls(region, gpu, float(k), lam, p24)

    def predict(self, t_hours: float) -> float:
        return self.prob_revoked_within(t_hours)

    def update(self, lifetimes_h) -> "LifetimeModel":
        return type(self).fit(self.region, self.gpu, lifetimes_h, k=self.k)

    def score(self, lifetimes_h) -> dict:
        """Goodness-of-fit on the one quantity Eq (5) consumes: the 24h
        revocation probability, against the sample's finite fraction."""
        lt = np.asarray(lifetimes_h, float)
        if lt.size == 0:
            raise ValueError("LifetimeModel.score: no observed lifetimes")
        observed = float(np.isfinite(lt).mean())
        return {"n": int(lt.size), "mae": abs(observed - self.p24),
                "mape": abs(observed - self.p24)
                / max(observed, 1e-12) * 100.0}

    def params_hash(self) -> str:
        from repro.calibration.estimator import params_hash
        return params_hash("lifetime", self.region, self.gpu, self.k,
                           self.lam, self.p24)


REGION_GPU_PARAMS = {key: LifetimeModel.calibrated(*key)
                     for key, rate in TABLE5_RATES.items() if rate is not None}


@dataclasses.dataclass
class RevocationSampler:
    """Fleet-level sampler used by the simulator and Eq (5).

    `provider` selects the market whose lifetime laws are sampled (a
    `repro.providers` registry name or instance); the default reproduces
    the paper's GCP fleet bit-for-bit.
    """
    seed: int = 0
    provider: object = "gcp"

    def __post_init__(self):
        from repro.providers import get_provider
        self.rng = np.random.default_rng(self.seed)
        self.provider = get_provider(self.provider)

    def lifetime(self, region: str, gpu: str, start_hour: float = 0.0) -> float:
        return float(self.lifetimes(region, gpu, 1, start_hour)[0])

    def lifetimes(self, region: str, gpu: str, n: int,
                  start_hour: float = 0.0) -> np.ndarray:
        """Batched lifetimes: resolves the lifetime model ONCE and draws
        `n` samples in one vectorized call — the Monte-Carlo hot path of
        the §V-C planner and the simulation ensemble."""
        m = self.provider.lifetime_model(region, gpu)
        return m.sample_batch(self.rng, n, start_hour)

    def prob_revoked_within(self, region: str, gpu: str,
                            t_hours: float) -> float:
        m = self.provider.lifetime_model(region, gpu)
        return m.prob_revoked_within(t_hours)
