"""CM-DARE performance profiler (Fig 1): tracks steps/sec with warmup
discard, rolling averages, coefficient of variation — feeds the controller's
bottleneck detector and retrains the online prediction models.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class StepRecord:
    t: float
    step: int
    loss: Optional[float] = None


class PerformanceProfiler:
    """Mirrors the paper's measurement protocol: average speed every
    `window` steps, discard the first `warmup_steps` (§III-A/B)."""

    def __init__(self, window: int = 100, warmup_steps: int = 100,
                 warmup_seconds: float = 30.0):
        self.window = window
        self.warmup_steps = warmup_steps
        self.warmup_seconds = warmup_seconds
        self.records: List[StepRecord] = []
        self.window_speeds: List[float] = []
        self._win: Deque[StepRecord] = deque()

    def record(self, step: int, t: Optional[float] = None,
               loss: Optional[float] = None) -> None:
        rec = StepRecord(time.monotonic() if t is None else t, step, loss)
        self.records.append(rec)
        self._win.append(rec)
        if len(self._win) > self.window + 1:
            self._win.popleft()
        if len(self._win) >= self.window + 1:
            span = self._win[-1].t - self._win[0].t
            dsteps = self._win[-1].step - self._win[0].step
            if span > 0:
                self.window_speeds.append(dsteps / span)

    def _post_warmup(self) -> List[StepRecord]:
        if not self.records:
            return []
        t0 = self.records[0].t
        return [r for r in self.records
                if r.step >= self.warmup_steps
                and (r.t - t0) >= self.warmup_seconds]

    def speed(self) -> Optional[float]:
        """Current steps/s over post-warmup records."""
        rs = self._post_warmup()
        if len(rs) < 2:
            return None
        span = rs[-1].t - rs[0].t
        return (rs[-1].step - rs[0].step) / span if span > 0 else None

    def cov(self) -> Optional[float]:
        """Coefficient of variation of windowed speeds (Fig 2: <= 0.02)."""
        if len(self.window_speeds) < 2:
            return None
        arr = np.asarray(self.window_speeds, float)
        return float(arr.std() / max(arr.mean(), 1e-12))

    def step_time(self) -> Optional[float]:
        """Seconds per step; `None` only when there is genuinely no data.
        A measured speed of exactly 0.0 (a stalled run) is data — it maps
        to an infinite step time, not to "no measurement"."""
        sp = self.speed()
        if sp is None:
            return None
        return (1.0 / sp) if sp > 0 else float("inf")

    def history(self) -> List[dict]:
        """Export records as plain dicts — the calibration layer's refit
        input (`ClusterSpeedEstimator.fit`) and the Session's profiler
        history surface. Plain data, so consumers can serialize it."""
        return [{"t": r.t, "step": r.step, "loss": r.loss}
                for r in self.records]

    def recent_speed(self, last: int) -> Optional[float]:
        """Steps/s over the trailing `last` records only — what a refit
        wants after a regime change (the full-window `speed()` still
        averages across the shift)."""
        rs = self.records[-max(int(last), 2):]
        if len(rs) < 2:
            return None
        span = rs[-1].t - rs[0].t
        return (rs[-1].step - rs[0].step) / span if span > 0 else None
