"""Revocation-aware launch planner — the paper's §V-C future work, built:
"investigating how strategically launching transient clusters at different
times of day and different data center locations can help mitigate
revocation impacts."

For a desired (GPU, cluster size, workload), score every (region,
launch-hour) offering that GPU: Monte-Carlo the diurnal-aware lifetime model
for E[revocations] during the run, push that through Eq (4) for expected
wall-clock, and price the result (transient rates + replacement overheads).
Returns the Pareto plan (min expected cost, tie-broken by time).

`provider=` selects the market being planned over (DESIGN.md §5): regions,
lifetime laws, startup/replacement overheads and prices all come from the
`repro.providers` adapter, so the same planner compares GCP preemptible,
AWS spot and Azure low-priority offerings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.perf_model.cluster_model import (Eq4Inputs, WorkerSpec,
                                                 cluster_speed,
                                                 predict_total_time)
from repro.core.transient.replacement import ReplacementModel
from repro.core.transient.revocation import RevocationSampler
from repro.core.transient.startup import StartupModel


@dataclasses.dataclass
class LaunchPlan:
    region: str
    gpu: str
    launch_hour: int
    n_workers: int
    expected_revocations: float
    expected_time_s: float
    expected_cost: float
    provider: str = "gcp"


def expected_revocations_mc(region: str, gpu: str, start_hour: float,
                            run_hours: float, n_workers: int,
                            samples: int = 200, seed: int = 0,
                            provider: object = "gcp") -> float:
    """Diurnal-aware E[revocations]: MC over the lifetime sampler (the CDF
    alone is launch-hour-agnostic)."""
    samp = RevocationSampler(seed, provider)
    horizon = min(run_hours, samp.provider.max_lifetime_hours)
    hits = 0
    for s in range(samples):
        lt = samp.lifetime(region, gpu, start_hour=start_hour)
        if math.isfinite(lt) and lt <= horizon:
            hits += 1
    return n_workers * hits / samples


def plan_launch(gpu: str, n_workers: int, worker_speed: float,
                n_w: int, i_c: int, t_c: float,
                hours: Optional[List[int]] = None,
                seed: int = 0,
                provider: object = "gcp",
                model_gflops: float = 1.54) -> Tuple[LaunchPlan,
                                                     List[LaunchPlan]]:
    """Scores all (region, hour) cells of one provider; returns (best, all).

    worker_speed: steps/s per worker for the target model (from the §III
    predictors); model_gflops: its complexity C_m, which sets the Fig 10
    replacement cold-start (default: the paper's ResNet-32). Costing:
    transient hourly price x workers x expected time, replacement overhead
    included via Eq (4).
    """
    from repro.providers import get_provider
    prov = get_provider(provider)
    prov.check_gpu_offered(gpu)
    hours = hours if hours is not None else list(range(0, 24, 3))
    startup = StartupModel(seed, prov)
    repl = ReplacementModel(seed, prov)
    price = prov.price(gpu)
    sp = cluster_speed([WorkerSpec(gpu, worker_speed)] * n_workers)
    base_hours = n_w / sp / 3600.0
    t_p = startup.mean_total(gpu)
    t_s = repl.cold_start_s(model_gflops)
    plans: List[LaunchPlan] = []
    for region in prov.regions_offering(gpu):
        for h in hours:
            n_r = expected_revocations_mc(region, gpu, float(h), base_hours,
                                          n_workers, seed=seed,
                                          provider=prov)
            # spread Pr over workers equally for Eq (5)
            probs = [n_r / n_workers] * n_workers
            t = predict_total_time(sp, Eq4Inputs(n_w, i_c, t_c, t_p, t_s,
                                                 probs))
            cost = (t / 3600.0) * n_workers * price \
                + n_r * (t_p / 3600.0) * price
            plans.append(LaunchPlan(region, gpu, h, n_workers, n_r, t, cost,
                                    prov.name))
    best = min(plans, key=lambda p: (p.expected_cost, p.expected_time_s))
    return best, plans
