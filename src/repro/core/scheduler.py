"""Revocation-aware launch planner — the paper's §V-C future work, built:
"investigating how strategically launching transient clusters at different
times of day and different data center locations can help mitigate
revocation impacts."

For a desired (GPU, cluster size, workload), score every (region,
launch-hour) offering that GPU: Monte-Carlo the diurnal-aware lifetime model
for E[revocations] during the run, push that through Eq (4) for expected
wall-clock, and price the result (transient rates + replacement overheads).
Returns the Pareto plan (min expected cost, tie-broken by time).

The Monte-Carlo core is batched (docs/performance.md): each (region, hour)
cell is ONE `RevocationSampler.lifetimes` draw — the lifetime model is
resolved once and `samples` candidates come back as an array, then scored
through the shared Eq (4) (`predict_total_time`, so plan() and predict()
can never drift apart) with the startup/replacement means hoisted out of
the loop. Every cell also reports the binomial standard error of its
E[revocations] estimate, threaded through `Session.plan` and the `plan`
CLI.

`provider=` selects the market being planned over (DESIGN.md §5): regions,
lifetime laws, startup/replacement overheads and prices all come from the
`repro.providers` adapter, so the same planner compares GCP preemptible,
AWS spot and Azure low-priority offerings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.perf_model.cluster_model import (Eq4Inputs,
                                                 PSBottleneckModel,
                                                 WorkerSpec, cluster_speed,
                                                 predict_total_time)
from repro.core.transient.replacement import ReplacementModel
from repro.core.transient.revocation import RevocationSampler
from repro.core.transient.startup import StartupModel


@dataclasses.dataclass
class LaunchPlan:
    region: str
    gpu: str
    launch_hour: int
    n_workers: int
    expected_revocations: float
    expected_time_s: float
    expected_cost: float
    provider: str = "gcp"
    #: standard error of `expected_revocations` (same units): binomial
    #: under score="eq4", the trajectory-sample SEM under score="sim"
    revocation_stderr: float = 0.0
    #: Monte-Carlo sample count behind the estimate
    samples: int = 0
    #: how the cell was scored: "eq4" (Eq (4) point estimate around a
    #: lifetime MC) or "sim" (full batched fleet-simulation ensemble)
    score: str = "eq4"
    #: distribution summary, populated under score="sim" (zeros otherwise)
    time_p50_s: float = 0.0
    time_p90_s: float = 0.0
    cost_p50: float = 0.0
    cost_p90: float = 0.0
    #: trajectories that completed every step (score="sim"); if it is
    #: below `samples` the cell's time/cost understate the truth
    finished: int = 0


def expected_revocations_mc(region: str, gpu: str, start_hour: float,
                            run_hours: float, n_workers: int,
                            samples: int = 200, seed: int = 0,
                            provider: object = "gcp") -> float:
    """Diurnal-aware E[revocations]: MC over the lifetime sampler (the CDF
    alone is launch-hour-agnostic). One batched draw; see the `_stats`
    variant for the standard error."""
    return expected_revocations_mc_stats(region, gpu, start_hour, run_hours,
                                         n_workers, samples, seed,
                                         provider)[0]


def expected_revocations_mc_stats(region: str, gpu: str, start_hour: float,
                                  run_hours: float, n_workers: int,
                                  samples: int = 200, seed: int = 0,
                                  provider: object = "gcp"
                                  ) -> Tuple[float, float]:
    """(E[revocations], standard error) from one batched lifetime draw."""
    if samples < 1:
        raise ValueError(f"need at least one MC sample, got {samples}")
    samp = RevocationSampler(seed, provider)
    horizon = min(run_hours, samp.provider.max_lifetime_hours)
    lts = samp.lifetimes(region, gpu, samples, start_hour)
    p_hat = _hit_fraction(lts, horizon)
    return n_workers * p_hat, _binomial_stderr(p_hat, samples, n_workers)


def _hit_fraction(lifetimes: np.ndarray, horizon_hours: float) -> float:
    """Fraction of sampled lifetimes revoked inside the horizon."""
    return float(np.count_nonzero(
        np.isfinite(lifetimes) & (lifetimes <= horizon_hours))
        / max(len(lifetimes), 1))


def _binomial_stderr(p_hat: float, samples: int, n_workers: int) -> float:
    return n_workers * math.sqrt(max(p_hat * (1.0 - p_hat), 0.0)
                                 / max(samples, 1))


def plan_launch(gpu: str, n_workers: int, worker_speed: float,
                n_w: int, i_c: int, t_c: float,
                hours: Optional[List[int]] = None,
                seed: int = 0,
                provider: object = "gcp",
                model_gflops: float = 1.54,
                samples: int = 200,
                ps: Optional[PSBottleneckModel] = None,
                score: str = "eq4",
                engine: str = "batched",
                model_bytes: float = 1.87e6,
                replace: bool = True,
                handover: bool = True,
                max_sim_hours: Optional[float] = None,
                region: Optional[str] = None,
                resilience: object = None
                ) -> Tuple[LaunchPlan, List[LaunchPlan]]:
    """Scores all (region, hour) cells of one provider; returns (best, all).

    worker_speed: steps/s per worker for the target model (from the §III
    predictors); model_gflops: its complexity C_m, which sets the Fig 10
    replacement cold-start (default: the paper's ResNet-32); samples: MC
    draws (score="eq4") or simulated trajectories (score="sim") per
    (region, hour) cell. Costing: transient hourly price x workers x
    expected time, replacement overhead included via Eq (4) — or, under
    score="sim", the ensemble's realized GPU-hour cost.

    `score` picks the estimator behind each cell:

    * ``"eq4"`` (default) — the Eq (4) point estimate around one batched
      lifetime draw (+ binomial stderr), exactly the historic planner.
    * ``"sim"`` — a full `FleetSim.run_many` ensemble per cell on
      `engine` (`"batched"`/`"event"`/`"jit"`): every plan carries
      realized time/cost percentiles (`time_p50_s`/`time_p90_s`/
      `cost_p50`/`cost_p90`), the trajectory-sample revocation stderr and
      the `finished` censoring count, so the chosen cell reflects the
      simulated dynamics (chief loss, replacement chains, diurnal join
      hours) instead of the Eq (4) closed form alone. `model_bytes`,
      `replace`, `handover` and `max_sim_hours` (default: 6x the
      no-revocation Eq (4) wall-clock, at least 48 h) shape that
      simulation; cells share the simulation seed, so they are compared
      under common random numbers like the eq4 grid.

    `ps` (optional) caps the cluster speed with the Fig 4 PS capacity
    model, including its `compression` scheme — a plan made for a
    compressed run (§VI-B) sees the raised capacity ceiling and the
    correspondingly shorter exposure window; under score="sim" the same
    recalibration is forwarded to the simulator. `ps=None` keeps the
    uncapped Σ sp_i composition.

    The eq4 MC horizon is the Eq (4) *wall-clock* — compute plus
    checkpoint pauses, then one fixed-point iteration adding the
    revocation overhead itself — not the compute-only time: a
    checkpoint-heavy run stays exposed to the market for every pause too,
    and the lifetimes are drawn once per cell so the refined horizon
    reuses the same draws.

    `region` (optional) constrains the sweep to one region BEFORE any
    cell is scored — under score="sim" every discarded cell would have
    cost a full ensemble.

    `resilience` (a `repro.resilience.ResilienceConfig`) is honored under
    score="sim" only: the simulated fleets apply its quorum degradation
    and restore-retry stalls (docs/resilience.md), so a plan made for a
    resilient run prices the recovery time in. The eq4 closed form has no
    recovery term and ignores it.
    """
    from repro.providers import get_provider
    if samples < 1:
        raise ValueError(f"need at least one MC sample, got {samples}")
    if score not in ("eq4", "sim"):
        raise ValueError(f"unknown score {score!r}; known: ('eq4', 'sim')")
    prov = get_provider(provider)
    if region is not None:
        prov.check_offered(region, gpu)
        regions = [region]
    else:
        prov.check_gpu_offered(gpu)
        regions = prov.regions_offering(gpu)
    hours = hours if hours is not None else list(range(0, 24, 3))
    if i_c <= 0:  # no checkpointing: zero pauses, Eq (4) stays defined
        i_c, t_c = n_w, 0.0
    # decorrelated streams, matching FleetSim's seed+1/seed+2 convention
    # (the MC sampler itself owns `seed`)
    startup = StartupModel(seed + 1, prov)
    repl = ReplacementModel(seed + 2, prov)
    price = prov.price(gpu)
    sp = cluster_speed([WorkerSpec(gpu, worker_speed)] * n_workers, ps)
    t_p = startup.mean_total(gpu)
    t_s = repl.cold_start_s(model_gflops)

    def eq4(n_r: float) -> float:
        # spread Pr over workers equally for Eq (5)
        return predict_total_time(sp, Eq4Inputs(
            n_w, i_c, t_c, t_p, t_s, [n_r / n_workers] * n_workers))

    base_s = eq4(0.0)                       # Eq (4) without revocations
    if score == "sim":
        plans = _sim_scored_grid(
            gpu, n_workers, worker_speed, n_w, i_c, t_c, hours, seed, prov,
            model_gflops, samples, ps, engine, model_bytes, replace,
            handover,
            max_sim_hours if max_sim_hours is not None
            else max(48.0, 6.0 * base_s / 3600.0), regions, resilience)
        best = min(plans, key=lambda p: (p.expected_cost, p.expected_time_s))
        return best, plans
    horizon0 = min(base_s / 3600.0, prov.max_lifetime_hours)
    plans: List[LaunchPlan] = []
    for region in regions:
        for h in hours:
            # one batched draw per cell — same seed per cell, so cells
            # are compared under common random numbers (as the pre-
            # batched planner did by re-seeding per cell)
            samp = RevocationSampler(seed, prov)
            lts = samp.lifetimes(region, gpu, samples, float(h))
            p0 = _hit_fraction(lts, horizon0)
            # one Eq (4) iteration: revocation overhead extends exposure,
            # re-scored against the same draws
            horizon1 = min(eq4(n_workers * p0) / 3600.0,
                           prov.max_lifetime_hours)
            p1 = _hit_fraction(lts, horizon1)
            n_r = n_workers * p1
            t = eq4(n_r)
            cost = (t / 3600.0) * n_workers * price \
                + n_r * (t_p / 3600.0) * price
            plans.append(LaunchPlan(
                region, gpu, h, n_workers, n_r, t, cost, prov.name,
                revocation_stderr=_binomial_stderr(p1, samples, n_workers),
                samples=samples))
    best = min(plans, key=lambda p: (p.expected_cost, p.expected_time_s))
    return best, plans


def _sim_scored_grid(gpu, n_workers, worker_speed, n_w, i_c, t_c, hours,
                     seed, prov, model_gflops, samples, ps, engine,
                     model_bytes, replace, handover, max_sim_hours,
                     regions, resilience=None) -> List[LaunchPlan]:
    """One batched fleet-simulation ensemble per (region, hour) cell —
    the simulation-backed §V-C planner the lockstep engine makes routine
    (10k+ trajectories per sweep stay sub-second)."""
    from repro.core.transient.fleet import FleetSim, SimWorker
    plans: List[LaunchPlan] = []
    for region in regions:
        for h in hours:
            workers = [SimWorker(i, gpu, region, worker_speed)
                       for i in range(n_workers)]
            sim = FleetSim(
                workers, model_gflops=model_gflops,
                model_bytes=ps.model_bytes if ps is not None
                else model_bytes,
                step_speed_of=lambda g: worker_speed,
                checkpoint_interval_steps=i_c, checkpoint_time_s=t_c,
                n_ps=ps.n_ps if ps is not None else 1,
                n_tensors=ps.n_tensors if ps is not None else 0,
                grad_compression=ps.compression if ps is not None
                else "none",
                seed=seed, replace=replace, handover=handover,
                price_of={gpu: prov.price(gpu)}, provider=prov,
                resilience=resilience)
            ens = sim.run_many(n_w, samples, max_hours=max_sim_hours,
                               start_hour=float(h), engine=engine)
            st = ens.stats
            plans.append(LaunchPlan(
                region, gpu, h, n_workers,
                expected_revocations=st.revocations_mean,
                expected_time_s=st.time_mean_s,
                expected_cost=st.cost_mean,
                provider=prov.name,
                revocation_stderr=st.revocations_stderr,
                samples=samples, score="sim",
                time_p50_s=st.time_p50_s, time_p90_s=st.time_p90_s,
                cost_p50=st.cost_p50, cost_p90=st.cost_p90,
                finished=st.finished))
    return plans
