"""Process-wide memo for jit/lower artifacts — the ROADMAP's Session-level
caching item (docs/performance.md).

Every `Session.train` used to rebuild and re-trace its train step, and
every `Session.serve` call re-jitted the decode step, even when nothing
that shapes the traced computation had changed. This module keys the built
artifacts on the *values* that reach the trace — the `ModelConfig`, the
`RunConfig` fields the step closure reads, the mesh and the sharding
rules — so repeated train/serve calls (and fresh Sessions over the same
config) reuse one jitted callable, and XLA's own compilation cache is hit
instead of rebuilt.

Keys are `repr()` strings of plain dataclasses/tuples: a faithful value
key for the frozen config objects used here, with the fields that never
enter the traced graph (checkpoint paths, data seeds, checkpoint cadence)
normalized away by the callers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, Tuple, TypeVar

T = TypeVar("T")

_CACHE: Dict[Tuple[str, str], Any] = {}
_HITS = 0
_MISSES = 0


def cache_key(*parts: object) -> str:
    """A stable value-key from reprs of config-shaped objects."""
    return "|".join(repr(p) for p in parts)


def cached(kind: str, key_parts: Iterable[object],
           build: Callable[[], T]) -> T:
    """Return the memoized artifact for (kind, key), building it once."""
    global _HITS, _MISSES
    key = (kind, cache_key(*key_parts))
    if key in _CACHE:
        _HITS += 1
    else:
        _MISSES += 1
        _CACHE[key] = build()
    return _CACHE[key]


def stats() -> Dict[str, int]:
    return {"entries": len(_CACHE), "hits": _HITS, "misses": _MISSES}


def clear() -> None:
    """Drop all cached artifacts (tests; frees tracer memory)."""
    global _HITS, _MISSES
    _CACHE.clear()
    _HITS = _MISSES = 0


def normalized_run(run) -> object:
    """A RunConfig with the trace-irrelevant fields zeroed, for keying:
    checkpoint_dir/interval steer the outer loop, seed steers data, the
    compilation-cache dir steers XLA's disk cache, resilience policies
    steer retries around the step — none of them reach the jitted step
    function."""
    return dataclasses.replace(run, checkpoint_dir="",
                               checkpoint_interval=0, seed=0,
                               compilation_cache_dir="",
                               resilience=None, recalibration=None)


_PERSISTENT_DIR = None


def enable_persistent_cache(path: str) -> bool:
    """Point JAX's persistent (on-disk) compilation cache at `path`.

    Complements the in-process memo above: that one dedupes within a
    process, the disk cache survives process restarts — repeated chaos /
    live runs of the same step skip XLA entirely. Idempotent; returns
    False (feature off) when this JAX build lacks the config knobs."""
    global _PERSISTENT_DIR
    if not path:
        return False
    if _PERSISTENT_DIR == path:
        return True
    import jax
    try:
        jax.config.update("jax_compilation_cache_dir", str(path))
        # cache every entry: the smoke-sized steps used in chaos/live runs
        # compile fast and would otherwise fall under the default minimums
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:
        return False
    _PERSISTENT_DIR = str(path)
    return True
