"""Asynchronous parameter-server runtime emulation (§II).

Two layers:

1. `PSQueueSim` — event-driven queueing model of the PS architecture: each
   worker alternates (compute step_time) -> (PS service 2*model_bytes/bw).
   Reproduces Table III / Fig 4: per-worker step time flat until aggregate
   demand saturates the PS, then uniform slowdown; adding a PS (§VI-B)
   restores throughput.

2. `async_sgd` — a functional JAX emulation of asynchronous SGD with
   bounded staleness: each worker computes gradients at a stale snapshot of
   the parameters; the PS applies updates in arrival order. Used to validate
   the paper's premise that async training tolerates heterogeneous worker
   paces (slow workers don't block fast ones).

TPU adaptation note (docs/DESIGN.md §2): the production runtime is synchronous
SPMD (core/trainer.py); this module exists to reproduce the paper's
measurement semantics faithfully.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.perf_model.cluster_model import PS_NET_BYTES_PER_S


# ---------------------------------------------------------------------------
# 1. queueing model
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PSQueueResult:
    worker_step_time: Dict[int, float]   # effective mean step time per worker
    cluster_speed: float                 # aggregate steps/s
    ps_utilization: float


def ps_queue_sim(compute_times: Sequence[float], model_bytes: float,
                 n_ps: int = 1, ps_bw: float = PS_NET_BYTES_PER_S,
                 steps: int = 400, seed: int = 0,
                 n_tensors: int = 0,
                 grad_compression: str = "none") -> PSQueueResult:
    """Workers with given per-step compute times sharing n_ps servers.

    Per-update service follows the calibrated PS law (cluster_model):
    max(network, per-tensor RPC) / n_ps — variables are striped across
    PSes. `grad_compression` shrinks the network term by
    `compression_ratio` (§VI-B), exactly as `PSBottleneckModel` does.

    Async semantics: a worker pushing to a FREE PS proceeds immediately
    (apply/pull overlap its next compute); pushing to a BUSY PS waits for
    the queue to drain (the Table III saturation regime).

    The stepper is the fleet engine's next-event array reduction instead
    of a per-push Python heap (docs/DESIGN.md §2): each round sorts the
    pending arrivals once, computes every admissible start time in one
    Lindley-recursion cummax, and serves the longest prefix whose order
    cannot be perturbed by a re-arrival — the whole worker population per
    round. When the queue is fully saturated and the per-cycle service
    order reaches its fixed point (always, for homogeneous compute
    times), whole service cycles collapse into one closed-form batch, so
    the Table III saturation regime costs O(1) rounds instead of
    O(steps). Results match the retired per-push heap loop up to float
    association order: the closed-form Lindley starts can differ from
    the incremental ones in the last bits, so two arrivals closer than
    that noise may serve in either order — transient serve-order swaps
    that keep aggregates within ~0.5% for short runs and vanish as
    steps grow (tests/test_fleet_batched.py fuzzes the bound against a
    pinned copy of the heap loop). Small heterogeneous populations
    (n <= 8) keep a scalar next-event scan — the array rounds would pay
    ~20 numpy calls per 1-2 served pushes there.
    """
    from repro.core.perf_model.cluster_model import PSBottleneckModel
    if steps < 1:
        raise ValueError(f"need at least one step per worker, got {steps}")
    n = len(compute_times)
    ct = np.asarray(compute_times, float)
    service = PSBottleneckModel(model_bytes, n_ps, ps_bw,
                                n_tensors=n_tensors,
                                compression=grad_compression).service_time_s()
    rng = np.random.default_rng(seed)
    pending = ct * rng.uniform(0.2, 1.0, size=n)   # next arrival per worker
    remaining = np.full(n, steps)
    done_steps = np.zeros(n, int)
    finish_t = np.zeros(n, float)
    widx = np.arange(n)
    ks = widx * service
    ps_free_at = 0.0
    busy = 0.0
    n_live = n
    if n <= 8 and ct.min() < ct.max():
        # a small heterogeneous population rarely reaches a collapsible
        # steady state, so the array rounds would pay their per-round
        # overhead for 1-2 served pushes each; a scalar next-event scan
        # (min over <= 8 floats, first-minimum = lowest worker id like
        # the heap's tuple order) is faster there
        arr = [float(p) for p in pending]
        cts = [float(c) for c in ct]
        left = [steps] * n
        while n_live:
            w = arr.index(min(arr))
            start = arr[w] if arr[w] > ps_free_at else ps_free_at
            ps_free_at = start + service
            busy += service
            done_steps[w] += 1
            finish_t[w] = start
            left[w] -= 1
            if left[w] > 0:
                arr[w] = start + cts[w]
            else:
                arr[w] = float("inf")
                n_live -= 1
        eff = {w: finish_t[w] / done_steps[w] for w in range(n)}
        total_time = float(finish_t.max())
        return PSQueueResult(eff, float(done_steps.sum()) / total_time,
                             busy / total_time)
    while n_live:
        # arrivals in (time, worker) order — kind="stable" reproduces the
        # heap's (time, worker-id) tuple comparison; finished workers
        # (pending=inf) sort to the tail and are dropped
        order = np.argsort(pending, kind="stable")[:n_live]
        a = pending[order]
        m = order.size
        # Lindley recursion in closed form: s_k = max(a_k, s_{k-1} + S)
        #   => s_k = k*S + max(ps_free_at, cummax_j<=k (a_j - j*S))
        base = np.maximum.accumulate(np.maximum(a - ks[:m], ps_free_at))
        starts = ks[:m] + base
        # a served worker's next push; workers on their last step never
        # return, so they cannot constrain the prefix
        re_arr = np.where(remaining[order] > 1, starts + ct[order], np.inf)
        # serve the longest prefix no re-arrival can interleave into:
        # item k is safe iff every re-arrival produced before it lands at
        # or after a_k (ties defer to the next round's (time, worker)
        # sort, matching heap tie-breaking)
        safe = np.ones(m, bool)
        if m > 1:
            safe[1:] = a[1:] < np.minimum.accumulate(re_arr)[:-1]
        k = int(np.argmin(safe)) if not safe.all() else m
        served = order[:k]
        s_served = starts[:k]
        done_steps[served] += 1
        finish_t[served] = s_served
        busy += k * service
        ps_free_at = s_served[-1] + service
        remaining[served] -= 1
        rem = remaining[served]
        pending[served] = np.where(rem > 0, s_served + ct[served], np.inf)
        n_live -= int(np.count_nonzero(rem == 0))
        # ---- steady states: collapse whole service cycles --------------
        # After a round that served the whole population once, the next
        # cycles may be exact time-shifted copies; when the shift
        # invariance is provable, C = min(remaining) - 1 cycles are
        # served in closed form instead of C more rounds.
        if k == m and np.all(remaining[order] > 1):
            cycles = int(remaining[order].min()) - 1
            key = pending[order]            # next cycle's arrival times
            last = None                     # final-cycle starts, if any
            if cycles > 0 and np.all(np.diff(key) > 0):
                # (a) saturated: THIS round was served back-to-back
                # (constant Lindley base, so starts = base + k*S — only
                # then does `key <= ps_free_at + k*S` reduce to the
                # shift-invariant `ct_k <= m*S`), arrivals stay in this
                # order (strictly, so ties cannot reshuffle), every
                # worker re-arrives before its next back-to-back turn,
                # and cycles stay separated in arrival time — each cycle
                # is the last one shifted by m*service, the Table III
                # plateau regime.
                if (base[0] == base[-1]
                        and np.all(key <= ps_free_at + ks[:m])
                        and key[0] + m * service > key[-1]):
                    last = (ps_free_at + ks[:m]
                            + (cycles - 1) * m * service)
                # (b) idle (uniform paces): every start equals its
                # arrival, gaps fit the service time, and uniform
                # compute times shift all arrivals alike — each cycle is
                # the last one shifted by the common compute time.
                elif (ct[order[0]] == ct[order].min() == ct[order].max()
                        and key[0] >= ps_free_at
                        and np.all(np.diff(key) >= service)
                        and key[0] + ct[order[0]] >= key[-1] + service):
                    last = key + (cycles - 1) * ct[order[0]]
            if last is not None:
                done_steps[order] += cycles
                finish_t[order] = last
                busy += cycles * m * service
                ps_free_at = last[-1] + service
                remaining[order] -= cycles
                pending[order] = last + ct[order]
    eff = {w: finish_t[w] / done_steps[w] for w in range(n)}
    total_time = float(finish_t.max())
    return PSQueueResult(eff, float(done_steps.sum()) / total_time,
                         busy / total_time)


# ---------------------------------------------------------------------------
# 2. JAX async-SGD emulation with bounded staleness
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AsyncTrace:
    losses: List[float]
    applied_updates: int
    staleness_hist: Dict[int, int]
    #: updates each worker actually pushed over the run (the realized
    #: share of progress — fast workers dominate)
    worker_updates: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: each worker's configured pace, echoed for the telemetry consumer
    #: (this emulation has no PS contention, so pace IS the step time;
    #: `ps_queue_sim` models the contended regime)
    worker_step_time: Dict[int, float] = dataclasses.field(
        default_factory=dict)


def async_sgd(loss_fn: Callable, params, data_for_worker: Callable,
              worker_step_times: Sequence[float], lr: float = 0.1,
              total_updates: int = 200, seed: int = 0,
              on_update: Optional[Callable[[dict], None]] = None
              ) -> Tuple[object, AsyncTrace]:
    """Emulate async PS training: workers produce gradients computed at the
    params snapshot they last pulled; the PS applies them on arrival.

    worker_step_times sets each worker's pace; staleness emerges naturally
    from pace differences (fast workers update many times while a slow
    worker's gradient is in flight). `on_update` (if given) observes every
    applied update — `Session.train(mode="async_ps")` forwards it onto the
    event bus.
    """
    grad_fn = jax.jit(jax.grad(loss_fn))
    n = len(worker_step_times)
    rng = np.random.default_rng(seed)
    # each worker holds (pull_version, params_snapshot, ready_time)
    q: List[Tuple[float, int]] = []
    snaps = []
    for w, st in enumerate(worker_step_times):
        snaps.append((0, params))
        heapq.heappush(q, (st * rng.uniform(0.5, 1.5), w))
    version = 0
    losses = []
    stale_hist: Dict[int, int] = {}
    pushes: Dict[int, int] = {w: 0 for w in range(n)}
    key = jax.random.PRNGKey(seed)
    while version < total_updates:
        t, w = heapq.heappop(q)
        pull_v, snap = snaps[w]
        key, sub = jax.random.split(key)
        batch = data_for_worker(w, sub)
        g = grad_fn(snap, *batch)
        staleness = version - pull_v
        stale_hist[staleness] = stale_hist.get(staleness, 0) + 1
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        version += 1
        loss = float(loss_fn(params, *data_for_worker(w, sub)))
        losses.append(loss)
        pushes[w] += 1
        if on_update is not None:
            on_update({"update": version, "worker": w,
                       "staleness": staleness, "loss": loss, "t": t})
        snaps[w] = (version, params)
        heapq.heappush(q, (t + worker_step_times[w], w))
    step_time = {w: float(st) for w, st in enumerate(worker_step_times)}
    return params, AsyncTrace(losses, version, stale_hist, pushes, step_time)
