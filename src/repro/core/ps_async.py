"""Asynchronous parameter-server runtime emulation (§II).

Two layers:

1. `PSQueueSim` — event-driven queueing model of the PS architecture: each
   worker alternates (compute step_time) -> (PS service 2*model_bytes/bw).
   Reproduces Table III / Fig 4: per-worker step time flat until aggregate
   demand saturates the PS, then uniform slowdown; adding a PS (§VI-B)
   restores throughput.

2. `async_sgd` — a functional JAX emulation of asynchronous SGD with
   bounded staleness: each worker computes gradients at a stale snapshot of
   the parameters; the PS applies updates in arrival order. Used to validate
   the paper's premise that async training tolerates heterogeneous worker
   paces (slow workers don't block fast ones).

TPU adaptation note (docs/DESIGN.md §2): the production runtime is synchronous
SPMD (core/trainer.py); this module exists to reproduce the paper's
measurement semantics faithfully.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.perf_model.cluster_model import PS_NET_BYTES_PER_S


# ---------------------------------------------------------------------------
# 1. queueing model
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PSQueueResult:
    worker_step_time: Dict[int, float]   # effective mean step time per worker
    cluster_speed: float                 # aggregate steps/s
    ps_utilization: float


def ps_queue_sim(compute_times: Sequence[float], model_bytes: float,
                 n_ps: int = 1, ps_bw: float = PS_NET_BYTES_PER_S,
                 steps: int = 400, seed: int = 0,
                 n_tensors: int = 0,
                 grad_compression: str = "none") -> PSQueueResult:
    """Workers with given per-step compute times sharing n_ps servers.

    Per-update service follows the calibrated PS law (cluster_model):
    max(network, per-tensor RPC) / n_ps — variables are striped across
    PSes. `grad_compression` shrinks the network term by
    `compression_ratio` (§VI-B), exactly as `PSBottleneckModel` does.
    """
    from repro.core.perf_model.cluster_model import PSBottleneckModel
    n = len(compute_times)
    service = PSBottleneckModel(model_bytes, n_ps, ps_bw,
                                n_tensors=n_tensors,
                                compression=grad_compression).service_time_s()
    # Async semantics: a worker pushing to a FREE PS proceeds immediately
    # (apply/pull overlap its next compute); pushing to a BUSY PS waits for
    # the queue to drain (the Table III saturation regime).
    q: List[Tuple[float, int]] = []
    rng = np.random.default_rng(seed)
    for w, ct in enumerate(compute_times):
        heapq.heappush(q, (ct * rng.uniform(0.2, 1.0), w))
    ps_free_at = 0.0
    done_steps = np.zeros(n, int)
    finish_t = np.zeros(n, float)
    busy = 0.0
    t = 0.0
    while q:
        t, w = heapq.heappop(q)
        start = max(t, ps_free_at)          # queue wait if PS busy
        ps_free_at = start + service
        busy += service
        done_steps[w] += 1
        finish_t[w] = start
        if done_steps[w] < steps:
            heapq.heappush(q, (start + compute_times[w], w))
    eff = {w: finish_t[w] / done_steps[w] for w in range(n)}
    total_time = float(finish_t.max())
    return PSQueueResult(eff, float(done_steps.sum()) / total_time,
                         busy / total_time)


# ---------------------------------------------------------------------------
# 2. JAX async-SGD emulation with bounded staleness
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AsyncTrace:
    losses: List[float]
    applied_updates: int
    staleness_hist: Dict[int, int]
    #: updates each worker actually pushed over the run (the realized
    #: share of progress — fast workers dominate)
    worker_updates: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: each worker's configured pace, echoed for the telemetry consumer
    #: (this emulation has no PS contention, so pace IS the step time;
    #: `ps_queue_sim` models the contended regime)
    worker_step_time: Dict[int, float] = dataclasses.field(
        default_factory=dict)


def async_sgd(loss_fn: Callable, params, data_for_worker: Callable,
              worker_step_times: Sequence[float], lr: float = 0.1,
              total_updates: int = 200, seed: int = 0,
              on_update: Optional[Callable[[dict], None]] = None
              ) -> Tuple[object, AsyncTrace]:
    """Emulate async PS training: workers produce gradients computed at the
    params snapshot they last pulled; the PS applies them on arrival.

    worker_step_times sets each worker's pace; staleness emerges naturally
    from pace differences (fast workers update many times while a slow
    worker's gradient is in flight). `on_update` (if given) observes every
    applied update — `Session.train(mode="async_ps")` forwards it onto the
    event bus.
    """
    grad_fn = jax.jit(jax.grad(loss_fn))
    n = len(worker_step_times)
    rng = np.random.default_rng(seed)
    # each worker holds (pull_version, params_snapshot, ready_time)
    q: List[Tuple[float, int]] = []
    snaps = []
    for w, st in enumerate(worker_step_times):
        snaps.append((0, params))
        heapq.heappush(q, (st * rng.uniform(0.5, 1.5), w))
    version = 0
    losses = []
    stale_hist: Dict[int, int] = {}
    pushes: Dict[int, int] = {w: 0 for w in range(n)}
    key = jax.random.PRNGKey(seed)
    while version < total_updates:
        t, w = heapq.heappop(q)
        pull_v, snap = snaps[w]
        key, sub = jax.random.split(key)
        batch = data_for_worker(w, sub)
        g = grad_fn(snap, *batch)
        staleness = version - pull_v
        stale_hist[staleness] = stale_hist.get(staleness, 0) + 1
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        version += 1
        loss = float(loss_fn(params, *data_for_worker(w, sub)))
        losses.append(loss)
        pushes[w] += 1
        if on_update is not None:
            on_update({"update": version, "worker": w,
                       "staleness": staleness, "loss": loss, "t": t})
        snaps[w] = (version, params)
        heapq.heappush(q, (t + worker_step_times[w], w))
    step_time = {w: float(st) for w, st in enumerate(worker_step_times)}
    return params, AsyncTrace(losses, version, stale_hist, pushes, step_time)
