"""Transient-aware elastic training loop — the TPU-native CM-DARE runtime.

Integrates: sharded train_step (launch/steps.py), resumable data pipeline,
lease-based checkpointing, performance profiler, bottleneck controller, and
a revocation schedule (from the fleet simulator or injected by tests).

Loop contract per step:
  1. drain membership events (revocations / joins) -> roll epoch, re-split
     batch, possibly steal the checkpoint-writer lease;
  2. fetch the epoch's data shards (deterministic in (seed, step, shard));
  3. jit'd train_step;
  4. profiler.record; controller.check on a cadence;
  5. checkpoint on the interval (writer-lease holder only).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, RunConfig
from repro.core import jit_cache
from repro.core.controller import Controller, Detection
from repro.core.profiler import PerformanceProfiler
from repro.data.pipeline import ShardedLoader
from repro.dist import sharding as sh
from repro.dist.elastic import ElasticMembership, Member
from repro.launch import steps as st
from repro.models import api


@dataclasses.dataclass
class MembershipEvent:
    step: int
    kind: str            # revoke | join
    member_id: int
    gpu: str = "v5e"


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_loss: float
    losses: List[float]
    speed: Optional[float]
    epochs: int
    checkpoints: int
    restores: int
    detections: List[Detection]
    wall_seconds: float


class TransientTrainer:
    def __init__(self, cfg: ModelConfig, run: RunConfig, loader: ShardedLoader,
                 members: Optional[List[Member]] = None,
                 holder: str = "worker-0",
                 predicted_speed: Optional[float] = None,
                 on_event: Optional[Callable[[str, dict], None]] = None):
        self.cfg = cfg
        self.run = run
        self.loader = loader
        self._emit = on_event or (lambda kind, payload: None)
        self.members = ElasticMembership(
            members or [Member(0)], loader.global_batch)
        self.profiler = PerformanceProfiler(window=10, warmup_steps=5,
                                            warmup_seconds=0.0)
        self.controller = Controller()
        self.ckpt = Checkpointer(run.checkpoint_dir, holder=holder)
        self.predicted_speed = predicted_speed
        # jit/lower artifacts are memoized across trainers/Sessions keyed
        # on (cfg, trace-relevant run fields, mesh, rules) — rebuilding a
        # Session no longer re-traces an identical step (jit_cache)
        self.train_step, self.opt, self._jit_step = jit_cache.cached(
            "train_step",
            (cfg, jit_cache.normalized_run(run), None, sh.MEGATRON_RULES),
            lambda: self._build_step(cfg, run))
        self.detections: List[Detection] = []

    @staticmethod
    def _build_step(cfg: ModelConfig, run: RunConfig):
        train_step, opt = st.make_train_step(cfg, run)
        return train_step, opt, jax.jit(train_step, donate_argnums=(0,))

    # ------------------------------------------------------------------ state
    def init_state(self, key=None) -> st.TrainState:
        params, _ = api.init(self.cfg, key)
        return st.TrainState(params, self.opt.init(params),
                             jnp.zeros((), jnp.int32))

    def restore_or_init(self, key=None) -> Tuple[st.TrainState, int]:
        shapes = jax.eval_shape(self.init_state, key)
        try:
            state, step = self.ckpt.restore(shapes)
            state = jax.tree.map(jnp.asarray, state)
            self.loader.step = step
            self._emit("restore", {"step": step})
            return st.TrainState(state.params, state.opt,
                                 jnp.asarray(step, jnp.int32)), step
        except FileNotFoundError:
            return self.init_state(key), 0

    # ------------------------------------------------------------------- run
    def run_steps(self, state: st.TrainState, n_steps: int,
                  events: Optional[List[MembershipEvent]] = None,
                  check_every: int = 10) -> Tuple[st.TrainState, TrainReport]:
        events = sorted(events or [], key=lambda e: e.step)
        ev_i = 0
        losses: List[float] = []
        restores = checkpoints = 0
        t0 = time.monotonic()
        start_step = int(state.step)
        for local in range(n_steps):
            step = start_step + local
            # 1. membership events at this step boundary
            while ev_i < len(events) and events[ev_i].step <= step:
                ev = events[ev_i]
                ev_i += 1
                if ev.kind == "revoke":
                    if ev.member_id not in self.members:
                        # stale schedule entry (member already gone — e.g. a
                        # replayed fleet timeline after a restore): ignore
                        continue
                    epoch = self.members.revoke(ev.member_id)
                    # revoked writer: lease handover (Fig 11 fix)
                    if not self.ckpt.lease.held_by_me():
                        self.ckpt.lease.notify_revoked()
                        self.ckpt.lease.try_acquire()
                else:
                    if ev.member_id in self.members:
                        continue  # stale join (already present)
                    epoch = self.members.join(Member(ev.member_id, ev.gpu))
                self._emit("epoch", {"step": step, "kind": ev.kind,
                                     "member_id": ev.member_id,
                                     "epoch": epoch.number,
                                     "n_alive": len(epoch.members)})
                if not epoch.members:
                    raise RuntimeError("all members revoked")
            # 2. data (global batch stays constant across membership changes)
            n_shards = max(1, self.members.n_alive)
            batch_np = self.loader.next_global(n_shards)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            # 3. step
            state, metrics = self._jit_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            self._emit("step", {"step": step, "loss": loss})
            # 4. profile + detect
            self.profiler.record(step, loss=loss)
            if self.predicted_speed and step % check_every == 0 and step > 0:
                det = self.controller.check(self.profiler,
                                            self.predicted_speed)
                self.detections.append(det)
                self._emit("detection", {"step": step,
                                         "bottleneck": det.bottleneck,
                                         "action": det.action.value,
                                         "deviation": det.deviation})
            # 5. checkpoint
            if self.run.checkpoint_interval and \
                    (step + 1) % self.run.checkpoint_interval == 0:
                sizes = self.ckpt.save(step + 1, state,
                                       metadata=self.loader.state())
                if sizes is not None:
                    checkpoints += 1
                    self._emit("checkpoint", {"step": step + 1,
                                              "sizes": sizes})
        report = TrainReport(
            steps_run=n_steps, final_loss=losses[-1] if losses else float("nan"),
            losses=losses, speed=self.profiler.speed(),
            epochs=self.members.epoch_no + 1, checkpoints=checkpoints,
            restores=restores, detections=self.detections,
            wall_seconds=time.monotonic() - t0)
        return state, report
