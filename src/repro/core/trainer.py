"""Transient-aware elastic training loop — the TPU-native CM-DARE runtime.

Integrates: sharded train_step (launch/steps.py), resumable data pipeline,
lease-based checkpointing, performance profiler, bottleneck controller, and
a revocation schedule (from the fleet simulator or injected by tests).

Loop contract per step:
  1. drain membership events (revocations / joins) -> roll epoch, re-split
     batch, possibly steal the checkpoint-writer lease;
  2. fetch the epoch's data shards (deterministic in (seed, step, shard));
  3. jit'd train_step;
  4. profiler.record; controller.check on a cadence;
  5. checkpoint on the interval (writer-lease holder only).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.checkpoint.checkpointer import CheckpointCorruptError
from repro.configs.base import ModelConfig, RunConfig
from repro.resilience import (ResilienceConfig, RetryExhausted,
                              call_with_retries)
from repro.core import jit_cache
from repro.core.controller import Action, Controller, Detection
from repro.core.perf_model.cluster_model import (PSBottleneckModel,
                                                 WorkerSpec, cluster_speed)
from repro.core.profiler import PerformanceProfiler
from repro.data.pipeline import ShardedLoader
from repro.dist import sharding as sh
from repro.dist.elastic import ElasticMembership, Member
from repro.launch import steps as st
from repro.models import api


@dataclasses.dataclass
class MembershipEvent:
    step: int
    kind: str            # revoke | join
    member_id: int
    gpu: str = "v5e"


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_loss: float
    losses: List[float]
    speed: Optional[float]
    epochs: int
    checkpoints: int
    restores: int
    detections: List[Detection]
    wall_seconds: float
    #: §VI-B mitigations applied mid-run (see `apply_mitigation` payloads)
    mitigations: List[dict] = dataclasses.field(default_factory=list)
    #: checkpoint saves that failed (chaos checkpoint-store outage)
    checkpoint_failures: int = 0
    #: chaos faults injected mid-run (see `inject_fault` payloads)
    faults: List[dict] = dataclasses.field(default_factory=list)
    #: recovery accounting (resilience enabled; docs/resilience.md)
    retries: int = 0                    # backoff retries beyond attempt 1
    recovered_saves: int = 0            # saves that landed after failures
    fallback_depth: int = 0             # checkpoint generations skipped
    paused_steps: int = 0               # step slots skipped below quorum
    degradations: List[dict] = dataclasses.field(default_factory=list)
    #: online-recalibration ledgers (recalibration armed; docs/calibration.md)
    drift_events: List[dict] = dataclasses.field(default_factory=list)
    refits: List[dict] = dataclasses.field(default_factory=list)


class TransientTrainer:
    def __init__(self, cfg: ModelConfig, run: RunConfig, loader: ShardedLoader,
                 members: Optional[List[Member]] = None,
                 holder: str = "worker-0",
                 predicted_speed: Optional[float] = None,
                 on_event: Optional[Callable[[str, dict], None]] = None,
                 ps_model: Optional[PSBottleneckModel] = None,
                 workers: Optional[List[WorkerSpec]] = None,
                 auto_mitigate: bool = True,
                 mitigation_scheme: str = "int8",
                 max_mitigations: int = 8,
                 clock: Optional[Callable[[], float]] = None,
                 resilience: Optional[ResilienceConfig] = None,
                 recalibrator: Optional[object] = None):
        self.cfg = cfg
        self.run = run
        self.loader = loader
        self._emit = on_event or (lambda kind, payload: None)
        self.members = ElasticMembership(
            members or [Member(0)], loader.global_batch)
        self.profiler = PerformanceProfiler(window=10, warmup_steps=5,
                                            warmup_seconds=0.0)
        self.controller = Controller()
        # the writer lease shares the trainer's clock, so chaos
        # VirtualClock scenarios exercise lease expiry without sleeping
        self.ckpt = Checkpointer(run.checkpoint_dir, holder=holder,
                                 clock=clock or time.time)
        self.predicted_speed = predicted_speed
        # §VI-B mitigation loop state: a PS capacity model + worker specs
        # let the controller attribute a slowdown to PS saturation and let
        # the trainer *act* on it mid-run (apply_mitigation)
        if ps_model is not None and ps_model.compression != run.grad_compression:
            ps_model = dataclasses.replace(ps_model,
                                           compression=run.grad_compression)
        self.ps_model = ps_model
        self.workers = workers
        self.auto_mitigate = auto_mitigate
        self.mitigation_scheme = mitigation_scheme
        # backstop against mitigation loops: adding a PS is self-limiting
        # (the controller stops once capacity exceeds demand), but a badly
        # mis-set prediction could otherwise re-fire on every check
        self.max_mitigations = max_mitigations
        # chaos hooks: an injectable profiler clock (virtual time makes
        # detection latency deterministic across machines) and live fault
        # state the chaos driver toggles via `inject_fault`
        self.clock = clock
        self.ckpt_outage = False
        self.ckpt_failures = 0
        self.faults: List[dict] = []
        self.restores = 0
        self.mitigations: List[dict] = []
        # recovery layer (docs/resilience.md): None keeps every legacy
        # code path byte-identical
        self.resilience = resilience
        # under a virtual clock a backoff sleep must not block the host
        self._sleep: Callable[[float], None] = (
            (lambda s: None) if clock is not None else time.sleep)
        self.retries = 0
        self.recovered_saves = 0
        self.fallback_depth = 0
        self.paused_steps = 0
        self.degradations: List[dict] = []
        # online recalibration (docs/calibration.md): None keeps the
        # static-prediction path byte-identical (golden contract)
        self.recalibrator = recalibrator
        if recalibrator is not None:
            recalibrator.bind(self._emit)
            if predicted_speed:
                recalibrator.seed(predicted_speed)
            self.controller.model_version = recalibrator.version
        self._rebuild_step()
        self.detections: List[Detection] = []

    def _rebuild_step(self) -> None:
        # jit/lower artifacts are memoized across trainers/Sessions keyed
        # on (cfg, trace-relevant run fields, mesh, rules) — rebuilding a
        # Session no longer re-traces an identical step (jit_cache); the
        # key includes run.grad_compression, so the quantized step and the
        # plain step cache separately
        cfg, run = self.cfg, self.run
        self.train_step, self.opt, self._jit_step = jit_cache.cached(
            "train_step",
            (cfg, jit_cache.normalized_run(run), None, sh.MEGATRON_RULES),
            lambda: self._build_step(cfg, run))

    @staticmethod
    def _build_step(cfg: ModelConfig, run: RunConfig):
        train_step, opt = st.make_train_step(cfg, run)
        return train_step, opt, jax.jit(train_step, donate_argnums=(0,))

    # ------------------------------------------------------------------ state
    def init_state(self, key=None) -> st.TrainState:
        params, _ = api.init(self.cfg, key)
        return st.TrainState(params, self.opt.init(params),
                             jnp.zeros((), jnp.int32),
                             st.init_residual(params, self.run))

    def restore_or_init(self, key=None) -> Tuple[st.TrainState, int]:
        # a mid-run ENABLE_COMPRESSION must outlive the process: the
        # scheme is run *state* recorded in the checkpoint metadata, so a
        # restart whose config still says "none" resumes compressed (and
        # keeps its error-feedback residual) instead of silently reverting
        try:
            saved = self.ckpt.read_meta().get("grad_compression", "none")
        except (FileNotFoundError, ValueError):
            saved = "none"
        if saved != "none" and self.run.grad_compression == "none":
            self.run = dataclasses.replace(self.run, grad_compression=saved)
            self._rebuild_step()
            if self.ps_model is not None:
                self.ps_model = dataclasses.replace(self.ps_model,
                                                    compression=saved)
        shapes = jax.eval_shape(self.init_state, key)
        try:
            try:
                state, step = self._restore_validated(shapes)
                residual = state.residual
            except KeyError:
                # checkpoint predates compression (no residual leaves):
                # restore the legacy (params, opt, step) triple and start
                # the error-feedback residual from zero
                legacy = st.TrainState(shapes.params, shapes.opt, shapes.step)
                state, step = self.ckpt.restore(legacy)
                residual = jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), shapes.residual)
            state = jax.tree.map(jnp.asarray, state)
            residual = jax.tree.map(jnp.asarray, residual)
            self.loader.step = step
            self.restores += 1
            self._emit("restore", {"step": step, "restores": self.restores})
            return st.TrainState(state.params, state.opt,
                                 jnp.asarray(step, jnp.int32), residual), step
        except FileNotFoundError:
            return self.init_state(key), 0
        except CheckpointCorruptError as exc:
            # every committed generation failed validation: surface it and
            # restart clean rather than load torn state
            self._emit("restore_failed", {"error": str(exc)})
            return self.init_state(key), 0

    def _restore_validated(self, shapes):
        """Restore under the resilience policy: retry the read, validate
        checksums, and fall back generation-by-generation past torn or
        corrupt checkpoints (``restore_fallback`` events record each skip).
        With resilience disabled this is the legacy strict restore."""
        res = self.resilience
        if res is None:
            return self.ckpt.restore(shapes)

        def on_fallback(step, exc):
            self.fallback_depth += 1
            self._emit("restore_fallback", {"step": step,
                                            "depth": self.fallback_depth,
                                            "error": str(exc)})

        def attempt():
            tree, step, _depth = self.ckpt.restore_latest_valid(
                shapes, on_fallback=on_fallback)
            return tree, step

        try:
            (tree, step), attempts = call_with_retries(
                attempt, res.retry, op="restore", seed=self.run.seed,
                key=-1, sleep=self._sleep, emit=self._emit,
                retry_on=(CheckpointCorruptError,))
        except RetryExhausted as exc:
            self.retries += exc.attempts - 1
            raise exc.last
        self.retries += attempts - 1
        return tree, step

    # ------------------------------------------------------------------- run
    def run_steps(self, state: st.TrainState, n_steps: int,
                  events: Optional[List[MembershipEvent]] = None,
                  check_every: int = 10) -> Tuple[st.TrainState, TrainReport]:
        events = sorted(events or [], key=lambda e: e.step)
        ev_i = 0
        losses: List[float] = []
        checkpoints = 0
        t0 = time.monotonic()
        start_step = int(state.step)
        steps_run = 0
        base_global_batch = self.loader.global_batch
        tier = "continue"
        for local in range(n_steps):
            step = start_step + local
            # 1. membership events at this step boundary
            while ev_i < len(events) and events[ev_i].step <= step:
                ev = events[ev_i]
                ev_i += 1
                if ev.kind == "revoke":
                    if ev.member_id not in self.members:
                        # stale schedule entry (member already gone — e.g. a
                        # replayed fleet timeline after a restore): ignore
                        continue
                    epoch = self.members.revoke(ev.member_id)
                    # revoked writer: lease handover (Fig 11 fix)
                    if not self.ckpt.lease.held_by_me():
                        self.ckpt.lease.notify_revoked()
                        if self.ckpt.lease.try_acquire():
                            self._emit("lease_handover",
                                       {"step": step,
                                        "holder": self.ckpt.lease.holder,
                                        "revoked_member": ev.member_id})
                else:
                    if ev.member_id in self.members:
                        continue  # stale join (already present)
                    epoch = self._join_member(ev)
                self._emit("epoch", {"step": step, "kind": ev.kind,
                                     "member_id": ev.member_id,
                                     "epoch": epoch.number,
                                     "n_alive": len(epoch.members)})
                if not epoch.members:
                    raise RuntimeError("all members revoked")
            # 1b. quorum degradation tier (docs/resilience.md): pause skips
            # this step slot entirely (future joins can restore quorum),
            # shrink temporarily scales the global batch down
            new_tier = ("continue" if self.resilience is None else
                        self.resilience.degradation.tier(
                            self.members.n_alive, self.members.roster_size))
            if new_tier != tier:
                tier = new_tier
                record = {"step": step, "tier": tier,
                          "n_alive": self.members.n_alive,
                          "roster_size": self.members.roster_size}
                self.degradations.append(record)
                self._emit("degradation", record)
            if tier == "pause":
                self.paused_steps += 1
                if ev_i >= len(events):
                    break  # no future join can restore quorum
                continue
            if tier == "shrink_batch":
                self.loader.global_batch = max(
                    self.members.n_alive,
                    int(round(base_global_batch
                              * self.resilience.degradation.shrink_factor)))
            else:
                self.loader.global_batch = base_global_batch
            # 2. data (global batch stays constant across membership changes)
            n_shards = max(1, self.members.n_alive)
            batch_np = self.loader.next_global(n_shards)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            # 3. step
            state, metrics = self._jit_step(state, batch)
            steps_run += 1
            loss = float(metrics["loss"])
            losses.append(loss)
            payload = {"step": step, "loss": loss}
            if "payload_bytes" in metrics:
                # §VI-B telemetry: the actual compressed wire size of this
                # step's gradient push, not a config echo
                payload["payload_bytes"] = float(metrics["payload_bytes"])
                payload["grad_compression"] = self.run.grad_compression
            self._emit("step", payload)
            # 4. profile + detect (+ §VI-B mitigation). With an injected
            # clock (chaos), the "step" emit above let the driver advance
            # virtual time for this step before it is recorded.
            self.profiler.record(
                step, t=self.clock() if self.clock is not None else None,
                loss=loss)
            if self.predicted_speed and step % check_every == 0 and step > 0:
                det = self.controller.check(self.profiler,
                                            self.predicted_speed,
                                            ps_model=self.ps_model,
                                            workers=self.workers)
                self.detections.append(det)
                self._emit("detection", {"step": step,
                                         "bottleneck": det.bottleneck,
                                         "action": det.action.value,
                                         "deviation": det.deviation,
                                         "model_version": det.model_version})
                mitigated = False
                if self.auto_mitigate and det.action in (
                        Action.ADD_PARAMETER_SERVER,
                        Action.ENABLE_COMPRESSION) \
                        and len(self.mitigations) < self.max_mitigations:
                    state = self.apply_mitigation(det.action, state,
                                                  step=step)
                    mitigated = True
                if self.recalibrator is not None:
                    if mitigated:
                        # mitigation changed the cluster; deviation against
                        # the pre-mitigation prediction is void drift input
                        self.recalibrator.notify_mitigation(step)
                    else:
                        dev = (det.deviation if det.measured is not None
                               else None)
                        new_speed = self.recalibrator.observe(
                            step, dev, self.profiler)
                        if new_speed is not None:
                            self._apply_refit(new_speed, step)
            # 5. checkpoint
            if self.run.checkpoint_interval and \
                    (step + 1) % self.run.checkpoint_interval == 0:
                checkpoints += self._save_checkpoint(step + 1, state)
        self.loader.global_batch = base_global_batch
        report = TrainReport(
            steps_run=steps_run,
            final_loss=losses[-1] if losses else float("nan"),
            losses=losses, speed=self.profiler.speed(),
            epochs=self.members.epoch_no + 1, checkpoints=checkpoints,
            restores=self.restores, detections=self.detections,
            wall_seconds=time.monotonic() - t0,
            mitigations=self.mitigations,
            checkpoint_failures=self.ckpt_failures, faults=self.faults,
            retries=self.retries, recovered_saves=self.recovered_saves,
            fallback_depth=self.fallback_depth,
            paused_steps=self.paused_steps, degradations=self.degradations,
            drift_events=(list(self.recalibrator.drift_events)
                          if self.recalibrator is not None else []),
            refits=(list(self.recalibrator.refits)
                    if self.recalibrator is not None else []))
        return state, report

    def _join_member(self, ev: "MembershipEvent"):
        """Replacement join, retried under the resilience policy: a join
        that races a membership epoch roll is transient, so it gets the
        same bounded backoff as a checkpoint save."""
        join = lambda: self.members.join(Member(ev.member_id, ev.gpu))
        if self.resilience is None:
            return join()
        epoch, attempts = call_with_retries(
            join, self.resilience.retry, op="join", seed=self.run.seed,
            key=ev.member_id, sleep=self._sleep, emit=self._emit,
            retry_on=(RuntimeError,))
        self.retries += attempts - 1
        return epoch

    def _save_checkpoint(self, step: int, state) -> int:
        """One interval save. Legacy path (no resilience): an outage
        fails fast and silently drops the save. Resilience path: the save
        is retried under the policy (``retry`` events per attempt); only
        once attempts/deadline are exhausted does it count as a
        ``checkpoint_failed`` — and that event carries the attempt count,
        so no failure is silent. Returns 1 if a checkpoint committed."""
        metadata = {**self.loader.state(),
                    "grad_compression": self.run.grad_compression}
        if self.resilience is None:
            if self.ckpt_outage:
                # chaos checkpoint-store outage: the save fails fast
                # and the run continues on its last good checkpoint
                self.ckpt_failures += 1
                self._emit("checkpoint_failed",
                           {"step": step, "failures": self.ckpt_failures})
                return 0
            sizes = self.ckpt.save(step, state, metadata=metadata)
            if sizes is None:
                return 0
            self._emit("checkpoint", {"step": step, "sizes": sizes})
            return 1

        def attempt():
            if self.ckpt_outage:
                raise OSError("checkpoint store unavailable (ckpt_outage)")
            return self.ckpt.save(step, state, metadata=metadata)

        had_failures = self.ckpt_failures > 0
        try:
            sizes, attempts = call_with_retries(
                attempt, self.resilience.retry, op="checkpoint_save",
                seed=self.run.seed, key=step, sleep=self._sleep,
                emit=self._emit)
        except RetryExhausted as exc:
            self.retries += exc.attempts - 1
            self.ckpt_failures += 1
            self._emit("checkpoint_failed",
                       {"step": step, "failures": self.ckpt_failures,
                        "attempts": exc.attempts,
                        "error": type(exc.last).__name__})
            return 0
        self.retries += attempts - 1
        if sizes is None:
            return 0
        if attempts > 1 or had_failures:
            self.recovered_saves += 1
        self._emit("checkpoint", {"step": step, "sizes": sizes})
        return 1

    # ------------------------------------------------------------- refit
    def _apply_refit(self, new_speed: float, step: int) -> None:
        """Adopt a drift-triggered refit: the controller now compares
        against the refit prediction (and stamps its new version), and
        the measurement window restarts so the next check is refit-vs-
        post-drift data, not refit-vs-straddled history."""
        self.predicted_speed = new_speed
        self.controller.model_version = self.recalibrator.version
        self.profiler.records.clear()
        self.profiler._win.clear()

    # ---------------------------------------------------- chaos injection
    def inject_fault(self, kind: str, step: int = 0, **payload) -> None:
        """Flip one live fault on/off mid-run (the chaos driver's hook).

        Kinds:
          * ``ckpt_outage`` / ``ckpt_recover`` — fail checkpoint saves
            fast (``checkpoint_failed`` events) / resume saving. The one
            fault the trainer itself enacts, since it owns the save path.
          * ``ps_crash`` / ``ps_recover`` and ``straggler`` /
            ``straggler_end`` — bookkeeping only. These faults are
            *silent*: the trainer's capacity model and prediction stay
            healthy (a silently degraded cluster is exactly what the
            controller must notice from measurement alone), while the
            chaos driver's virtual clock prices every step at the truly
            degraded cluster speed.
        """
        if kind == "ckpt_outage":
            self.ckpt_outage = True
        elif kind == "ckpt_recover":
            self.ckpt_outage = False
        elif kind not in ("ps_crash", "ps_recover",
                          "straggler", "straggler_end"):
            raise ValueError(f"unknown fault kind {kind!r}")
        record = {"step": step, "fault": kind, **payload}
        self.faults.append(record)
        self._emit("fault", record)

    # ------------------------------------------------------- §VI-B mitigate
    def apply_mitigation(self, action: Action, state: st.TrainState,
                         step: int = 0) -> st.TrainState:
        """Act on a PS-bottleneck detection mid-run and re-derive the
        prediction the controller compares against.

        * ``ADD_PARAMETER_SERVER`` — provision one more PS in the capacity
          model (Li et al.'s first mitigation lever);
        * ``ENABLE_COMPRESSION`` — walk the compression ladder one rung:
          an uncompressed run flips to ``mitigation_scheme`` (the dense
          quantizer, attaching a zero error-feedback residual), a
          dense-compressed run escalates to ``topk`` sparsification
          (keeping its residual — the trees are shaped alike). Either
          way the jitted step is rebuilt (cache-keyed on the scheme) and
          the PS capacity model recalibrated with ``compression_ratio``.

        Either way ``predicted_speed`` is recomputed from the new capacity
        so subsequent `Controller.check` calls measure against the
        mitigated cluster, and a ``mitigation`` event is emitted.
        """
        if self.ps_model is None:
            return state
        if action is Action.ADD_PARAMETER_SERVER:
            self.ps_model = self.controller.mitigate_ps(self.ps_model)
        elif action is Action.ENABLE_COMPRESSION:
            current = self.run.grad_compression
            target = (self.mitigation_scheme if current == "none"
                      else "topk")
            if current != target and current != "topk":
                self.run = dataclasses.replace(
                    self.run, grad_compression=target)
                self._rebuild_step()
                if current == "none":
                    state = state._replace(
                        residual=st.init_residual(state.params, self.run))
                # dense -> topk keeps the residual: same tree shape, and
                # the accumulated quantization error still belongs in the
                # next push
            self.ps_model = self.controller.mitigate_compression(
                self.ps_model, self.run.grad_compression)
        else:
            return state
        if self.workers:
            self.predicted_speed = cluster_speed(self.workers, self.ps_model)
        # restart the measurement window: `speed()` averages the whole
        # post-warmup history, so pre-mitigation records would keep the
        # measured speed depressed for many steps and re-trigger the
        # controller against the already-mitigated cluster
        self.profiler.records.clear()
        self.profiler._win.clear()
        record = {"step": step, "action": action.value,
                  "n_ps": self.ps_model.n_ps,
                  "grad_compression": self.run.grad_compression,
                  "ps_capacity": self.ps_model.capacity_steps_per_s(),
                  "predicted_speed": self.predicted_speed}
        self.mitigations.append(record)
        self._emit("mitigation", record)
        return state
