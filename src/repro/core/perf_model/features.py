"""Features of the paper's §III models: model complexity C_m (FLOPs/image),
GPU computational capacity C_gpu (peak TFLOPs), computation ratio
C_norm = C_m / C_gpu, min-max normalized.

TPU adaptation (docs/DESIGN.md §2): the same features work for TPU slice
generations — C_gpu becomes per-chip peak bf16 FLOP/s, and C_m comes from the
dry-run's compiled HLO FLOPs instead of a TF profiler.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    name: str
    teraflops: float          # paper's C_gpu
    mem_gb: float
    hourly_price: float       # on-demand $/h (approx. GCP 2019)
    transient_price: float    # preemptible $/h


# The paper's three GPUs (§III-A) + TPU v5e chip for the TPU-native path.
GPU_SPECS: Dict[str, GPUSpec] = {
    "k80": GPUSpec("k80", 4.11, 12.0, 0.45, 0.135),
    "p100": GPUSpec("p100", 9.53, 16.0, 1.46, 0.43),
    "v100": GPUSpec("v100", 14.13, 16.0, 2.48, 0.74),
    "v5e": GPUSpec("v5e", 197.0, 16.0, 1.2, 0.36),  # bf16 chip
}


def c_norm(c_m: np.ndarray, c_gpu: np.ndarray) -> np.ndarray:
    """Computation ratio: model complexity / GPU capacity."""
    return np.asarray(c_m, float) / np.asarray(c_gpu, float)


def minmax_fit(x: np.ndarray) -> Tuple[float, float]:
    x = np.asarray(x, float)
    return float(x.min()), float(x.max())


def minmax_apply(x: np.ndarray, lo: float, hi: float) -> np.ndarray:
    span = (hi - lo) if hi > lo else 1.0
    return (np.asarray(x, float) - lo) / span
