from repro.core.perf_model.features import (  # noqa: F401
    GPU_SPECS, GPUSpec, c_norm, minmax_fit, minmax_apply,
)
from repro.core.perf_model.regression import (  # noqa: F401
    LinearModel, PCA, kfold_mae, mae, mape, ols_fit,
)
from repro.core.perf_model.svr import SVR, grid_search_svr  # noqa: F401
