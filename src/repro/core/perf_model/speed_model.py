"""§III — training-speed characterization & prediction.

* A calibrated GPU step-time generator stands in for the paper's cloud fleet
  (this container has no K80/P100/V100): per-GPU linear coefficients are fit
  to Table I's published (C_m, step-time) points, and measurements are drawn
  with the paper's observed stability (CoV <= 0.02, Fig 2).
* The full regression zoo of Table II is built on top: GPU-agnostic
  univariate (C_norm) / multivariate (C_m, C_gpu), per-GPU univariate OLS and
  SVR with polynomial / RBF kernels, with min-max normalization, k-fold CV
  and the 4:1 train/test protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.perf_model.features import (GPU_SPECS, c_norm, minmax_apply,
                                            minmax_fit)
from repro.core.perf_model.regression import (LinearModel, kfold_mae, mae,
                                              mape, train_test_split)
from repro.core.perf_model.svr import SVR, grid_search_svr

# Table I of the paper: steps/s for (GPU x model); models with their GFLOPs.
TABLE1_MODELS = {  # name -> C_m in GFLOPs (paper's numbers, CIFAR-10)
    "resnet_15": 0.59,
    "resnet_32": 1.54,
    "shake_shake_small": 2.41,
    "shake_shake_big": 21.3,
}
TABLE1_SPEED = {  # gpu -> steps/s per model (paper Table I means)
    "k80": {"resnet_15": 9.46, "resnet_32": 4.56,
            "shake_shake_small": 2.58, "shake_shake_big": 0.70},
    "p100": {"resnet_15": 21.16, "resnet_32": 12.19,
             "shake_shake_small": 6.99, "shake_shake_big": 1.98},
    "v100": {"resnet_15": 27.38, "resnet_32": 15.61,
             "shake_shake_small": 8.80, "shake_shake_big": 2.18},
}
STEP_TIME_COV = 0.02  # Fig 2: post-warmup stability


@dataclasses.dataclass
class GPUStepTimeModel:
    """Calibrated per-GPU step-time generator: monotone piecewise-linear
    interpolation through Table I's (C_m, step-time) anchors (exact at the
    paper's published points; linear extrapolation outside)."""
    gpu: str
    c_anchors: np.ndarray      # GFLOPs, ascending
    t_anchors: np.ndarray      # seconds

    def step_time(self, c_m_gflops: float) -> float:
        c = float(c_m_gflops)
        ca, ta = self.c_anchors, self.t_anchors
        if c <= ca[0]:  # extrapolate with the first segment's slope
            slope = (ta[1] - ta[0]) / (ca[1] - ca[0])
            return max(1e-4, ta[0] + slope * (c - ca[0]))
        if c >= ca[-1]:
            slope = (ta[-1] - ta[-2]) / (ca[-1] - ca[-2])
            return max(1e-4, ta[-1] + slope * (c - ca[-1]))
        return float(np.interp(c, ca, ta))

    def sample(self, c_m_gflops: float, rng: np.random.Generator,
               n: int = 1) -> np.ndarray:
        t = self.step_time(c_m_gflops)
        return np.maximum(1e-4, rng.normal(t, STEP_TIME_COV * t, size=n))

    # Estimator protocol (repro.calibration) ------------------------------
    @classmethod
    def fit(cls, rows: List[dict], gpu: str) -> "GPUStepTimeModel":
        """Calibrate anchors from measurement rows ({c_m, step_time});
        repeated observations of one C_m average into one anchor."""
        sel = [r for r in rows if r.get("gpu", gpu) == gpu]
        if not sel:
            raise ValueError(f"GPUStepTimeModel.fit: no rows for {gpu!r}")
        by_c: Dict[float, List[float]] = {}
        for r in sel:
            by_c.setdefault(float(r["c_m"]), []).append(float(r["step_time"]))
        if len(by_c) < 2:
            raise ValueError("GPUStepTimeModel.fit: need >= 2 distinct C_m "
                             "anchors for interpolation")
        c = np.array(sorted(by_c))
        t = np.array([float(np.mean(by_c[ci])) for ci in c])
        return cls(gpu, c, t)

    def predict(self, c_m_gflops: float) -> float:
        return self.step_time(c_m_gflops)

    def update(self, rows: List[dict]) -> "GPUStepTimeModel":
        """Online refresh: rescale the anchor curve by the median observed
        /predicted step-time ratio (shape is Table I's; level is live)."""
        ratios = [float(r["step_time"]) / self.step_time(float(r["c_m"]))
                  for r in rows if r.get("gpu", self.gpu) == self.gpu]
        if not ratios:
            raise ValueError("GPUStepTimeModel.update: no rows for "
                             f"{self.gpu!r}")
        scale = float(np.median(ratios))
        return type(self)(self.gpu, self.c_anchors.copy(),
                          self.t_anchors * scale)

    def score(self, rows: List[dict]) -> Dict[str, float]:
        from repro.calibration.estimator import score_predictions
        sel = [r for r in rows if r.get("gpu", self.gpu) == self.gpu]
        return score_predictions(
            [r["step_time"] for r in sel],
            [self.step_time(float(r["c_m"])) for r in sel])

    def params_hash(self) -> str:
        from repro.calibration.estimator import params_hash
        return params_hash("step_time", self.gpu, self.c_anchors,
                           self.t_anchors)


_GENERATOR_CACHE: Optional[Dict[str, GPUStepTimeModel]] = None


def calibrate_generators() -> Dict[str, GPUStepTimeModel]:
    """Anchor each GPU's step-time curve at Table I's published points.

    Memoized at module level — the calibration is pure (Table I constants
    only) and sits on every Session/benchmark startup path, so repeated
    calls share the same `GPUStepTimeModel` instances. Returns a fresh
    dict each time so callers may add/drop entries without aliasing."""
    global _GENERATOR_CACHE
    if _GENERATOR_CACHE is None:
        out = {}
        for gpu, speeds in TABLE1_SPEED.items():
            c = np.array([TABLE1_MODELS[m] for m in speeds])
            t = np.array([1.0 / s for s in speeds.values()])
            order = np.argsort(c)
            out[gpu] = GPUStepTimeModel(gpu, c[order], t[order])
        _GENERATOR_CACHE = out
    return dict(_GENERATOR_CACHE)


def synth_dataset(models: Dict[str, float],
                  gpus: Tuple[str, ...] = ("k80", "p100", "v100"),
                  samples_per: int = 5, seed: int = 0):
    """Generate the paper's measurement dataset: (C_m, C_gpu, step_time) for
    every (CNN x GPU), multiple observations each (averaged-100-step samples).

    models: name -> C_m (GFLOPs).
    """
    gens = calibrate_generators()
    rng = np.random.default_rng(seed)
    rows = []
    for gpu in gpus:
        for name, c_m in models.items():
            ts = gens[gpu].sample(c_m, rng, samples_per)
            for t in ts:
                rows.append({"model": name, "gpu": gpu, "c_m": c_m,
                             "c_gpu": GPU_SPECS[gpu].teraflops,
                             "step_time": float(t)})
    return rows


@dataclasses.dataclass
class SpeedModelReport:
    name: str
    input_feature: str
    kfold_mae: float
    kfold_mae_std: float
    test_mae: float
    test_mape: float
    extra: dict = dataclasses.field(default_factory=dict)


def table2_models(rows: List[dict], seed: int = 0) -> List[SpeedModelReport]:
    """Fit and evaluate the paper's eight Table-II regression models."""
    c_m = np.array([r["c_m"] for r in rows])
    c_gpu = np.array([r["c_gpu"] for r in rows])
    t = np.array([r["step_time"] for r in rows])
    cn = c_norm(c_m, c_gpu)
    lo_n, hi_n = minmax_fit(cn)
    lo_m, hi_m = minmax_fit(c_m)
    cn_n = minmax_apply(cn, lo_n, hi_n)
    cm_n = minmax_apply(c_m, lo_m, hi_m)
    cg_n = minmax_apply(c_gpu, *minmax_fit(c_gpu))
    reports = []

    def eval_model(name, feat_name, X, y, fit_fn, extra=None):
        km, ks = kfold_mae(fit_fn, X, y, k=5, seed=seed)
        Xtr, ytr, Xte, yte = train_test_split(X, y, 0.2, seed)
        m = fit_fn(Xtr, ytr)
        pred = m.predict(Xte)
        reports.append(SpeedModelReport(name, feat_name, km, ks,
                                        mae(yte, pred), mape(yte, pred),
                                        extra or {}))

    # GPU-agnostic
    eval_model("univariate_gpu_agnostic", "C_norm", cn_n[:, None], t,
               lambda X, y: LinearModel().fit(X, y))
    eval_model("multivariate_gpu_agnostic", "C_m,C_gpu",
               np.stack([cm_n, cg_n], 1), t,
               lambda X, y: LinearModel().fit(X, y))

    # per-GPU
    for gpu in sorted({r["gpu"] for r in rows}):
        sel = np.array([r["gpu"] == gpu for r in rows])
        Xg, yg = cm_n[sel][:, None], t[sel]
        eval_model(f"univariate_{gpu}", "C_m", Xg, yg,
                   lambda X, y: LinearModel().fit(X, y))
        for kern in ("poly", "rbf"):
            _, info = grid_search_svr(Xg, yg, kern, seed=seed)
            Xtr, ytr, Xte, yte = train_test_split(Xg, yg, 0.2, seed)
            m = SVR(kernel=kern, C=info["C"], epsilon=info["epsilon"]
                    ).fit(Xtr, ytr)
            pred = m.predict(Xte)
            reports.append(SpeedModelReport(
                f"svr_{kern}_{gpu}", "C_m", info["kfold_mae"],
                info["kfold_mae_std"], mae(yte, pred), mape(yte, pred),
                {"C": info["C"], "epsilon": info["epsilon"]}))
    return reports


@dataclasses.dataclass
class WorkerSpeedPredictor:
    """Deployable per-GPU predictor (the paper's best: per-GPU SVR-RBF),
    with the OLS fallback for fast retraining (§IV-C discussion)."""
    gpu: str
    svr: SVR
    lo: float
    hi: float

    @classmethod
    def fit(cls, rows: List[dict], gpu: str) -> "WorkerSpeedPredictor":
        sel = [r for r in rows if r["gpu"] == gpu]
        c_m = np.array([r["c_m"] for r in sel])
        t = np.array([r["step_time"] for r in sel])
        lo, hi = minmax_fit(c_m)
        m, _ = grid_search_svr(minmax_apply(c_m, lo, hi)[:, None], t, "rbf")
        return cls(gpu, m, lo, hi)

    def step_time(self, c_m: float) -> float:
        x = minmax_apply(np.array([c_m]), self.lo, self.hi)[:, None]
        return float(self.svr.predict(x)[0])

    def speed(self, c_m: float) -> float:
        return 1.0 / self.step_time(c_m)

    # Estimator protocol (repro.calibration) ------------------------------
    def predict(self, c_m: float) -> float:
        return self.step_time(c_m)

    def update(self, rows: List[dict]) -> "WorkerSpeedPredictor":
        """Full SVR refit from fresh rows (§IV-C: the SVR is cheap enough
        to retrain on a monitoring cadence)."""
        return type(self).fit(rows, self.gpu)

    def score(self, rows: List[dict]) -> Dict[str, float]:
        from repro.calibration.estimator import score_predictions
        sel = [r for r in rows if r.get("gpu", self.gpu) == self.gpu]
        return score_predictions(
            [r["step_time"] for r in sel],
            [self.step_time(float(r["c_m"])) for r in sel])

    def params_hash(self) -> str:
        from repro.calibration.estimator import params_hash
        return params_hash("worker_speed", self.gpu, self.lo, self.hi,
                           self.svr.kernel, self.svr.beta_, self.svr.b_,
                           self.svr.X_)
