"""§III-C/D + §VI-A — cluster-level composition and end-to-end prediction.

Key empirical laws reproduced from the paper:
  * worker speed is invariant to cluster size/heterogeneity until the
    parameter server saturates (Table III);
  * cluster speed sp = Σ_i sp_i, capped by PS capacity (Fig 4, Fig 12);
  * total time Eq (4):
        T = N_w/sp + ceil(N_w/I_c) * T_c + N_r * (T_p + T_s)
  * expected revocations Eq (5): N_r = Σ_i Pr(R_i).

PS capacity model (calibrated to Table III + Fig 4 plateaus): serving one
update costs max(network, RPC/apply) time —
    service = max(2*model_bytes/ps_bw, rpc_per_tensor * n_tensors) / n_ps
Large-tensor models (Shake-Shake-Big) are network-bound; many-small-tensor
models (ResNet-32) are per-op RPC-bound — this reproduces the paper's
observed saturation points (P100x8 / V100x4 for ResNet-32, ~4 P100 for
Shake-Shake-Small, ~2-3 for SS-Big, none <=8 for ResNet-15).

TPU adaptation: with sharded sync-DP the same saturation law applies with
n_ps * ps_bw replaced by the ICI all-reduce bandwidth of the mesh — see
benchmarks/roofline.py's collective term.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.dist.compression import compression_ratio

PS_NET_BYTES_PER_S = 1.25e9   # 10 Gbps GCP NIC per parameter server
PS_RPC_PER_TENSOR_S = 2.52e-4  # per-variable RPC+apply cost, calibrated so
# ResNet-32 (97 tensors) saturates one PS at ~41 updates/s (Table III)


@dataclasses.dataclass
class WorkerSpec:
    gpu: str
    speed: float                # steps/s for the target model (solo)


@dataclasses.dataclass
class PSBottleneckModel:
    model_bytes: float
    n_ps: int = 1
    ps_bw: float = PS_NET_BYTES_PER_S
    n_tensors: int = 0
    rpc_per_tensor: float = PS_RPC_PER_TENSOR_S
    #: gradient-compression scheme on the wire (§VI-B): shrinks the network
    #: term by `compression_ratio` but NOT the per-tensor RPC term — a
    #: compressed push still issues one RPC per variable
    compression: str = "none"

    def service_time_s(self) -> float:
        net = (2.0 * self.model_bytes * compression_ratio(self.compression)
               / self.ps_bw)
        rpc = self.rpc_per_tensor * self.n_tensors
        return max(net, rpc) / self.n_ps

    def capacity_steps_per_s(self) -> float:
        return 1.0 / self.service_time_s()

    def cluster_speed(self, workers: Sequence[WorkerSpec]) -> float:
        raw = sum(w.speed for w in workers)
        return min(raw, self.capacity_steps_per_s())

    def worker_step_time(self, workers: Sequence[WorkerSpec],
                         gpu: str) -> float:
        """Average step time of a worker of `gpu` type inside the cluster
        (Table III): slowed uniformly once the PS saturates."""
        raw = sum(w.speed for w in workers)
        cap = self.capacity_steps_per_s()
        slowdown = max(1.0, raw / cap)
        solo = next(w.speed for w in workers if w.gpu == gpu)
        return slowdown / solo

    def is_bottlenecked(self, workers: Sequence[WorkerSpec]) -> bool:
        return sum(w.speed for w in workers) > self.capacity_steps_per_s()

    # Estimator protocol (repro.calibration) ------------------------------
    @classmethod
    def fit(cls, rows: Sequence[dict], model_bytes: float,
            n_ps: int = 1, n_tensors: int = 0,
            compression: str = "none") -> "PSBottleneckModel":
        """Calibrate the PS bandwidth from observed saturated-cluster
        updates/s (rows: {capacity_steps_per_s}); the RPC term keeps its
        Table III calibration (it needs per-tensor timing we don't
        observe in aggregate)."""
        caps = [float(r["capacity_steps_per_s"]) for r in rows
                if float(r.get("capacity_steps_per_s", 0.0)) > 0]
        if not caps:
            raise ValueError("PSBottleneckModel.fit: no positive observed "
                             "capacities")
        cap = float(np.median(caps))
        # invert service = max(net, rpc)/n_ps for ps_bw; only valid when
        # the network term dominates (otherwise capacity pins down rpc)
        ratio = compression_ratio(compression)
        ps_bw = 2.0 * model_bytes * ratio * cap / n_ps
        return cls(model_bytes=model_bytes, n_ps=n_ps, ps_bw=ps_bw,
                   n_tensors=n_tensors, compression=compression)

    def predict(self, workers: Sequence[WorkerSpec]) -> float:
        return self.cluster_speed(workers)

    def update(self, rows: Sequence[dict]) -> "PSBottleneckModel":
        return type(self).fit(rows, self.model_bytes, n_ps=self.n_ps,
                              n_tensors=self.n_tensors,
                              compression=self.compression)

    def score(self, rows: Sequence[dict]) -> Dict[str, float]:
        from repro.calibration.estimator import score_predictions
        caps = [float(r["capacity_steps_per_s"]) for r in rows]
        return score_predictions(caps,
                                 [self.capacity_steps_per_s()] * len(caps))

    def params_hash(self) -> str:
        from repro.calibration.estimator import params_hash
        return params_hash("ps_capacity", self.model_bytes, self.n_ps,
                           self.ps_bw, self.n_tensors, self.rpc_per_tensor,
                           self.compression)


def cluster_speed(workers: Sequence[WorkerSpec],
                  ps: Optional[PSBottleneckModel] = None) -> float:
    """sp = Σ sp_i (§VI-A), PS-capped when a PS model is provided."""
    if ps is None:
        return sum(w.speed for w in workers)
    return ps.cluster_speed(workers)


@dataclasses.dataclass
class Eq4Inputs:
    n_w: int                 # training work, steps
    i_c: int                 # checkpoint interval, steps
    t_c: float               # checkpoint seconds (predicted §IV)
    t_p: float               # provisioning seconds (startup model §V-B)
    t_s: float               # worker replacement seconds (Fig 10)
    revoke_probs: Sequence[float]  # Pr(R_i) per worker over the run (Eq 5)


def expected_revocations(revoke_probs: Sequence[float]) -> float:
    """Eq (5)."""
    return float(sum(revoke_probs))


def predict_total_time(sp: float, inp: Eq4Inputs) -> float:
    """Eq (4)."""
    n_r = expected_revocations(inp.revoke_probs)
    return (inp.n_w / sp
            + math.ceil(inp.n_w / inp.i_c) * inp.t_c
            + n_r * (inp.t_p + inp.t_s))


@dataclasses.dataclass
class HeterogeneousPredictor:
    """§VI-A use case: compose per-GPU speed predictors into cluster
    predictions; built offline, refreshed from monitoring."""
    speed_of: Dict[str, float]      # gpu -> predicted steps/s (solo)
    model_bytes: float
    n_ps: int = 1
    n_tensors: int = 0
    compression: str = "none"

    def predict(self, counts: Dict[str, int]) -> float:
        workers = [WorkerSpec(g, self.speed_of[g])
                   for g, n in counts.items() for _ in range(n)]
        ps = PSBottleneckModel(self.model_bytes, self.n_ps,
                               n_tensors=self.n_tensors,
                               compression=self.compression)
        return cluster_speed(workers, ps)
