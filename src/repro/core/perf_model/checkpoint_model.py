"""§IV — fault-tolerance (checkpoint) overhead modeling.

Checkpoint time T_c is predicted from checkpoint file sizes. TF's (data,
index, meta) triple maps to our checkpointer's (array-shard bytes, manifest
bytes, pytree-structure bytes) — same roles: S_d dominates, S_m/S_i correlate
with tensor count. Four models as Table IV: univariate (S_c), multivariate
(S_d,S_m), PCA-2 (S_d,S_m,S_i), SVR-RBF (S_c).

The paper's key structural finding — training and checkpointing are
SEQUENTIAL, so T_total = T_train + ceil(N_w/I_c) * T_c — is used by
cluster_model.predict_total_time.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.perf_model.regression import (LinearModel, PCA, kfold_mae,
                                              mae, mape, train_test_split)
from repro.core.perf_model.svr import SVR, grid_search_svr


@dataclasses.dataclass
class CkptRow:
    model: str
    s_d: float   # data bytes (array shards)
    s_m: float   # meta bytes (pytree structure)
    s_i: float   # index bytes (manifest)
    t_c: float   # measured checkpoint seconds

    @property
    def s_c(self) -> float:
        return self.s_d + self.s_m + self.s_i


@dataclasses.dataclass
class CkptModelReport:
    name: str
    input_feature: str
    kfold_mae: float
    kfold_mae_std: float
    test_mae: float
    test_mape: float
    extra: dict = dataclasses.field(default_factory=dict)


class _PCALinear:
    def __init__(self, n=2):
        self.pca = PCA(n)
        self.lm = LinearModel()

    def fit(self, X, y):
        Z = self.pca.fit_transform(X)
        self.lm.fit(Z, y)
        return self

    def predict(self, X):
        return self.lm.predict(self.pca.transform(X))


def table4_models(rows: List[CkptRow], seed: int = 0) -> List[CkptModelReport]:
    s_c = np.array([r.s_c for r in rows]) / 1e6   # MB scale
    s_d = np.array([r.s_d for r in rows]) / 1e6
    s_m = np.array([r.s_m for r in rows]) / 1e6
    s_i = np.array([r.s_i for r in rows]) / 1e6
    t = np.array([r.t_c for r in rows])
    reports = []

    def eval_model(name, feat, X, fit_fn, extra=None):
        km, ks = kfold_mae(fit_fn, X, t, k=5, seed=seed)
        Xtr, ytr, Xte, yte = train_test_split(X, t, 0.2, seed)
        m = fit_fn(Xtr, ytr)
        pred = m.predict(Xte)
        reports.append(CkptModelReport(name, feat, km, ks, mae(yte, pred),
                                       mape(yte, pred), extra or {}))

    eval_model("univariate", "S_c", s_c[:, None],
               lambda X, y: LinearModel().fit(X, y))
    eval_model("multivariate", "S_d,S_m", np.stack([s_d, s_m], 1),
               lambda X, y: LinearModel().fit(X, y))
    eval_model("multivariate_pca2", "PCA(S_d,S_m,S_i)",
               np.stack([s_d, s_m, s_i], 1),
               lambda X, y: _PCALinear(2).fit(X, y))

    # min-max normalize S_c (same preprocessing as the §III speed models);
    # fixed gamma=1 keeps the RBF lengthscale on the normalized range
    lo, hi = float(s_c.min()), float(s_c.max())
    Xn = ((s_c - lo) / max(hi - lo, 1e-9))[:, None]
    _, info = grid_search_svr(Xn, t, "rbf", seed=seed)
    Xtr, ytr, Xte, yte = train_test_split(Xn, t, 0.2, seed)
    m = SVR(kernel="rbf", C=info["C"], epsilon=info["epsilon"],
            gamma=1.0).fit(Xtr, ytr)
    pred = m.predict(Xte)
    reports.append(CkptModelReport("svr_rbf", "S_c", info["kfold_mae"],
                                   info["kfold_mae_std"], mae(yte, pred),
                                   mape(yte, pred),
                                   {"C": info["C"],
                                    "epsilon": info["epsilon"]}))
    return reports


@dataclasses.dataclass
class CheckpointTimePredictor:
    """Deployable T_c predictor (linear on S_c — retrains instantly, the
    paper's recommendation for monitored clusters; §IV-C)."""
    lm: LinearModel

    @classmethod
    def fit(cls, rows: List[CkptRow]) -> "CheckpointTimePredictor":
        s_c = np.array([r.s_c for r in rows]) / 1e6
        t = np.array([r.t_c for r in rows])
        return cls(LinearModel().fit(s_c[:, None], t))

    def predict_seconds(self, total_bytes: float) -> float:
        return float(max(0.0, self.lm.predict(
            np.array([[total_bytes / 1e6]]))[0]))

    # Estimator protocol (repro.calibration) ------------------------------
    def predict(self, total_bytes: float) -> float:
        return self.predict_seconds(total_bytes)

    def update(self, rows: List[CkptRow]) -> "CheckpointTimePredictor":
        """Linear model on S_c: refit IS the online update (§IV-C)."""
        return type(self).fit(rows)

    def score(self, rows: List[CkptRow]) -> dict:
        from repro.calibration.estimator import score_predictions
        return score_predictions(
            [r.t_c for r in rows],
            [self.predict_seconds(r.s_c) for r in rows])

    def params_hash(self) -> str:
        from repro.calibration.estimator import params_hash
        return params_hash("checkpoint_time", self.lm.w, self.lm.b)
