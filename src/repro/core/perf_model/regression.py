"""Regression substrate (no sklearn in the container): OLS (uni/multivariate),
PCA preprocessing, k-fold cross-validation, MAE / MAPE — exactly the paper's
evaluation protocol (§III-B, §IV-C).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


def mae(y_true, y_pred) -> float:
    y_true = np.asarray(y_true, float)
    if y_true.size == 0:
        raise ValueError("mae: empty input")
    return float(np.mean(np.abs(y_true - np.asarray(y_pred))))


def mape(y_true, y_pred) -> float:
    y_true = np.asarray(y_true, float)
    if y_true.size == 0:
        raise ValueError("mape: empty input")
    if not np.any(np.abs(y_true) > 0):
        raise ValueError("mape: all targets are zero (undefined denominator)")
    return float(np.mean(np.abs(y_true - np.asarray(y_pred))
                         / np.maximum(np.abs(y_true), 1e-12))) * 100.0


@dataclasses.dataclass
class LinearModel:
    """OLS y = X @ w + b (univariate or multivariate)."""
    w: np.ndarray = None
    b: float = 0.0

    def fit(self, X, y) -> "LinearModel":
        X = np.atleast_2d(np.asarray(X, float))
        if X.shape[0] != len(y):
            X = X.T
        A = np.concatenate([X, np.ones((X.shape[0], 1))], axis=1)
        coef, *_ = np.linalg.lstsq(A, np.asarray(y, float), rcond=None)
        self.w, self.b = coef[:-1], float(coef[-1])
        return self

    def predict(self, X) -> np.ndarray:
        X = np.atleast_2d(np.asarray(X, float))
        if X.shape[1] != len(self.w):
            X = X.T
        return X @ self.w + self.b


@dataclasses.dataclass
class PCA:
    """SVD-based PCA to n_components (paper preprocesses (S_d,S_m,S_i) -> 2)."""
    n_components: int = 2
    mean_: np.ndarray = None
    comps_: np.ndarray = None

    def fit(self, X) -> "PCA":
        X = np.asarray(X, float)
        self.mean_ = X.mean(axis=0)
        _, _, vt = np.linalg.svd(X - self.mean_, full_matrices=False)
        self.comps_ = vt[: self.n_components]
        return self

    def transform(self, X) -> np.ndarray:
        return (np.asarray(X, float) - self.mean_) @ self.comps_.T

    def fit_transform(self, X) -> np.ndarray:
        return self.fit(X).transform(X)


def ols_fit(X, y) -> LinearModel:
    return LinearModel().fit(X, y)


def kfold_indices(n: int, k: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [idx[i::k] for i in range(k)]


def kfold_mae(fit_fn: Callable, X, y, k: int = 5, seed: int = 0
              ) -> Tuple[float, float]:
    """Returns (mean MAE, std MAE) across folds. fit_fn(Xtr, ytr) -> model
    with .predict."""
    X = np.atleast_2d(np.asarray(X, float))
    if X.shape[0] != len(y):
        X = X.T
    y = np.asarray(y, float)
    if y.size == 0:
        raise ValueError("kfold_mae: empty input")
    if k < 2 or k > y.size:
        raise ValueError(f"kfold_mae: k={k} invalid for n={y.size} "
                         "(need 2 <= k <= n, else a fold is empty)")
    folds = kfold_indices(len(y), k, seed)
    maes = []
    for i in range(k):
        te = folds[i]
        tr = np.concatenate([folds[j] for j in range(k) if j != i])
        model = fit_fn(X[tr], y[tr])
        maes.append(mae(y[te], model.predict(X[te])))
    return float(np.mean(maes)), float(np.std(maes))


def train_test_split(X, y, test_frac: float = 0.2, seed: int = 0):
    """The paper's 4:1 split."""
    X = np.atleast_2d(np.asarray(X, float))
    if X.shape[0] != len(y):
        X = X.T
    y = np.asarray(y, float)
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(y))
    n_test = max(1, int(round(len(y) * test_frac)))
    te, tr = idx[:n_test], idx[n_test:]
    return X[tr], y[tr], X[te], y[te]
