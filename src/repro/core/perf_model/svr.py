"""ε-SVR (support vector regression) in the dual, as the paper uses
(Eqs 2-3): f(x) = Σ_i β_i K(x_i, x) + b, β_i = α_i - α_i*, with polynomial
and RBF kernels and box constraint |β_i| <= C (penalty p).

No sklearn in the container. Solver: exact cyclic coordinate descent on the
dual box-QP
    min_β  ½ βᵀKβ − yᵀβ + ε‖β‖₁   s.t. |β_i| ≤ C
(each coordinate has a closed-form soft-threshold + clip update), with the
bias b recovered from KKT-interior support vectors. The Σβ=0 equality of the
textbook dual is absorbed into the post-hoc bias fit — standard practice for
small-N kernel machines and indistinguishable at the paper's N=20 scale.

Grid-search CV mirrors §III-B exactly: p ∈ [10,100] step 10,
ε ∈ [0.01,0.1] step 0.01, k-fold MAE. Kernel matrices are computed once per
fold and shared across the whole grid.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import numpy as np

from repro.core.perf_model.regression import kfold_indices, mae


def poly_kernel(degree: int = 2, coef0: float = 1.0, gamma: float = 1.0):
    def k(a, b):
        return (gamma * (a @ b.T) + coef0) ** degree
    return k


def rbf_kernel(gamma: float = 1.0):
    def k(a, b):
        aa = np.sum(a * a, axis=1)[:, None]
        bb = np.sum(b * b, axis=1)[None, :]
        d2 = aa + bb - 2.0 * (a @ b.T)
        return np.exp(-gamma * np.maximum(d2, 0.0))
    return k


def _fit_dual(K: np.ndarray, y: np.ndarray, C: float, eps: float,
              passes: int = 200, tol: float = 1e-8) -> np.ndarray:
    """Cyclic coordinate descent on the box-constrained ε-SVR dual."""
    n = len(y)
    beta = np.zeros(n)
    f = np.zeros(n)            # K @ beta, maintained incrementally
    # kernel matrices are symmetric, so the contiguous row K[i] stands in
    # for the strided column K[:, i] the update needs
    kdiag = np.diag(K)
    diag = np.maximum(kdiag.copy(), 1e-12)
    for _ in range(passes):
        max_delta = 0.0
        for i in range(n):
            r = y[i] - (f[i] - kdiag[i] * beta[i])  # residual excluding i
            # soft-threshold on epsilon, then box clip
            if r > eps:
                b_new = (r - eps) / diag[i]
            elif r < -eps:
                b_new = (r + eps) / diag[i]
            else:
                b_new = 0.0
            b_new = min(C, max(-C, b_new))
            d = b_new - beta[i]
            if d != 0.0:
                f += K[i] * d
                beta[i] = b_new
                max_delta = max(max_delta, abs(d))
        if max_delta < tol:
            break
    return beta


def _bias(K, y, beta, C, eps) -> float:
    f0 = K @ beta
    interior = (np.abs(beta) > 1e-9) & (np.abs(beta) < C - 1e-9)
    if interior.any():
        return float(np.mean(y[interior] - f0[interior]
                             - eps * np.sign(beta[interior])))
    return float(np.mean(y - f0))


@dataclasses.dataclass
class SVR:
    kernel: str = "rbf"           # rbf | poly
    C: float = 10.0               # paper's penalty p
    epsilon: float = 0.1
    gamma: Optional[float] = None  # default 1/(n_features * var)
    degree: int = 2
    passes: int = 200
    beta_: np.ndarray = None
    b_: float = 0.0
    X_: np.ndarray = None

    def _kfn(self, n_features: int, x_var: float) -> Callable:
        gamma = self.gamma
        if gamma is None:
            gamma = 1.0 / max(n_features * max(x_var, 1e-12), 1e-12)
        if self.kernel == "rbf":
            return rbf_kernel(gamma)
        if self.kernel == "poly":
            return poly_kernel(self.degree, coef0=1.0, gamma=gamma)
        raise KeyError(self.kernel)

    def fit(self, X, y) -> "SVR":
        X = np.atleast_2d(np.asarray(X, float))
        if X.shape[0] != len(y):
            X = X.T
        y = np.asarray(y, float)
        self.X_ = X
        self._kfn_cached = self._kfn(X.shape[1], float(X.var()))
        K = self._kfn_cached(X, X)
        self.beta_ = _fit_dual(K, y, self.C, self.epsilon, self.passes)
        self.b_ = _bias(K, y, self.beta_, self.C, self.epsilon)
        return self

    def predict(self, X) -> np.ndarray:
        X = np.atleast_2d(np.asarray(X, float))
        if self.X_.shape[1] != X.shape[1]:
            X = X.T
        K = self._kfn_cached(X, self.X_)
        return K @ self.beta_ + self.b_

    @property
    def n_support_(self) -> int:
        return int(np.sum(np.abs(self.beta_) > 1e-8))


def grid_search_svr(X, y, kernel: str = "rbf", k: int = 5, seed: int = 0,
                    penalties=None, epsilons=None) -> Tuple[SVR, dict]:
    """The paper's grid search: p ∈ [10,100] step 10, ε ∈ [0.01,0.1] step
    0.01, k-fold CV.

    The kernel is evaluated ONCE on the full dataset and every fold's
    train/test blocks are `np.ix_` selections into it — no per-fold
    kernel re-evaluation, and nothing kernel-shaped inside the (C, ε)
    double loop.
    """
    X = np.atleast_2d(np.asarray(X, float))
    if X.shape[0] != len(y):
        X = X.T
    y = np.asarray(y, float)
    penalties = penalties if penalties is not None else np.arange(10, 101, 10)
    epsilons = epsilons if epsilons is not None else np.arange(0.01, 0.101, 0.01)
    folds = kfold_indices(len(y), k, seed)

    proto = SVR(kernel=kernel)
    kfn = proto._kfn(X.shape[1], float(X.var()))
    K_full = kfn(X, X)                      # one kernel evaluation total
    cache = []
    for i in range(k):
        te = folds[i]
        tr = np.concatenate([folds[j] for j in range(k) if j != i])
        cache.append((K_full[np.ix_(tr, tr)], K_full[np.ix_(te, tr)],
                      y[tr], y[te]))

    best = None
    for C in penalties:
        for eps in epsilons:
            maes = []
            for K_tr, K_te, ytr, yte in cache:
                beta = _fit_dual(K_tr, ytr, float(C), float(eps), passes=60)
                b = _bias(K_tr, ytr, beta, float(C), float(eps))
                maes.append(mae(yte, K_te @ beta + b))
            score = float(np.mean(maes))
            if best is None or score < best["kfold_mae"]:
                best = {"C": float(C), "epsilon": float(eps),
                        "kfold_mae": score,
                        "kfold_mae_std": float(np.std(maes))}
    model = SVR(kernel=kernel, C=best["C"], epsilon=best["epsilon"]).fit(X, y)
    return model, best
