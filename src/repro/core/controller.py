"""CM-DARE controller (Fig 1, §VI-B): compares model-predicted speed against
online measurement; deviations beyond the threshold flag a bottleneck and
trigger mitigation (add a parameter server / replace a slow worker /
re-provision after revocations).

Defaults follow the paper: 30 s warmup, 6.7 % deviation threshold.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional

from repro.core.perf_model.cluster_model import (HeterogeneousPredictor,
                                                 PSBottleneckModel, WorkerSpec,
                                                 cluster_speed)
from repro.core.profiler import PerformanceProfiler


class Action(enum.Enum):
    NONE = "none"
    ADD_PARAMETER_SERVER = "add_parameter_server"
    ENABLE_COMPRESSION = "enable_compression"
    REPLACE_WORKER = "replace_worker"
    REQUEST_REPLACEMENT = "request_replacement"


@dataclasses.dataclass
class Detection:
    bottleneck: bool
    measured: Optional[float]
    predicted: float
    deviation: float
    action: Action
    note: str = ""
    #: version of the `cluster_speed` estimator the check compared against
    #: (0 = static prediction, no recalibration armed). Lets post-hoc
    #: analysis tell "deviation against the stale model" from "deviation
    #: against the refit one".
    model_version: int = 0


class Controller:
    def __init__(self, threshold: float = 0.067, warmup_seconds: float = 30.0):
        self.threshold = threshold
        self.warmup_seconds = warmup_seconds
        self.log: List[Detection] = []
        #: bumped by the recalibration loop on every refit; stamped into
        #: each Detection so the log is auditable against the ModelStore
        self.model_version = 0

    def check(self, profiler: PerformanceProfiler,
              predicted_speed: float,
              ps_model: Optional[PSBottleneckModel] = None,
              workers: Optional[List[WorkerSpec]] = None) -> Detection:
        measured = profiler.speed()
        if measured is None or predicted_speed <= 0:
            det = Detection(False, measured, predicted_speed, 0.0, Action.NONE,
                            "insufficient data / warming up",
                            model_version=self.model_version)
            self.log.append(det)
            return det
        dev = (predicted_speed - measured) / predicted_speed
        if dev <= self.threshold:
            det = Detection(False, measured, predicted_speed, dev, Action.NONE,
                            model_version=self.model_version)
            self.log.append(det)
            return det
        # bottleneck: attribute it
        action = Action.REPLACE_WORKER
        note = "under-performing worker(s) suspected"
        if ps_model is not None and workers is not None:
            if ps_model.is_bottlenecked(workers):
                over = (f"({sum(w.speed for w in workers):.2f} > "
                        f"{ps_model.capacity_steps_per_s():.2f} steps/s)")
                if ps_model.compression == "none":
                    # §VI-B: shrinking the payload is free (no new server);
                    # try it before provisioning more PS capacity
                    action = Action.ENABLE_COMPRESSION
                    note = ("aggregate worker speed exceeds PS capacity "
                            f"{over}; compress the update payload")
                elif ps_model.compression != "topk":
                    # dense compression was not enough — escalate to top-k
                    # sparsification (the last free lever) before paying
                    # for another server
                    action = Action.ENABLE_COMPRESSION
                    note = ("aggregate worker speed exceeds PS capacity "
                            f"{over} despite {ps_model.compression} "
                            "compression; escalate to top-k sparsification")
                else:
                    action = Action.ADD_PARAMETER_SERVER
                    note = ("aggregate worker speed exceeds PS capacity "
                            f"{over} despite "
                            f"{ps_model.compression} compression")
        det = Detection(True, measured, predicted_speed, dev, action, note,
                        model_version=self.model_version)
        self.log.append(det)
        return det

    def mitigate_ps(self, ps_model: PSBottleneckModel) -> PSBottleneckModel:
        """§VI-B mitigation: provision one more parameter server.

        Rebuilt with `replace` so the per-tensor RPC term (`n_tensors`,
        `rpc_per_tensor`) and the wire compression scheme survive the
        mitigation — dropping them silently inflated capacity estimates
        for RPC-bound models.
        """
        return dataclasses.replace(ps_model, n_ps=ps_model.n_ps + 1)

    def mitigate_compression(self, ps_model: PSBottleneckModel,
                             scheme: str = "int8") -> PSBottleneckModel:
        """§VI-B mitigation: shrink the update payload — the capacity
        model's network term scales by `compression_ratio(scheme)`."""
        return dataclasses.replace(ps_model, compression=scheme)
