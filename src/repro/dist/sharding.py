"""Logical-axis sharding: rules, resolution, divisibility fallback, trees.

Models annotate every parameter/activation dimension with a *logical* axis
name ("batch", "heads", "ff", ...). A rule-set maps logical names to mesh
axes; resolution turns a tuple of logical names into a PartitionSpec for a
concrete mesh. The contract:

* a rule value may be a mesh-axis name (``"model"``), a tuple of mesh-axis
  names (``("pod", "data")`` — sharded over the product), or ``None``;
* tuple rules are filtered to the axes present in the target mesh,
  preserving order (so the same rule-set works on single- and multi-pod
  meshes);
* a mesh axis is used at most once per spec — later duplicates replicate;
* unknown logical names replicate;
* ``divisible_spec`` drops any mesh axis that does not divide the concrete
  dimension (for tuples: the longest divisible prefix survives), so reduced
  smoke shapes lower cleanly on production meshes.

``use_sharding(mesh, rules)`` installs an ambient context that the models'
``constrain(x, *names)`` calls read; outside the context ``constrain`` is an
identity, which keeps single-device tests/benchmarks free of mesh plumbing.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Rule = Union[str, Tuple[str, ...], None]
Rules = Dict[str, Rule]

# ---------------------------------------------------------------------------
# rule-sets
# ---------------------------------------------------------------------------
# Training default: Megatron-style tensor parallelism over "model", data
# parallelism over ("pod", "data").
MEGATRON_RULES: Rules = {
    "batch": ("pod", "data"),
    "moe_groups": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "vocab": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "conv_dim": "model",
}

# Decode: keep the TP layout but let the (small) decode batch also absorb the
# "model" axis when divisible — at decode shapes the batch is the only large
# dimension, and the divisibility fallback drops the extra axis otherwise.
DECODE_RULES: Rules = dict(MEGATRON_RULES, batch=("pod", "data", "model"))

# Expert parallelism: experts across "model", everything else data-parallel.
EP_RULES: Rules = {
    "batch": ("pod", "data"),
    "moe_groups": ("pod", "data"),
    "experts": "model",
    "vocab": "model",
}

# Pure data parallelism: flatten every mesh axis into the batch.
DP_RULES: Rules = {
    "batch": ("pod", "data", "model"),
    "moe_groups": ("pod", "data", "model"),
}

# DP + EP hybrid (MoE without tensor parallelism).
DPEP_RULES: Rules = {
    "batch": ("pod", "data"),
    "moe_groups": ("pod", "data"),
    "experts": "model",
}

# FSDP-flavored: parameters sharded along their "embed" dim over the data
# axis (gathered on use); activations stay batch-sharded (the duplicate-axis
# rule replicates "embed" wherever "batch" already took "data").
FSDP_RULES: Rules = {
    "batch": ("pod", "data"),
    "moe_groups": ("pod", "data"),
    "embed": "data",
    "vocab": "model",
}

RULE_SETS: Dict[str, Rules] = {
    "megatron": MEGATRON_RULES, "decode": DECODE_RULES, "ep": EP_RULES,
    "dp": DP_RULES, "dpep": DPEP_RULES, "fsdp": FSDP_RULES,
}


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------
def logical_spec(names: Sequence[Optional[str]], rules: Rules,
                 mesh) -> P:
    """Resolve logical axis names to a PartitionSpec on `mesh`.

    Tuple rules keep tuple form after filtering to the mesh's axes; each
    mesh axis is consumed at most once (later claims replicate).
    """
    mesh_axes = set(mesh.axis_names)
    used: set = set()
    entries = []
    for name in names:
        rule = rules.get(name) if name is not None else None
        entry: Rule = None
        if isinstance(rule, str):
            if rule in mesh_axes and rule not in used:
                entry = rule
                used.add(rule)
        elif isinstance(rule, tuple):
            keep = tuple(a for a in rule if a in mesh_axes and a not in used)
            if keep:
                entry = keep
                used.update(keep)
        entries.append(entry)
    return P(*entries)


def divisible_spec(mesh, spec: P, shape: Sequence[int]) -> P:
    """Drop mesh axes that do not divide the concrete dims of `shape`.

    For tuple entries the longest divisible *prefix* survives (a tuple
    shards over the product of its axes, in order). Singleton tuples
    collapse to the bare axis name.
    """
    sizes = dict(mesh.shape)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        prod = 1
        for a in axes:
            if dim % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
            else:
                break
        out.append(None if not keep
                   else keep[0] if len(keep) == 1 else tuple(keep))
    return P(*out)


def spec(names: Sequence[Optional[str]], rules: Rules, mesh,
         shape: Optional[Sequence[int]] = None) -> P:
    """logical_spec + (optional) divisibility fallback in one call."""
    s = logical_spec(names, rules, mesh)
    return s if shape is None else divisible_spec(mesh, s, shape)


def named_sharding(mesh, names: Sequence[Optional[str]], rules: Rules,
                   shape: Sequence[int]) -> NamedSharding:
    return NamedSharding(mesh, spec(names, rules, mesh, shape))


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def tree_shardings(mesh, axes_tree, rules: Rules, specs_tree):
    """Map a pytree of logical-axes tuples + a matching pytree of
    ShapeDtypeStructs (or arrays) to a pytree of NamedShardings."""
    return jax.tree.map(
        lambda ax, sds: named_sharding(mesh, ax, rules, sds.shape),
        axes_tree, specs_tree, is_leaf=_is_axes_leaf)


def abstract_mesh(axis_sizes: Sequence[int],
                  axis_names: Sequence[str]):
    """Version-portable AbstractMesh construction (the constructor signature
    changed across jax releases)."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes)))


# ---------------------------------------------------------------------------
# ambient context for model-internal constraints
# ---------------------------------------------------------------------------
_CTX = threading.local()


@contextlib.contextmanager
def use_sharding(mesh, rules: Rules):
    """Install (mesh, rules) as the ambient sharding context; model code's
    `constrain` calls resolve against it (trace-time, so wrap jit/lower)."""
    prev = getattr(_CTX, "active", None)
    _CTX.active = (mesh, rules)
    try:
        yield
    finally:
        _CTX.active = prev


def current_sharding() -> Optional[Tuple[Any, Rules]]:
    return getattr(_CTX, "active", None)


def constrain(x, *names: Optional[str]):
    """Apply a with_sharding_constraint derived from logical `names` when a
    sharding context is active; identity otherwise."""
    ctx = current_sharding()
    if ctx is None:
        return x
    mesh, rules = ctx
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(names, rules, mesh, x.shape)))
