"""Gradient compression with error feedback (§VI-B bandwidth mitigation).

When the parameter-server (or all-reduce) link is the bottleneck, shrinking
the update payload raises the PS capacity ceiling of
`cluster_model.PSBottleneckModel`. Plain quantization biases SGD; *error
feedback* (Karimireddy et al., 2019) folds each round's quantization
residual into the next round's gradient, so the applied updates track the
true gradient sum.

Schemes:
  * ``none`` — identity (residual stays zero);
  * ``bf16`` — round-to-bfloat16 (2x smaller);
  * ``int8`` — per-tensor symmetric int8 (4x smaller vs f32);
  * ``topk`` — keep the TOPK_FRACTION largest-|g| entries per tensor
    (sparsification). Each kept entry ships a f32 value + int32 index, so
    the wire cost is 8 bytes * fraction per gradient value — 50x smaller
    at the default 1 % — and error feedback turns it into classic top-k
    EF-SGD (the dropped mass returns through the residual).

The escalation ladder the controller walks is dense-first: none -> the
configured dense scheme (int8) -> topk -> add a parameter server.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

#: fraction of gradient entries top-k sparsification keeps per tensor
TOPK_FRACTION = 0.01

SCHEMES = ("none", "bf16", "int8", "topk")
_BYTES_PER_VALUE = {"none": 4.0, "bf16": 2.0, "int8": 1.0,
                    # f32 value + int32 index per surviving entry
                    "topk": 8.0 * TOPK_FRACTION}


def compression_ratio(scheme: str) -> float:
    """Payload bytes per f32 gradient value (feeds the PS capacity model)."""
    if scheme not in SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}; known: {SCHEMES}")
    return _BYTES_PER_VALUE[scheme] / 4.0


def payload_bytes(tree, scheme: str) -> float:
    """Wire bytes of one compressed gradient push (the telemetry the
    trainer emits per step, and the numerator of the PS network term)."""
    n_values = sum(
        int(math.prod(getattr(leaf, "shape", jnp.shape(leaf))))
        for leaf in jax.tree.leaves(tree))
    return n_values * _BYTES_PER_VALUE[scheme]


def _quantize(x: jnp.ndarray, scheme: str) -> jnp.ndarray:
    """Lossy round-trip of one tensor (decompressed representation)."""
    if scheme == "none":
        return x
    if scheme == "bf16":
        return x.astype(jnp.bfloat16).astype(x.dtype)
    if scheme == "topk":
        flat = x.reshape(-1)
        k = max(1, int(round(TOPK_FRACTION * flat.size)))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return kept.reshape(x.shape)
    # int8: per-tensor symmetric scale
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q * scale


class ErrorFeedback:
    """Stateless compressor + explicit residual tree (functional style, so
    the residual can live in a checkpointable train state)."""

    def __init__(self, scheme: str = "int8"):
        if scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {scheme!r}; known: {SCHEMES}")
        self.scheme = scheme

    def init(self, params) -> Any:
        """Zero residual tree shaped like `params` (f32)."""
        return jax.tree.map(
            lambda p: jnp.zeros(jnp.shape(p), jnp.float32), params)

    def roundtrip(self, grads, residual) -> Tuple[Any, Any]:
        """Compress (grads + residual); return (decompressed update,
        new residual). The decompressed update is what the PS applies."""
        corrected = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residual)
        applied = jax.tree.map(
            lambda c: _quantize(c, self.scheme), corrected)
        new_residual = jax.tree.map(lambda c, a: c - a, corrected, applied)
        return applied, new_residual
