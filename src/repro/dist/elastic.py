"""Elastic membership for transient clusters (§V).

A training run on revocable servers is a sequence of *membership epochs*:
the member set is fixed within an epoch and rolls on every revocation or
join. The global batch is an invariant of the run — each epoch re-splits it
across the surviving members (the paper's data-parallel recovery semantics:
no data is dropped or duplicated across a membership change).

`ElasticMembership` is pure bookkeeping — the trainer drives it from its
event stream, the fleet simulator from sampled revocations.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Tuple


@dataclasses.dataclass(frozen=True)
class Member:
    """One worker: a transient accelerator server."""
    id: int
    gpu: str = "v5e"


@dataclasses.dataclass(frozen=True)
class Epoch:
    """An immutable membership epoch: who is in it and how the global batch
    is split across them (first members absorb the remainder)."""
    number: int
    members: Tuple[Member, ...]
    batch_of: Dict[int, int]


def split_batch(global_batch: int, member_ids: List[int]) -> Dict[int, int]:
    """Even split of `global_batch` with the remainder spread over the
    first members; always sums to `global_batch`."""
    n = len(member_ids)
    if n == 0:
        return {}
    per, rem = divmod(global_batch, n)
    return {mid: per + (1 if i < rem else 0)
            for i, mid in enumerate(member_ids)}


class ElasticMembership:
    """Mutable membership state; every revoke/join rolls the epoch."""

    def __init__(self, members: Iterable[Member], global_batch: int):
        self._members: Dict[int, Member] = {m.id: m for m in members}
        self.global_batch = int(global_batch)
        self.epoch_no = 0
        # launch-roster size: the denominator of the quorum fraction a
        # DegradationPolicy tiers on (replacement joins restore it toward
        # 1.0; over-joins may push it above — both are meaningful)
        self.roster_size = max(1, len(self._members))

    # ------------------------------------------------------------- queries
    @property
    def n_alive(self) -> int:
        return len(self._members)

    @property
    def alive_fraction(self) -> float:
        return self.n_alive / self.roster_size

    def __contains__(self, member_id: int) -> bool:
        return member_id in self._members

    def alive(self) -> Tuple[Member, ...]:
        return tuple(self._members.values())

    def current_epoch(self) -> Epoch:
        return Epoch(self.epoch_no, self.alive(),
                     split_batch(self.global_batch, list(self._members)))

    # ------------------------------------------------------------- events
    def revoke(self, member_id: int) -> Epoch:
        if member_id not in self._members:
            raise KeyError(f"member {member_id} is not in the cluster")
        del self._members[member_id]
        self.epoch_no += 1
        return self.current_epoch()

    def join(self, member: Member) -> Epoch:
        if member.id in self._members:
            raise KeyError(f"member {member.id} already in the cluster")
        self._members[member.id] = member
        self.epoch_no += 1
        return self.current_epoch()
