"""`repro.dist` — the distributed-execution layer of the CM-DARE stack.

Three deliberately small, orthogonal modules:

* :mod:`repro.dist.sharding` — logical-axis -> mesh-axis resolution
  (rule-sets, divisibility fallback, NamedSharding trees, and the
  ``use_sharding`` context the models' ``constrain`` calls read).
* :mod:`repro.dist.elastic` — transient-cluster membership: who is alive,
  which membership epoch we are in, and how the fixed global batch is
  re-split when workers are revoked or join (§V of the paper).
* :mod:`repro.dist.compression` — gradient compression with error
  feedback (bf16 / int8), for the bandwidth-bound PS regimes of §VI-B.

Everything here is host-side metadata/bookkeeping; nothing allocates device
memory at import time.
"""
from repro.dist.elastic import ElasticMembership, Epoch, Member  # noqa: F401
from repro.dist.compression import (ErrorFeedback,  # noqa: F401
                                    compression_ratio, payload_bytes)
from repro.dist import sharding  # noqa: F401
