from repro.checkpoint.checkpointer import (  # noqa: F401
    Checkpointer, CheckpointSizes, WriterLease,
)
