"""Fault-tolerant checkpointing with writer-lease handover.

Layout per checkpoint (mirrors TF's data/index/meta triple — the sizes feed
the §IV prediction models):
    step_<N>/
      data-00000.bin     array payload, concatenated           (S_d)
      index.json         leaf -> (offset, shape, dtype, crc32)  (S_i)
      meta.json          pytree structure + user metadata       (S_m)
    LATEST               atomic pointer to the newest committed step
    writer.lease         checkpoint-writer lease (chief handover, §V-E)

Properties the paper's transient setting needs:
  * atomic commit (tmp dir + rename): a revocation mid-write never corrupts
    the latest checkpoint;
  * the writer role is a LEASE, not an identity: any surviving worker can
    steal an expired lease and continue checkpointing (CM-DARE's fix for the
    chief-IP recomputation pathology, Fig 11);
  * async mode: device->host copy happens synchronously (fast), file write
    happens on a background thread (training continues) — used to contrast
    with the paper's sequential checkpointing measurement.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A committed checkpoint failed integrity validation (missing file,
    short payload, or per-array checksum mismatch)."""


class LeaseLostError(RuntimeError):
    """The writer lease was lost between starting a save and committing
    it; the commit was aborted so no torn/contested state was published."""


@dataclasses.dataclass
class CheckpointSizes:
    s_d: int
    s_i: int
    s_m: int

    @property
    def total(self) -> int:
        return self.s_d + self.s_i + self.s_m


class WriterLease:
    """File-based lease: holder writes {holder, expires}; others may steal
    after expiry or an explicit revocation notification.

    `clock` is injectable (default `time.time`) so chaos `VirtualClock`
    scenarios exercise expiry and steal races deterministically instead
    of sleeping. Acquisition is verified by reading back the committed
    lease file: under a steal race both contenders pass the pre-check,
    but only the one whose rename landed last actually holds the lease.
    """

    def __init__(self, root: str, holder: str, ttl_s: float = 60.0,
                 clock: Callable[[], float] = time.time):
        self.path = os.path.join(root, "writer.lease")
        self.holder = holder
        self.ttl = ttl_s
        self.clock = clock

    def _read(self) -> Optional[dict]:
        try:
            with open(self.path) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def try_acquire(self, now: Optional[float] = None) -> bool:
        now = self.clock() if now is None else now
        cur = self._read()
        if cur is not None and cur["holder"] != self.holder \
                and cur["expires"] > now and not cur.get("revoked"):
            return False
        # per-holder tmp name: two stealers racing must not truncate each
        # other's in-flight write before the atomic rename
        tmp = f"{self.path}.tmp.{self.holder}"
        with open(tmp, "w") as f:
            json.dump({"holder": self.holder, "expires": now + self.ttl,
                       "revoked": False}, f)
        os.replace(tmp, self.path)
        cur = self._read()
        return cur is not None and cur.get("holder") == self.holder

    def renew(self, now: Optional[float] = None) -> bool:
        cur = self._read()
        if cur is None or cur["holder"] != self.holder:
            return False
        return self.try_acquire(now)

    def held_by_me(self, now: Optional[float] = None) -> bool:
        cur = self._read()
        now = self.clock() if now is None else now
        return (cur is not None and cur["holder"] == self.holder
                and cur["expires"] > now and not cur.get("revoked"))

    def notify_revoked(self) -> None:
        """Revocation notification (transient-TF's hook): immediately frees
        the lease so a survivor can take over without waiting for expiry."""
        cur = self._read() or {"holder": self.holder, "expires": 0}
        cur["revoked"] = True
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(cur, f)
        os.replace(tmp, self.path)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


class Checkpointer:
    def __init__(self, root: str, holder: str = "worker-0",
                 async_write: bool = False, keep: int = 3,
                 clock: Callable[[], float] = time.time):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.lease = WriterLease(root, holder, clock=clock)
        self.async_write = async_write
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_sizes: Optional[CheckpointSizes] = None
        self.last_save_seconds: Optional[float] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, metadata: Optional[dict] = None,
             require_lease: bool = True) -> Optional[CheckpointSizes]:
        if require_lease and not self.lease.held_by_me():
            if not self.lease.try_acquire():
                return None  # someone else holds the writer role
        t0 = time.monotonic()
        flat = _flatten(tree)  # device->host copy is synchronous
        if self.async_write:
            self.wait()
            self._thread = threading.Thread(
                target=self._write,
                args=(step, flat, metadata or {}, require_lease))
            self._thread.start()
            return None
        sizes = self._write(step, flat, metadata or {}, require_lease)
        self.last_save_seconds = time.monotonic() - t0
        return sizes

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               metadata: dict, fenced: bool = False) -> CheckpointSizes:
        tmp = os.path.join(self.root, f".tmp_step_{step}")
        final = os.path.join(self.root, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        index: Dict[str, Any] = {}
        offset = 0
        data_path = os.path.join(tmp, "data-00000.bin")
        with open(data_path, "wb") as f:
            for key in sorted(flat):
                arr = flat[key]
                buf = arr.tobytes()
                index[key] = {"offset": offset, "nbytes": len(buf),
                              "shape": list(arr.shape),
                              "dtype": str(arr.dtype),
                              "crc": zlib.crc32(buf) & 0xFFFFFFFF}
                f.write(buf)
                offset += len(buf)
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        meta = {"step": step, "n_tensors": len(flat),
                "created": time.time(), **metadata}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if fenced and not self.lease.held_by_me():
            # the lease was stolen (holder revoked mid-save): abort before
            # the rename so the contested write never becomes visible
            shutil.rmtree(tmp, ignore_errors=True)
            raise LeaseLostError(
                f"{self.lease.holder} lost writer.lease during save of "
                f"step {step}; commit aborted")
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        with open(os.path.join(self.root, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.root, "LATEST.tmp"),
                   os.path.join(self.root, "LATEST"))
        sizes = CheckpointSizes(
            offset,
            os.path.getsize(os.path.join(final, "index.json")),
            os.path.getsize(os.path.join(final, "meta.json")))
        self.last_sizes = sizes
        self._gc()
        return sizes

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        """Committed step numbers, hardened against stray entries: only
        directories named exactly ``step_<int>`` count — a leftover
        ``step_backup`` file or half-written ``.tmp_step_*`` dir must
        never break restore-or-init."""
        out = []
        for name in os.listdir(self.root):
            if not name.startswith("step_"):
                continue
            tail = name[len("step_"):]
            if not tail.isdigit():
                continue
            if not os.path.isdir(os.path.join(self.root, name)):
                continue
            out.append(int(tail))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        try:
            with open(os.path.join(self.root, "LATEST")) as f:
                step = int(f.read().strip())
            # a stale pointer (step dir GC'd or lost) falls through to the
            # newest committed directory instead of a doomed restore
            if step in steps:
                return step
        except (FileNotFoundError, ValueError):
            pass
        return steps[-1] if steps else None

    def read_meta(self, step: Optional[int] = None) -> dict:
        """The meta.json of a committed checkpoint (structure + user
        metadata) without loading the array payload."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        with open(os.path.join(self.root, f"step_{step}", "meta.json")) as f:
            return json.load(f)

    def restore(self, tree_like, step: Optional[int] = None
                ) -> Tuple[Any, int]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step}")
        with open(os.path.join(d, "index.json")) as f:
            index = json.load(f)
        with open(os.path.join(d, "data-00000.bin"), "rb") as f:
            blob = f.read()
        flat = {}
        for key, rec in index.items():
            arr = np.frombuffer(
                blob, dtype=np.dtype(rec["dtype"]),
                count=int(np.prod(rec["shape"])) if rec["shape"] else 1,
                offset=rec["offset"]).reshape(rec["shape"])
            flat[key] = arr
        # rebuild in tree_like's structure
        leaves_paths = jax.tree_util.tree_flatten_with_path(tree_like)
        new_leaves = []
        for path, leaf in leaves_paths[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = flat[key]
            new_leaves.append(np.asarray(arr).astype(leaf.dtype)
                              if hasattr(leaf, "dtype") else arr)
        tree = jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves)
        return tree, step

    # ------------------------------------------------------------- integrity
    def validate(self, step: int) -> None:
        """Raise `CheckpointCorruptError` unless ``step_<step>`` is a
        complete, checksum-clean checkpoint: index/meta parse, the data
        payload covers every recorded extent, and each array's crc32
        matches (entries written before checksums existed get the extent
        check only)."""
        d = os.path.join(self.root, f"step_{step}")
        try:
            with open(os.path.join(d, "index.json")) as f:
                index = json.load(f)
            with open(os.path.join(d, "meta.json")) as f:
                json.load(f)
            with open(os.path.join(d, "data-00000.bin"), "rb") as f:
                blob = f.read()
        except (FileNotFoundError, NotADirectoryError,
                json.JSONDecodeError) as exc:
            raise CheckpointCorruptError(
                f"step {step}: unreadable checkpoint ({exc})") from exc
        for key, rec in index.items():
            end = rec["offset"] + rec["nbytes"]
            if end > len(blob):
                raise CheckpointCorruptError(
                    f"step {step}: torn payload — {key} needs bytes "
                    f"[{rec['offset']}, {end}) of {len(blob)}")
            if "crc" in rec:
                got = zlib.crc32(blob[rec["offset"]:end]) & 0xFFFFFFFF
                if got != rec["crc"]:
                    raise CheckpointCorruptError(
                        f"step {step}: checksum mismatch on {key} "
                        f"(stored {rec['crc']:#010x}, got {got:#010x})")

    def restore_latest_valid(self, tree_like,
                             on_fallback=None) -> Tuple[Any, int, int]:
        """Restore from the newest checkpoint that passes `validate`,
        falling back generation by generation past torn or corrupt ones
        instead of crashing or silently loading bad state. Returns
        ``(tree, step, depth)`` where ``depth`` counts skipped
        generations (0 = the latest was clean); ``on_fallback(step,
        error)`` is called for each one skipped. Raises
        `FileNotFoundError` when no checkpoint exists at all and
        `CheckpointCorruptError` when every one is damaged."""
        steps: List[int] = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        errors: List[str] = []
        latest = self.latest_step()
        # LATEST first, then the remaining committed steps newest-first
        order = [latest] + [s for s in sorted(steps, reverse=True)
                            if s != latest]
        for depth, step in enumerate(order):
            try:
                self.validate(step)
                tree, got = self.restore(tree_like, step=step)
                return tree, got, depth
            except CheckpointCorruptError as exc:
                errors.append(str(exc))
                if on_fallback is not None:
                    on_fallback(step, exc)
        raise CheckpointCorruptError(
            "every committed checkpoint failed validation: "
            + "; ".join(errors))

    def corrupt(self, step: int, nbytes: int = 16) -> None:
        """Test/chaos hook: flip the first `nbytes` of a committed step's
        payload in place, simulating a torn or bit-rotted write that the
        checksum fallback must detect and skip."""
        path = os.path.join(self.root, f"step_{step}", "data-00000.bin")
        with open(path, "r+b") as f:
            head = f.read(nbytes)
            f.seek(0)
            f.write(bytes(b ^ 0xFF for b in head))
