"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution. Backbone only; the vision
frontend is a stub: input_specs() provides precomputed patch embeddings merged
into the token stream plus 3D (t,h,w) M-RoPE position ids. [arXiv:2409.12191; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # t/h/w rotary sections (sum = head_dim/2)
    tie_embeddings=True,
    frontend_dim=1536,            # patch embeds arrive at d_model
)

SMOKE = CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                     head_dim=32, d_ff=256, vocab_size=512,
                     mrope_sections=(4, 6, 6), frontend_dim=128)
