"""stablelm-1.6b [dense] — MHA (kv=32), partial rotary 25%.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    partial_rotary=0.25,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                     head_dim=32, d_ff=256, vocab_size=512)
