"""qwen3-1.7b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                     head_dim=32, d_ff=256, vocab_size=512)
