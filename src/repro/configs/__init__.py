from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    PREFILL_32K,
    RunConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    TRAIN_4K,
    valid_cells,
)
from repro.configs.registry import ARCH_IDS, all_configs, get_config  # noqa: F401
