"""starcoder2-15b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=100_000.0,
    mlp_variant="gelu",   # starcoder2 uses a plain 2-matrix GELU MLP
)

SMOKE = CONFIG.with_(n_layers=2, d_model=192, n_heads=6, n_kv_heads=2,
                     head_dim=32, d_ff=384, vocab_size=512)
