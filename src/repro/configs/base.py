"""Config system: architecture + shape + mesh + run configs.

Every assigned architecture is a `ModelConfig`; input shapes are `ShapeConfig`s.
Configs are plain frozen dataclasses so they hash (usable as jit static args).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 0
    n_shared_experts: int = 0     # always-on experts (DeepSeek style)
    expert_d_ff: int = 0          # per-expert hidden size
    capacity_factor: float = 1.25
    group_size: int = 4096        # tokens per routing group (local sort dispatch)
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => no query compression (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD mixer."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm | cnn
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # attention flavor
    qk_norm: bool = False
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0     # fraction of head_dim that rotates
    mrope_sections: Tuple[int, ...] = ()  # M-RoPE (qwen2-vl): dims per (t,h,w)
    causal: bool = True             # False => encoder (hubert)
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # deepseek: first k layers use a dense FFN instead of MoE
    first_k_dense: int = 0
    dense_d_ff: int = 0
    # hybrid (zamba2): one weight-shared attention block every `shared_attn_every`
    shared_attn_every: int = 0
    # misc
    mlp_variant: str = "swiglu"  # swiglu | gelu (2-matrix, starcoder2-style)
    kv_quant: bool = False       # int8 KV cache (per-token-per-head scales)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # frontend stub for audio/vlm: dim of precomputed frame/patch embeddings
    frontend_dim: int = 0
    remat: str = "none"  # none | full | dots  (activation checkpoint policy)
    use_pallas: bool = False
    # dry-run probes: python-loop layers instead of lax.scan so XLA
    # cost_analysis sees every layer (scan bodies are costed only once)
    unroll_layers: bool = False

    # ---- derived quantities -------------------------------------------------
    @property
    def kv_groups(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter count (embedding + blocks + head), used for C_m features,
    # checkpoint-size prediction and MODEL_FLOPS=6ND roofline sanity.
    def param_count(self) -> int:
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        total = V * d  # embed
        if not self.tie_embeddings and V:
            total += V * d  # lm head
        if self.family in ("ssm",):
            total += L * self._ssm_layer_params()
        elif self.family == "hybrid":
            n_shared = 1
            total += L * self._ssm_layer_params()
            total += n_shared * self._attn_params() + n_shared * self._mlp_params(self.d_ff)
        else:
            total += L * self._attn_params()
            if self.moe:
                moe_layers = L - self.first_k_dense
                total += self.first_k_dense * self._mlp_params(self.dense_d_ff or self.d_ff)
                per_expert = self._mlp_params(self.moe.expert_d_ff)
                total += moe_layers * (
                    (self.moe.n_experts + self.moe.n_shared_experts) * per_expert
                    + self.d_model * self.moe.n_experts  # router
                )
            else:
                total += L * self._mlp_params(self.d_ff)
        total += L * 2 * d + d  # norms
        return total

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * (self.n_heads * qk_head)                        # W_q
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)          # W_dkv (+rope k)
            p += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += self.n_heads * m.v_head_dim * d                    # W_o
            return p
        hd = self.head_dim
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

    def _mlp_params(self, d_ff: int) -> int:
        mats = 2 if self.mlp_variant == "gelu" else 3  # SwiGLU has a gate
        return mats * self.d_model * d_ff

    def _ssm_layer_params(self) -> int:
        s = self.ssm
        d_inner = s.expand * self.d_model
        n_heads = d_inner // s.head_dim
        p = self.d_model * (2 * d_inner + 2 * s.n_groups * s.d_state + n_heads)  # in_proj
        p += s.d_conv * (d_inner + 2 * s.n_groups * s.d_state)  # conv
        p += n_heads * 2  # A_log, D
        p += d_inner * self.d_model  # out_proj
        return p

    def flops_per_token(self, seq_len: int) -> float:
        """Approx. training-forward FLOPs per token (the paper's C_m feature).

        6*N_active per fwd+bwd token is computed by callers; this returns the
        *active* parameter count (dense-equivalent matmul params touched per
        token) plus the attention quadratic term.
        """
        n_active = self.active_param_count()
        flops = 2.0 * n_active
        # attention score/value quadratic term
        if self.family not in ("ssm",):
            n_attn_layers = (1 if self.family == "hybrid" else self.n_layers)
            if self.family == "hybrid" and self.shared_attn_every:
                n_attn_layers = self.n_layers // self.shared_attn_every
            hd = self.head_dim or (self.mla.qk_nope_head_dim + self.mla.qk_rope_head_dim
                                   if self.mla else 0)
            flops += n_attn_layers * 4.0 * self.n_heads * hd * seq_len * (
                0.5 if self.causal else 1.0)
        return flops

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k + shared experts)."""
        if not self.moe:
            return self.param_count()
        total = self.param_count()
        moe_layers = self.n_layers - self.first_k_dense
        per_expert = self._mlp_params(self.moe.expert_d_ff)
        inactive = moe_layers * (self.moe.n_experts - self.moe.top_k) * per_expert
        return total - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "long_decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def valid_cells(cfg: ModelConfig):
    """The (arch x shape) cells that are runnable for this architecture.

    Skips (recorded, per docs/DESIGN.md §4): decode shapes for encoder-only archs;
    long_500k for pure full-attention archs (needs sub-quadratic attention).
    """
    out = []
    for s in ALL_SHAPES:
        if not cfg.causal and s.kind in ("decode", "long_decode"):
            continue  # encoder-only: no autoregressive step
        if s.kind == "long_decode" and cfg.family not in ("ssm", "hybrid"):
            continue  # full attention: sub-quadratic required at 500k
        out.append(s)
    return out


@dataclass(frozen=True)
class RunConfig:
    """Training-run hyperparameters (used by the launcher/examples)."""
    optimizer: str = "adamw"
    lr: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    checkpoint_interval: int = 500        # steps (paper: I_c)
    checkpoint_dir: str = "/tmp/repro_ckpt"
    zero1: bool = True                    # shard optimizer state over data axis
    master_weights: bool = False          # bf16 live params + fp32 master in opt
    grad_compression: str = "none"        # none | bf16 | int8 | topk
    seed: int = 0
    microbatch: int = 0                   # 0 => no gradient accumulation
    # persistent JAX compilation cache directory ("" = disabled): repeated
    # Sessions/processes over the same step skip XLA recompilation
    compilation_cache_dir: str = ""
    # recovery policies (repro.resilience.ResilienceConfig; None = the
    # pre-resilience fail-fast behavior). Steers the outer training loop
    # and the fleet simulators, never the traced step function.
    resilience: Optional[object] = None
    # online recalibration (repro.calibration.RecalibrationConfig; None =
    # static calibrations, bit-identical to the pre-calibration-layer
    # behavior). Like `resilience`, steers only the outer loop.
    recalibration: Optional[object] = None
