"""yi-6b [dense] — llama-arch GQA kv=4. [arXiv:2403.04652; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                     head_dim=32, d_ff=256, vocab_size=512)
