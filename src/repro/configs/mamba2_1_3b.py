"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                       # attention-free, no FFN (mixer-only blocks)
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=256),
)

SMOKE = CONFIG.with_(n_layers=2, d_model=128, vocab_size=512,
                     ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                                   n_groups=1, chunk_size=32))
