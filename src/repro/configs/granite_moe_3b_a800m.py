"""granite-moe-3b-a800m [moe] — 40 experts top-8, per-expert d_ff=512.
[hf:ibm-granite/granite-3.0 family; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                 # == expert_d_ff; all FFNs are MoE
    vocab_size=49155,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, expert_d_ff=512,
                  capacity_factor=1.25, group_size=4096),
)

SMOKE = CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                     head_dim=32, d_ff=64, vocab_size=512,
                     moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=64,
                                   capacity_factor=1.5, group_size=64))
