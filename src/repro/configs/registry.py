"""Architecture registry: ``--arch <id>`` resolution for launcher/tests/benchmarks."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "yi-6b": "repro.configs.yi_6b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
