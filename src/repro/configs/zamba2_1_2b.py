"""zamba2-1.2b [hybrid] — Mamba2 backbone + one weight-SHARED attention block
invoked every 6th layer (simplified from Zamba2's shared block + LoRA).
[arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,                  # mamba2 layers
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,                    # shared attention block's MLP
    vocab_size=32000,
    shared_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=256),
)

SMOKE = CONFIG.with_(n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
                     head_dim=32, d_ff=256, vocab_size=512, shared_attn_every=2,
                     ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                                   n_groups=1, chunk_size=32))
