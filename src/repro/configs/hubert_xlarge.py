"""hubert-xlarge [audio] — encoder-only (bidirectional), conv frontend stubbed:
input_specs() provides precomputed frame embeddings. vocab=504 is the masked-
prediction codebook. No decode shapes. [arXiv:2106.07447; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    partial_rotary=0.0,     # no RoPE; conv positional embedding
    frontend_dim=512,       # stubbed wav2vec2-style conv stem output dim
    mlp_variant="gelu",     # wav2vec2/hubert FFN: 2-matrix GELU
)

SMOKE = CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                     head_dim=32, d_ff=256, vocab_size=64, frontend_dim=64)
