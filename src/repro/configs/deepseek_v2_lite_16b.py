"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512), 2 shared + 64 routed top-6
experts (per assignment line), first layer dense. [arXiv:2405.04434; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=0,               # MLA defines per-component head dims
    d_ff=1408,                # routed-expert hidden size
    vocab_size=102400,
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2, expert_d_ff=1408,
                  capacity_factor=1.25, group_size=4096),
    first_k_dense=1,
    dense_d_ff=10944,
)

SMOKE = CONFIG.with_(
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=512,
    mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                  v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, n_shared_experts=1, expert_d_ff=64,
                  capacity_factor=1.5, group_size=64),
    first_k_dense=1, dense_d_ff=256)
