"""Recovery policies (docs/resilience.md, DESIGN.md §8).

Three frozen, hashable configs compose into `ResilienceConfig`, the
value carried by `RunConfig.resilience` and `FleetSim(resilience=...)`:

* `RetryPolicy` — bounded exponential backoff with symmetric jitter and
  a per-operation deadline. The schedule is a pure function of the
  attempt index and a uniform draw, so the live trainer and the three
  fleet engines can reproduce the *same* delays from the same keyed
  uniform streams (the PR 5/7 parity contract extends to recovery).
* `DegradationPolicy` — quorum-based tiers keyed on the alive fraction
  of the launch roster: ``continue`` (full speed), ``shrink_batch``
  (effective throughput × `shrink_factor`), ``pause`` (no forward
  progress until membership recovers above `quorum`).
* `ResilienceConfig` — the two policies plus the sim-side restore
  failure probability and an independent seed for the recovery streams.

Sim-side restore stalls are drawn from counter-based streams keyed on
``(seed, tag, generation)`` exactly like `FleetDraws` replacement pools:
one `(n, slots, 2K)` uniform block per generation level, row ``j`` a
fixed slice of the stream whatever the ensemble width, so every engine
(and any `n`) sees identical delays for trajectory ``j``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: stream tag for restore-retry uniforms (cf. fleet_batched's
#: _TAG_INITIAL / _TAG_JOIN and the chaos injector tags)
_TAG_RESTORE = 0x5E11E
#: stream tag for live-side retry jitter (per holder/op key)
_TAG_LIVE = 0x5E1FE


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff: the delay after the ``attempt``-th
    failure (1-based) is ``min(max_delay_s, base_delay_s *
    multiplier**(attempt-1))`` scaled by ``1 + jitter*(2u-1)`` for a
    uniform ``u`` — deterministic given the draw, bounded by
    ``max_delay_s * (1 + jitter)``, and the cumulative sleep never
    exceeds ``deadline_s``."""
    max_attempts: int = 4
    base_delay_s: float = 0.5
    multiplier: float = 2.0
    max_delay_s: float = 8.0
    jitter: float = 0.25
    deadline_s: float = 30.0

    def backoff(self, attempt: int, u: float) -> float:
        base = min(self.max_delay_s,
                   self.base_delay_s * self.multiplier ** (attempt - 1))
        return base * (1.0 + self.jitter * (2.0 * float(u) - 1.0))


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """Quorum tiers on the alive fraction ``n_alive / roster_size``:
    ``frac < quorum`` → ``pause``; ``frac < shrink_below`` →
    ``shrink_batch``; else ``continue``. The defaults (both thresholds
    0) never degrade, so `ResilienceConfig()` is behavior-preserving."""
    quorum: float = 0.0
    shrink_below: float = 0.0
    shrink_factor: float = 0.5

    def tier(self, n_alive: int, n_total: int) -> str:
        frac = n_alive / max(n_total, 1)
        if frac < self.quorum:
            return "pause"
        if frac < self.shrink_below:
            return "shrink_batch"
        return "continue"

    def speed_factor(self, n_alive: int, n_total: int) -> float:
        return {"pause": 0.0, "shrink_batch": self.shrink_factor,
                "continue": 1.0}[self.tier(n_alive, n_total)]


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """The recovery layer's single knob bundle. `restore_fail_p` is the
    sim-side per-attempt probability that reloading the checkpoint after
    a stock-chief revocation fails (store outage, torn read): each
    leading failure costs one backoff delay, so the revoked trajectory
    stalls for the keyed-deterministic retry schedule before
    recomputing. The default (0.0) adds no stalls."""
    retry: RetryPolicy = RetryPolicy()
    degradation: DegradationPolicy = DegradationPolicy()
    restore_fail_p: float = 0.0
    seed: int = 0


def stall_from_uniforms(retry: RetryPolicy, fail_p: float,
                        u: np.ndarray) -> np.ndarray:
    """Restore-stall seconds from a ``(..., 2K)`` uniform block
    (``K = retry.max_attempts``): the first K uniforms decide failures
    (``u < fail_p``), the last K supply jitter; the stall is the sum of
    backoff delays over the *leading* run of failures, clamped to the
    deadline. Pure NumPy float64 — the event and batched engines index
    it directly and the jit engine ships the materialized pool to
    device, so all three consume bit-identical delays."""
    u = np.asarray(u, np.float64)
    k = u.shape[-1] // 2
    u_fail, u_jit = u[..., :k], u[..., k:]
    lead = np.cumprod(u_fail < fail_p, axis=-1).astype(bool)
    i = np.arange(1, k + 1, dtype=np.float64)
    base = np.minimum(retry.max_delay_s,
                      retry.base_delay_s * retry.multiplier ** (i - 1.0))
    delays = base * (1.0 + retry.jitter * (2.0 * u_jit - 1.0))
    total = np.where(lead, delays, 0.0).sum(axis=-1)
    return np.minimum(float(retry.deadline_s), total)


def stall_pool(res: ResilienceConfig, sim_seed: int, n: int, slots: int,
               gen: int) -> np.ndarray:
    """The ``(n, slots)`` restore-stall matrix for generation ``gen`` —
    one keyed stream per level, same scheme as `FleetDraws._level`."""
    ss = np.random.SeedSequence(((sim_seed + res.seed) % 2 ** 32,
                                 _TAG_RESTORE, int(gen)))
    u = np.random.default_rng(ss).random(
        (n, slots, 2 * res.retry.max_attempts))
    return stall_from_uniforms(res.retry, res.restore_fail_p, u)


def live_jitter_uniforms(retry: RetryPolicy, seed: int,
                         key: int) -> np.ndarray:
    """Jitter uniforms for one live retried operation, keyed on
    ``(seed, op key)`` — deterministic under a fixed `RunConfig.seed`.
    Negative keys (the trainer tags its restore stream -1) wrap rather
    than crash: SeedSequence entropy must be non-negative."""
    ss = np.random.SeedSequence((seed % 2 ** 32, _TAG_LIVE,
                                 int(key) % 2 ** 32))
    return np.random.default_rng(ss).random(retry.max_attempts)
