"""Live-side retry execution: `call_with_retries` wraps one fallible
operation (checkpoint save, restore, replacement join) in a
`RetryPolicy`, emitting a ``retry`` event per attempt so the chaos
evaluator can score recovery cost (docs/resilience.md)."""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro.resilience.policy import RetryPolicy, live_jitter_uniforms


class RetryExhausted(RuntimeError):
    """All attempts failed (or the deadline ran out); `.last` holds the
    final exception, `.attempts` how many were made."""

    def __init__(self, op: str, attempts: int, last: BaseException):
        super().__init__(f"{op}: {attempts} attempt(s) failed: {last}")
        self.op = op
        self.attempts = attempts
        self.last = last


def call_with_retries(fn: Callable[[], object], policy: RetryPolicy, *,
                      op: str = "op", seed: int = 0, key: int = 0,
                      sleep: Callable[[float], None] = time.sleep,
                      emit: Optional[Callable[..., None]] = None,
                      retry_on: tuple = (Exception,)):
    """Run ``fn`` under ``policy``. Returns ``(value, attempts)`` on
    success; raises `RetryExhausted` once attempts or the deadline are
    spent. ``emit(kind, payload)`` (the trainer's `_emit` signature) gets
    one ``retry`` event per attempt with the outcome and the backoff
    slept; ``sleep`` is injectable so chaos `VirtualClock` runs never
    block. Exceptions outside ``retry_on`` are non-transient and
    propagate immediately, unretried."""
    us = live_jitter_uniforms(policy, seed, key)
    spent = 0.0
    last: Optional[BaseException] = None
    for attempt in range(1, policy.max_attempts + 1):
        try:
            out = fn()
        except retry_on as exc:           # noqa: BLE001 — rethrown below
            last = exc
            give_up = (attempt >= policy.max_attempts
                       or spent >= policy.deadline_s)
            delay = 0.0
            if not give_up:
                delay = min(policy.backoff(attempt, us[attempt - 1]),
                            policy.deadline_s - spent)
            if emit is not None:
                emit("retry", {"op": op, "attempt": attempt,
                               "outcome": "gave_up" if give_up else "fail",
                               "error": type(exc).__name__,
                               "backoff_s": delay})
            if give_up:
                raise RetryExhausted(op, attempt, exc) from exc
            sleep(delay)
            spent += delay
        else:
            if emit is not None:
                emit("retry", {"op": op, "attempt": attempt,
                               "outcome": "ok", "backoff_s": 0.0})
            return out, attempt
    raise RetryExhausted(op, policy.max_attempts, last)  # pragma: no cover
