"""repro.resilience — recovery layer: deterministic retry/backoff,
quorum degradation tiers, and keyed restore-stall draws shared by the
live `TransientTrainer` and the three fleet engines (docs/resilience.md,
DESIGN.md §8)."""
from repro.resilience.policy import (DegradationPolicy, ResilienceConfig,
                                     RetryPolicy, stall_from_uniforms,
                                     stall_pool)
from repro.resilience.runtime import RetryExhausted, call_with_retries

__all__ = [
    "DegradationPolicy", "ResilienceConfig", "RetryPolicy",
    "RetryExhausted", "call_with_retries", "stall_from_uniforms",
    "stall_pool",
]
