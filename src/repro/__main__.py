"""`python -m repro` — the CM-DARE command line, one shell over `repro.api`.

    python -m repro train    --arch qwen3-1.7b --steps 5
    python -m repro serve    --arch mamba2-1.3b --tokens 16
    python -m repro plan     [--arch ...] --gpu v100 --workers 4 [--provider aws]
    python -m repro simulate [--arch ...] --gpu v100 --workers 4 [--provider azure]
    python -m repro predict  [--arch ...] --gpu v100 --workers 4 [--provider gcp]
    python -m repro chaos    --scenario all [--engine batched|event|jit] [--live]
    python -m repro bench    --only table1_speed,fig2_stability
    python -m repro dryrun   --arch qwen3-1.7b --shape train_4k

The old module launchers (`python -m repro.launch.train` etc.) remain as
deprecation shims over the same Session facade.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.launch import cli


def build_parser() -> argparse.ArgumentParser:
    p = cli.make_parser("repro", __doc__.splitlines()[0])
    sub = p.add_subparsers(dest="cmd", required=True)

    t = sub.add_parser("train", help="elastic transient-aware training")
    cli.add_arch_arg(t)
    cli.add_scale_args(t)
    cli.add_batch_args(t)
    cli.add_train_args(t)
    cli.add_resilience_args(t)
    cli.add_recalib_args(t)

    s = sub.add_parser("serve", help="prefill + token-by-token decode, or "
                                     "--fleet SLO-aware serving planning")
    cli.add_arch_arg(s)
    cli.add_scale_args(s)
    cli.add_serve_args(s)
    cli.add_serve_fleet_args(s)
    # resilience flags shape the --fleet plan (drain/handover vs stock)
    cli.add_resilience_args(s)

    for name, hlp in (("plan", "revocation-aware launch planning (§V-C)"),
                      ("simulate", "discrete-event fleet simulation (§VI-A)"),
                      ("predict", "Eq (4)/(5) end-to-end prediction")):
        q = sub.add_parser(name, help=hlp)
        cli.add_arch_arg(q)
        cli.add_scale_args(q)
        cli.add_fleet_args(q)
        if name in ("plan", "simulate"):
            # predict is the Eq (4) closed form: no recovery term
            cli.add_resilience_args(q)
        q.add_argument("--steps", type=int, default=2000)
        q.add_argument("--checkpoint-interval", type=int, default=200)
        # --region defaults to None: `plan` scores every region of the
        # selected provider; simulate/predict fall back to the provider's
        # default region
        if name == "plan":
            q.add_argument("--samples", type=int, default=200,
                           help="Monte-Carlo draws per (region, hour) cell")
            q.add_argument("--score", default="eq4",
                           choices=("eq4", "sim"),
                           help="cell estimator: Eq (4) point estimate "
                                "(default) or a full fleet-simulation "
                                "ensemble per cell with time/cost "
                                "percentiles")
            q.add_argument("--engine", default="batched",
                           choices=("batched", "event", "jit"),
                           help="trajectory stepper for --score sim "
                                "(docs/performance.md)")
            # planning is uncapped unless the user asks for the Fig 4 PS
            # model (--score sim always applies it, with 1 PS by default)
            q.set_defaults(n_ps=None)
        elif name == "simulate":
            q.add_argument("--samples", type=int, default=1,
                           help="trajectories; >1 reports the p50/p90/mean "
                                "ensemble summary (SimStats)")
            q.add_argument("--engine", default="batched",
                           choices=("batched", "event", "jit"),
                           help="ensemble stepper: lockstep array engine "
                                "(default), the per-trajectory event "
                                "loop, or the compiled jit program "
                                "(docs/performance.md)")

    c = sub.add_parser("chaos", help="scripted fault scenarios with "
                                     "ground-truth-scored detection & "
                                     "mitigation (docs/chaos.md)")
    cli.add_arch_arg(c)
    cli.add_scale_args(c)
    c.add_argument("--scenario", default="all",
                   help="registered scenario name, or 'all' (default)")
    c.add_argument("--list", action="store_true",
                   help="list registered scenarios and exit")
    c.add_argument("--engine", default="batched",
                   choices=("batched", "event", "jit"),
                   help="fleet-ensemble stepper (an engine-vs-event "
                        "parity probe runs either way)")
    c.add_argument("--live", action=argparse.BooleanOptionalAction,
                   default=True,
                   help="drive the real trainer through scenarios that "
                        "carry a live plan (--no-live: simulation only)")
    c.add_argument("--samples", type=int, default=32,
                   help="fleet-simulation trajectories per ensemble")
    c.add_argument("--smoke", action="store_true",
                   help="enforce each scenario's expectation gates; "
                        "exit 1 if any fail")
    c.add_argument("--compilation-cache-dir", default="",
                   help="persistent XLA compilation cache for the live "
                        "runs (repeat invocations skip re-jit)")
    # recovery flags arm session.run.resilience, which the chaos runner's
    # simulated fleets AND live trainer runs inherit (docs/resilience.md)
    cli.add_resilience_args(c)
    # --recalibrate arms session.run.recalibration the same way: the live
    # runs drift-detect and refit mid-scenario (docs/calibration.md)
    cli.add_recalib_args(c)

    b = sub.add_parser("bench", help="paper table/figure benchmark driver")
    b.add_argument("--only", default="",
                   help="comma-separated benchmark module subset")
    b.add_argument("--list", action="store_true",
                   help="list available benchmark modules and exit")

    # `dryrun` is dispatched before argparse in main(): its flags are owned
    # by repro.launch.dryrun (or repro.launch.sweep under --sweep), whose
    # import must also happen first (it pins the XLA host-device count).
    # Registered here for `--help` only.
    sub.add_parser("dryrun", help="AOT lower/compile on production meshes "
                                  "(512 host devices); --sweep fans out the "
                                  "full arch x shape matrix with resumable "
                                  "artifacts; flags forwarded to "
                                  "repro.launch.dryrun / .sweep",
                   add_help=False)
    return p


# ----------------------------------------------------------------- handlers
def _cmd_train(args) -> int:
    from repro.core.trainer import MembershipEvent

    session = cli.session_from_args(args)
    if args.mode == "async_ps":
        if args.revoke_at or args.checkpoint_dir:
            raise ValueError("--revoke-at/--checkpoint-dir apply to "
                             "--mode sync only (the async-PS emulation "
                             "has no checkpointing or membership events)")
        rep = session.train(args.steps, global_batch=args.global_batch,
                            seq_len=args.seq, members=args.members,
                            mode="async_ps")
        stale = session.bus.of_kind("staleness")[-1].payload
        curve = (f"loss {rep.losses[0]:.3f}->{rep.losses[-1]:.3f} "
                 if rep.losses else "")
        print(f"arch={args.arch} mode=async_ps updates={rep.steps_run} "
              f"{curve}staleness_hist={stale['hist']}")
        return 0
    events = []
    if args.revoke_at and args.members > 1:
        events.append(MembershipEvent(step=args.revoke_at, kind="revoke",
                                      member_id=args.members - 1))
    rep = session.train(args.steps, global_batch=args.global_batch,
                        seq_len=args.seq, members=args.members,
                        events=events, checkpoint_dir=args.checkpoint_dir)
    compressed = [e.payload for e in session.bus.of_kind("step")
                  if "payload_bytes" in e.payload]
    extra = (f" payload={compressed[-1]['payload_bytes']:.0f}B/"
             f"{compressed[-1]['grad_compression']}" if compressed else "")
    print(f"arch={args.arch} steps={rep.steps_run} "
          f"loss {rep.losses[0]:.3f}->{rep.losses[-1]:.3f} "
          f"speed={rep.speed or 0:.2f} steps/s epochs={rep.epochs} "
          f"checkpoints={rep.checkpoints}{extra}")
    return 0


def _cmd_serve(args) -> int:
    # encoder-only archs raise ValueError in serving.generate; main()
    # renders it as a clean error + exit 2
    session = cli.session_from_args(args)
    if args.fleet:
        from repro.serving import ServingSLO, ServingWorkload
        workload = ServingWorkload(n_requests=args.requests,
                                   arrival_rate_per_s=args.rate,
                                   prompt_tokens=args.prompt_len,
                                   max_tokens=args.tokens)
        best, plans = session.plan_serving(
            replica_counts=tuple(int(x) for x in
                                 args.replica_counts.split(",")),
            providers=tuple(args.providers.split(",")),
            gpu=args.gpu, workload=workload,
            slo=ServingSLO(p99_latency_s=args.slo_p99),
            resilience=cli.resilience_from_args(args),
            samples=args.plan_samples, seed=args.seed)
        print(f"# serving plan: arch={args.arch} gpu={args.gpu} "
              f"slo_p99={args.slo_p99}s requests={args.requests} "
              f"@{args.rate}/s")
        for p in plans:
            mark = "*" if p is best else " "
            print(f"{mark} {p.provider:<7s} {p.region:<16s} "
                  f"x{p.replicas:<3d} slo={'ok ' if p.meets_slo else 'MISS'}"
                  f" p50={p.latency_p50_s:7.3f}s p99={p.latency_p99_s:7.3f}s"
                  f" completed={p.completed_frac:5.1%}"
                  f" shed={p.shed_frac:5.1%} drop={p.drop_frac:5.1%}"
                  f" ${p.cost_per_1k:.4f}/1k")
        return 0
    rep = session.serve(args.tokens, batch=args.batch,
                        prompt_len=args.prompt_len,
                        temperature=args.temperature, seed=args.seed)
    print(f"arch={args.arch} batch={rep.batch} "
          f"prefill {rep.prompt_len} tok in {rep.prefill_seconds:.2f}s; "
          f"decode {rep.tokens_generated} tok in {rep.decode_seconds:.2f}s "
          f"({rep.tokens_per_second:.1f} tok/s)")
    print(f"decode latency per token: p50={rep.decode_ms_p50:.2f}ms "
          f"p95={rep.decode_ms_p95:.2f}ms p99={rep.decode_ms_p99:.2f}ms")
    print("sample tokens:", rep.sample_tokens)
    return 0


def _cmd_plan(args) -> int:
    session = cli.session_from_args(args)
    best, plans = session.plan(gpu=args.gpu, n_workers=args.workers,
                               steps=args.steps,
                               checkpoint_interval=args.checkpoint_interval,
                               region=args.region, seed=args.seed,
                               provider=args.provider, samples=args.samples,
                               score=args.score, engine=args.engine,
                               n_ps=args.n_ps)
    where = args.region or "all regions"
    what = ("simulated trajectories" if args.score == "sim" else "samples")
    print(f"arch={session.arch} provider={args.provider} gpu={args.gpu} "
          f"workers={args.workers} "
          f"({where}): scored {len(plans)} (region, hour) cells "
          f"x {args.samples} {what} [score={args.score}]")
    print(f"best: {best.region} @ {best.launch_hour:02d}h  "
          f"E[revocations]={best.expected_revocations:.2f}"
          f"±{best.revocation_stderr:.2f}  "
          f"E[time]={best.expected_time_s:.0f}s  "
          f"E[cost]=${best.expected_cost:.2f}")
    if args.score == "sim":
        print(f"      time p50={best.time_p50_s:.0f}s "
              f"p90={best.time_p90_s:.0f}s  "
              f"cost p50=${best.cost_p50:.2f} p90=${best.cost_p90:.2f}  "
              f"finished={best.finished}/{best.samples}")
    return 0


def _cmd_simulate(args) -> int:
    session = cli.session_from_args(args)
    res = session.simulate(n_workers=args.workers, gpu=args.gpu,
                           region=args.region, steps=args.steps,
                           checkpoint_interval=args.checkpoint_interval,
                           n_ps=args.n_ps, seed=args.seed,
                           provider=args.provider, samples=args.samples,
                           engine=args.engine)
    if args.samples > 1:
        st = res.stats
        print(f"arch={session.arch} {args.workers}x{args.gpu} on "
              f"{res.provider}/{res.region}: {st.n} trajectories")
        if st.finished < st.n:
            print(f"WARNING: only {st.finished}/{st.n} trajectories "
                  f"finished all {args.steps} steps (censored at "
                  f"max_hours or fully revoked) — the time/cost summary "
                  f"understates the true distribution")
        print(f"time  p50={st.time_p50_s:.0f}s p90={st.time_p90_s:.0f}s "
              f"mean={st.time_mean_s:.0f}±{st.time_stderr_s:.0f}s")
        print(f"cost  p50=${st.cost_p50:.2f} p90=${st.cost_p90:.2f} "
              f"mean=${st.cost_mean:.2f}±{st.cost_stderr:.2f}")
        print(f"revocations p50={st.revocations_p50:.1f} "
              f"p90={st.revocations_p90:.1f} "
              f"mean={st.revocations_mean:.2f}")
        return 0
    print(f"arch={session.arch} {args.workers}x{args.gpu} on "
          f"{res.provider}/{res.region}: "
          f"{res.steps_done} steps in {res.total_time_s:.0f}s  "
          f"revocations={res.revocations} replacements={res.replacements} "
          f"ckpt={res.checkpoint_time_s:.0f}s cost=${res.monetary_cost:.2f}")
    return 0


def _cmd_predict(args) -> int:
    session = cli.session_from_args(args)
    rep = session.predict(n_workers=args.workers, gpu=args.gpu,
                          region=args.region, steps=args.steps,
                          checkpoint_interval=args.checkpoint_interval,
                          n_ps=args.n_ps, seed=args.seed,
                          provider=args.provider)
    print(f"arch={rep.arch} {rep.n_workers}x{rep.gpu} on "
          f"{rep.provider}/{rep.region}: "
          f"worker {rep.worker_speed:.2f} steps/s, cluster "
          f"{rep.cluster_speed:.2f} steps/s"
          f"{' (PS-bottlenecked)' if rep.ps_bottlenecked else ''}")
    print(f"Eq(4): {rep.total_time_seconds:.0f}s for {args.steps} steps  "
          f"(T_c={rep.checkpoint_seconds:.2f}s, "
          f"E[revocations]={rep.expected_revocations:.2f})")
    return 0


def _cmd_chaos(args) -> int:
    import json

    from repro.chaos import list_scenarios
    from repro.chaos.runner import run_scenarios

    if args.list:
        print("\n".join(list_scenarios()))
        return 0
    session = cli.session_from_args(args)
    card = run_scenarios(args.scenario, session=session, engine=args.engine,
                         live=args.live, samples=args.samples,
                         seed=args.seed, smoke=args.smoke,
                         progress=lambda m: print(m, file=sys.stderr))
    print(json.dumps(card, indent=2, sort_keys=True))
    if args.smoke and not card["passed"]:
        fails = {name: c["smoke"]["failures"]
                 for name, c in card["scenarios"].items()
                 if not c["smoke"]["passed"]}
        print(f"chaos smoke gates FAILED: {fails}", file=sys.stderr)
        return 1
    return 0


def _cmd_bench(args) -> int:
    try:
        from benchmarks import run as bench_run
    except ImportError as e:
        print("benchmarks package not importable — run from the repo root "
              f"({e})", file=sys.stderr)
        return 2
    if args.list:
        print("\n".join(bench_run.MODULES))
        return 0
    return bench_run.main(["--only", args.only] if args.only else [])


def _cmd_dryrun(rest: List[str]) -> int:
    if "--sweep" in rest:
        # the sweep driver never imports jax itself (each cell runs in a
        # subprocess), so it must not pull in repro.launch.dryrun here
        from repro.launch import sweep
        return sweep.main([a for a in rest if a != "--sweep"])
    from repro.launch import dryrun
    dryrun.main(rest)
    return 0


_HANDLERS = {
    "train": _cmd_train, "serve": _cmd_serve, "plan": _cmd_plan,
    "simulate": _cmd_simulate, "predict": _cmd_predict,
    "chaos": _cmd_chaos, "bench": _cmd_bench,
}


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv[:1] == ["dryrun"]:
        return _cmd_dryrun(argv[1:])
    args = build_parser().parse_args(argv)
    try:
        return _HANDLERS[args.cmd](args)
    except ValueError as e:
        # domain validation (e.g. a (region, gpu) cell the selected
        # provider never sold) — report cleanly, not as a traceback.
        # Unknown provider/arch never reach here: argparse `choices`
        # rejects them first, and internal KeyErrors stay loud.
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
