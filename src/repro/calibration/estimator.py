"""The `Estimator` protocol — one calibration contract for every model.

The prediction stack grew organically: §III step-time generators, the
Table II regression zoo, §IV checkpoint-time predictors, the Fig 4 PS
capacity law and the §V lifetime laws each had their own fit/predict
spelling. `docs/calibration.md` unifies them behind five methods so the
`ModelStore`, the `Recalibrator` and the transfer path can treat any of
them as "an estimator":

  fit(...)          (re)build the estimator from measurement rows
  predict(x)        point prediction for one input
  update(rows)      online refresh from new observations -> NEW estimator
                    (estimators are value objects; update never mutates)
  score(rows)       goodness-of-fit dict ({"mae", "mape", "n", ...})
  params_hash()     stable digest of the fitted parameters — equality of
                    hashes IS equality of calibrations, which is how the
                    golden-parity tests pin the unarmed path

Adopters: `GPUStepTimeModel` / `WorkerSpeedPredictor` (§III),
`CheckpointTimePredictor` (§IV), `PSBottleneckModel` (Fig 4 capacity),
`LifetimeModel` and the provider `LifetimeLaw`s (§V), plus the online
`ClusterSpeedEstimator` below that the drift/refit loop fits from
profiler history.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterable, Optional, Protocol, runtime_checkable

import numpy as np


def params_hash(*parts) -> str:
    """Stable sha1 digest of fitted parameters (floats, strings, arrays).

    Floats are hashed via their IEEE bytes at full precision, so two
    estimators hash equal iff their parameters are bit-identical — the
    property the unarmed-mode golden tests rely on.
    """
    h = hashlib.sha1()
    for p in parts:
        if p is None:
            h.update(b"\x00none")
        elif isinstance(p, str):
            h.update(b"\x01" + p.encode())
        elif isinstance(p, (int, np.integer)):
            h.update(b"\x02" + int(p).to_bytes(8, "little", signed=True))
        else:
            arr = np.ascontiguousarray(np.asarray(p, float))
            h.update(b"\x03" + arr.tobytes())
    return h.hexdigest()


@runtime_checkable
class Estimator(Protocol):
    """Structural protocol — adopters need the methods, not a base class."""

    def predict(self, x): ...

    def update(self, rows) -> "Estimator": ...

    def score(self, rows) -> Dict[str, float]: ...

    def params_hash(self) -> str: ...


def score_predictions(y_true, y_pred) -> Dict[str, float]:
    """The shared `score()` body: MAE/MAPE over paired observations,
    with the empty-input guard every adopter needs (an estimator scored
    against nothing is a caller bug, not a 0.0)."""
    y_true = np.asarray(y_true, float)
    y_pred = np.asarray(y_pred, float)
    if y_true.size == 0:
        raise ValueError("score: no observations to score against")
    err = np.abs(y_true - y_pred)
    denom = np.maximum(np.abs(y_true), 1e-12)
    return {"n": int(y_true.size),
            "mae": float(err.mean()),
            "mape": float((err / denom).mean()) * 100.0}


@dataclasses.dataclass(frozen=True)
class ClusterSpeedEstimator:
    """Online cluster-speed estimator the `Recalibrator` refits from
    profiler history (docs/calibration.md §drift).

    The "model" is the paper's measured quantity itself — steps/s over a
    record window — which is exactly what `Controller.check` compares
    the live measurement against. `fit` consumes profiler records
    (dicts with `t`/`step`, the `PerformanceProfiler.history()` export).
    """
    speed: float
    n_obs: int = 0
    source: str = "static"       # static | refit | transfer

    @classmethod
    def fit(cls, records: Iterable[Dict[str, float]],
            source: str = "refit") -> "ClusterSpeedEstimator":
        rs = list(records)
        if len(rs) < 2:
            raise ValueError("ClusterSpeedEstimator.fit: need >= 2 records")
        span = rs[-1]["t"] - rs[0]["t"]
        if span <= 0:
            raise ValueError("ClusterSpeedEstimator.fit: zero time span")
        sp = (rs[-1]["step"] - rs[0]["step"]) / span
        return cls(speed=float(sp), n_obs=len(rs), source=source)

    def predict(self, x=None) -> float:
        return self.speed

    def update(self, records) -> "ClusterSpeedEstimator":
        return type(self).fit(records, source="refit")

    def score(self, records) -> Dict[str, float]:
        rs = list(records)
        speeds = []
        for a, b in zip(rs, rs[1:]):
            dt = b["t"] - a["t"]
            if dt > 0:
                speeds.append((b["step"] - a["step"]) / dt)
        return score_predictions(speeds, [self.speed] * len(speeds))

    def params_hash(self) -> str:
        return params_hash("cluster_speed", self.speed, self.n_obs,
                           self.source)
