"""Cross-cell transfer: predict unmeasured (gpu, region) calibrations
from measured ones (PROFET / Habitat style; docs/calibration.md §transfer).

Step time. Habitat's observation: for compute-bound CNN training, step
time scales roughly inversely with peak throughput across GPUs of the
same family. Each measured GPU therefore yields a candidate curve for the
target (`t_target ≈ t_source * tf_source / tf_target`), and we combine
candidates with a geometric mean — multiplicative errors, log-space
average. Validated against Table I itself: predicting the p100 from the
k80 + v100 curves lands within ~6 % MAPE of the published p100 numbers.

Lifetime. Table V's revocation matrix is incomplete (two cells were never
offered). An additive log-odds decomposition
`logit(p24) ≈ mu + a[region] + b[gpu]`, least-squares fit over the
observed cells, fills the holes: region effects (us-west1 is calm,
europe-west1 is brutal) and GPU effects (v100 demand) separate cleanly.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Optional, Tuple

import numpy as np


def _teraflops(gpu: str) -> float:
    from repro.core.perf_model.features import GPU_SPECS
    if gpu not in GPU_SPECS:
        raise KeyError(f"unknown gpu {gpu!r}; known: {sorted(GPU_SPECS)}")
    return GPU_SPECS[gpu].teraflops


# ------------------------------------------------------------- step time
def transfer_step_time_model(target_gpu: str,
                             sources: Optional[Dict[str, object]] = None,
                             target_teraflops: Optional[float] = None):
    """Predict a `GPUStepTimeModel` for `target_gpu` from measured ones.

    `sources` defaults to every calibrated generator except the target
    (hold-one-out); `target_teraflops` overrides the spec sheet for GPUs
    not in `GPU_SPECS`. The returned model interpolates exactly like a
    calibrated one — downstream consumers cannot tell it apart.
    """
    from repro.core.perf_model.speed_model import (GPUStepTimeModel,
                                                   calibrate_generators)

    if sources is None:
        sources = {g: m for g, m in calibrate_generators().items()
                   if g != target_gpu}
    if not sources:
        raise ValueError("transfer_step_time_model: no source models")
    tf_t = (float(target_teraflops) if target_teraflops is not None
            else _teraflops(target_gpu))
    if tf_t <= 0:
        raise ValueError("target teraflops must be positive")

    first = next(iter(sources.values()))
    c_anchors = np.asarray(first.c_anchors, float)
    log_t = np.zeros_like(c_anchors)
    for gpu, model in sources.items():
        tf_s = _teraflops(gpu)
        for i, c in enumerate(c_anchors):
            log_t[i] += math.log(model.step_time(float(c)) * tf_s / tf_t)
    t_anchors = np.exp(log_t / len(sources))
    return GPUStepTimeModel(target_gpu, c_anchors.copy(), t_anchors)


# -------------------------------------------------------------- lifetime
def _logit(p: float) -> float:
    p = min(max(p, 1e-4), 1.0 - 1e-4)
    return math.log(p / (1.0 - p))


def fit_p24_effects(rates: Optional[Dict[Tuple[str, str], Optional[float]]]
                    = None) -> Dict[str, Dict[str, float]]:
    """Least-squares additive log-odds decomposition of the Table V
    revocation matrix. Returns `{"mu": ..., "region": {...}, "gpu": {...}}`
    with sum-to-zero effect coding (so `mu` is the grand mean log-odds)."""
    if rates is None:
        from repro.core.transient.revocation import TABLE5_RATES
        rates = TABLE5_RATES
    cells = [(r, g, p) for (r, g), p in sorted(rates.items())
             if p is not None]
    if len(cells) < 3:
        raise ValueError("fit_p24_effects: need >= 3 observed cells")
    regions = sorted({r for r, _, _ in cells})
    gpus = sorted({g for _, g, _ in cells})
    # Columns: [mu, a_region (all but last), b_gpu (all but last)];
    # the dropped levels are recovered from the sum-to-zero constraint.
    n_r, n_g = len(regions) - 1, len(gpus) - 1
    X = np.zeros((len(cells), 1 + n_r + n_g))
    y = np.zeros(len(cells))
    for i, (r, g, p) in enumerate(cells):
        X[i, 0] = 1.0
        ri, gi = regions.index(r), gpus.index(g)
        if ri < n_r:
            X[i, 1 + ri] = 1.0
        else:
            X[i, 1:1 + n_r] = -1.0
        if gi < n_g:
            X[i, 1 + n_r + gi] = 1.0
        else:
            X[i, 1 + n_r:] = -1.0
        y[i] = _logit(p)
    beta, *_ = np.linalg.lstsq(X, y, rcond=None)
    a = {r: float(beta[1 + i]) for i, r in enumerate(regions[:-1])}
    a[regions[-1]] = -float(beta[1:1 + n_r].sum())
    b = {g: float(beta[1 + n_r + i]) for i, g in enumerate(gpus[:-1])}
    b[gpus[-1]] = -float(beta[1 + n_r:].sum())
    return {"mu": float(beta[0]), "region": a, "gpu": b}


def transfer_p24(region: str, gpu: str,
                 effects: Optional[Dict[str, Dict[str, float]]] = None
                 ) -> float:
    """Predicted 24h revocation probability for an unmeasured cell."""
    eff = effects or fit_p24_effects()
    if region not in eff["region"]:
        raise KeyError(f"region {region!r} never observed; "
                       f"known: {sorted(eff['region'])}")
    if gpu not in eff["gpu"]:
        raise KeyError(f"gpu {gpu!r} never observed; "
                       f"known: {sorted(eff['gpu'])}")
    z = eff["mu"] + eff["region"][region] + eff["gpu"][gpu]
    return 1.0 / (1.0 + math.exp(-z))


def transfer_lifetime_model(region: str, gpu: str,
                            effects: Optional[Dict[str, Dict[str, float]]]
                            = None):
    """A `LifetimeModel` for a cell Table V never measured: p24 from the
    log-odds decomposition, shape/scale from the cell's Fig 8 hint when
    one exists, else the global default."""
    from repro.core.transient.revocation import _SHAPE_HINTS, LifetimeModel

    p24 = transfer_p24(region, gpu, effects)
    k, mean_hint = _SHAPE_HINTS.get((region, gpu), (1.2, 12.0))
    lam = mean_hint / math.gamma(1.0 + 1.0 / k)
    return LifetimeModel(region, gpu, k, lam, p24)


def holdout_p24_report(rates: Optional[Dict[Tuple[str, str],
                                            Optional[float]]] = None
                       ) -> Iterable[Dict[str, float]]:
    """Leave-one-out check over the observed Table V cells: refit the
    effects without each cell, predict it, report the error. The
    calibration tests gate on this report's MAE."""
    if rates is None:
        from repro.core.transient.revocation import TABLE5_RATES
        rates = TABLE5_RATES
    observed = {k: v for k, v in rates.items() if v is not None}
    rows = []
    for (r, g), actual in sorted(observed.items()):
        rest = dict(observed)
        rest.pop((r, g))
        try:
            eff = fit_p24_effects(rest)
            pred = transfer_p24(r, g, eff)
        except (KeyError, ValueError):
            continue  # cell's region or gpu unseen without it
        rows.append({"region": r, "gpu": g, "actual": actual,
                     "predicted": pred, "abs_err": abs(pred - actual)})
    return rows
