"""`ModelStore` — one versioned registry for every calibrated estimator.

Before this layer, each consumer reached for its own module-level global:
`calibrate_generators()`'s memo for §III step times, `REGION_GPU_PARAMS`
for §V lifetimes, an ad-hoc `PSBottleneckModel` per call site. The store
replaces those *handles* (not the calibrations — the same memoized
instances seed it, so the unarmed path stays bit-identical) with:

  register(name, est)   file an estimator under a name, version 1
  current(name)         the live estimator
  update(name, est)     new version; the old one is kept as a snapshot
  version(name)         monotonically increasing int — what the
                        Controller stamps into each Detection
  rollback(name[, v])   reinstate an older snapshot (itself a new
                        version, so the audit trail stays append-only)
  snapshots(name)       [(version, params_hash)] audit trail

Naming convention (docs/calibration.md): `step_time/<gpu>`,
`cluster_speed`, `checkpoint_time`, `ps_capacity`,
`lifetime/<provider>/<region>/<gpu>`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Snapshot:
    version: int
    estimator: object
    params_hash: str
    note: str = ""


class ModelStore:
    def __init__(self) -> None:
        self._snaps: Dict[str, List[Snapshot]] = {}

    # ------------------------------------------------------------ registry
    def register(self, name: str, estimator: object,
                 note: str = "calibrated") -> int:
        """File `estimator` under `name` (version 1). Re-registering an
        existing name is an error — use `update` for new versions."""
        if name in self._snaps:
            raise ValueError(f"model {name!r} already registered; "
                             "use update() for a new version")
        self._snaps[name] = [Snapshot(1, estimator,
                                      self._hash_of(estimator), note)]
        return 1

    def update(self, name: str, estimator: object,
               note: str = "refit") -> int:
        """File a new version of `name`; returns the new version number."""
        snaps = self._require(name)
        v = snaps[-1].version + 1
        snaps.append(Snapshot(v, estimator, self._hash_of(estimator), note))
        return v

    def rollback(self, name: str, version: Optional[int] = None) -> int:
        """Reinstate snapshot `version` (default: the one before current)
        as a NEW version, keeping the trail append-only."""
        snaps = self._require(name)
        if version is None:
            if len(snaps) < 2:
                raise ValueError(f"model {name!r} has no prior version "
                                 "to roll back to")
            target = snaps[-2]
        else:
            match = [s for s in snaps if s.version == version]
            if not match:
                raise ValueError(f"model {name!r} has no version {version}; "
                                 f"known: {[s.version for s in snaps]}")
            target = match[0]
        return self.update(name, target.estimator,
                           note=f"rollback->v{target.version}")

    # ------------------------------------------------------------- lookup
    def __contains__(self, name: str) -> bool:
        return name in self._snaps

    def names(self) -> List[str]:
        return sorted(self._snaps)

    def current(self, name: str) -> object:
        return self._require(name)[-1].estimator

    def get(self, name: str, default: object = None) -> object:
        snaps = self._snaps.get(name)
        return snaps[-1].estimator if snaps else default

    def version(self, name: str) -> int:
        return self._require(name)[-1].version

    def snapshots(self, name: str) -> List[Tuple[int, str]]:
        return [(s.version, s.params_hash) for s in self._require(name)]

    def at_version(self, name: str, version: int) -> object:
        for s in self._require(name):
            if s.version == version:
                return s.estimator
        raise ValueError(f"model {name!r} has no version {version}")

    # ------------------------------------------------------------ helpers
    def _require(self, name: str) -> List[Snapshot]:
        if name not in self._snaps:
            raise KeyError(f"unknown model {name!r}; "
                           f"registered: {self.names()}")
        return self._snaps[name]

    @staticmethod
    def _hash_of(estimator: object) -> str:
        fn = getattr(estimator, "params_hash", None)
        return fn() if callable(fn) else f"<unhashed:{type(estimator).__name__}>"

    # -------------------------------------------------------- construction
    @classmethod
    def with_static_calibrations(cls) -> "ModelStore":
        """Seed a store with the paper's static calibrations — the exact
        memoized `calibrate_generators()` instances, so resolving through
        the store is bit-identical to the module-global path."""
        from repro.core.perf_model.speed_model import calibrate_generators

        store = cls()
        for gpu, gen in calibrate_generators().items():
            store.register(f"step_time/{gpu}", gen)
        return store
