"""Online recalibration loop (paper §IV-C: monitor deployed clusters and
retrain the predictors from live measurements).

The `Recalibrator` sits beside the `Controller` in the training loop:

    controller.check ──deviation──▶ CusumDetector ──alarm──▶ refit
                                                      │
                              model_drift event       │  model_refit event
                                                      ▼
          profiler.history() ──fit──▶ ClusterSpeedEstimator ──▶ ModelStore
                                                      │
                        trainer.predicted_speed ◀─────┘ (new version)

Division of labour with the controller: the controller owns *mitigation*
(the cluster is wrong — add a PS, compress, replace the straggler); the
recalibrator owns *model drift* (the cluster is fine, the prediction is
stale). A mitigation resets the CUSUM statistic instead of feeding it —
refitting right after a mitigation would bake the degraded speed into the
model and mask the bottleneck the controller just fixed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from .drift import CusumDetector
from .estimator import ClusterSpeedEstimator
from .store import ModelStore

MODEL_NAME = "cluster_speed"


@dataclasses.dataclass(frozen=True)
class RecalibrationConfig:
    """Knobs for the drift/refit loop (CLI: `--recalibrate`, `--drift-*`)."""
    drift_threshold: float = 0.15   # CUSUM alarm level
    drift_allowance: float = 0.05   # per-check slack before accumulating
    refit_window: int = 6           # profiler records the refit consumes
    min_history: int = 3            # need this many records to refit
    cooldown_checks: int = 1        # checks to skip right after a refit
    trace_path: Optional[str] = None  # optional recorded provider trace


class Recalibrator:
    """Consumes controller detections + profiler history; maintains the
    `cluster_speed` estimator in a `ModelStore` and a refit ledger."""

    def __init__(self, config: Optional[RecalibrationConfig] = None,
                 store: Optional[ModelStore] = None,
                 emit: Optional[Callable[[str, dict], None]] = None) -> None:
        self.config = config or RecalibrationConfig()
        self.store = store if store is not None else ModelStore()
        self._emit = emit
        self.detector = CusumDetector(allowance=self.config.drift_allowance,
                                      threshold=self.config.drift_threshold)
        self.drift_events: List[Dict] = []
        self.refits: List[Dict] = []
        self._cooldown = 0

    # --------------------------------------------------------------- wiring
    def bind(self, emit: Callable[[str, dict], None]) -> None:
        """Late-bind the event sink (the trainer's `_emit`)."""
        self._emit = emit

    def seed(self, predicted_speed: float) -> None:
        """Record the static prediction as version 1, so the first refit
        becomes version 2 and the audit trail starts at the baseline."""
        if MODEL_NAME not in self.store:
            self.store.register(
                MODEL_NAME,
                ClusterSpeedEstimator(speed=float(predicted_speed),
                                      source="static"),
                note="static")

    @property
    def version(self) -> int:
        return self.store.version(MODEL_NAME) if MODEL_NAME in self.store else 0

    # ----------------------------------------------------------------- loop
    def observe(self, step: int, deviation: Optional[float],
                profiler) -> Optional[float]:
        """Feed one controller check. Returns the refit predicted speed
        when drift was confirmed and a refit succeeded, else None."""
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        if not self.detector.observe(deviation):
            return None

        drift = {"step": int(step), "deviation": float(deviation),
                 "model_version": self.version}
        self.drift_events.append(drift)
        self._fire("model_drift", drift)

        history = profiler.history()[-self.config.refit_window:]
        if len(history) < max(self.config.min_history, 2):
            return None
        try:
            est = ClusterSpeedEstimator.fit(history, source="refit")
        except ValueError:
            return None

        self.seed(est.speed)  # no-op if already seeded
        old = self.store.current(MODEL_NAME)
        version = (self.store.update(MODEL_NAME, est)
                   if self.store.snapshots(MODEL_NAME)[-1][1] != est.params_hash()
                   else self.store.version(MODEL_NAME))
        refit = {"step": int(step), "model_version": version,
                 "old_speed": float(getattr(old, "speed", est.speed)),
                 "new_speed": est.speed, "n_obs": est.n_obs}
        self.refits.append(refit)
        self._fire("model_refit", refit)
        self._cooldown = self.config.cooldown_checks
        return est.speed

    def notify_mitigation(self, step: int) -> None:
        """The controller changed the cluster; deviation accumulated
        against the pre-mitigation prediction is void."""
        self.detector.reset()
        self._cooldown = max(self._cooldown, self.config.cooldown_checks)

    # ---------------------------------------------------------------- traces
    def ingest_trace(self, path: Optional[str] = None) -> List[str]:
        """Refit lifetime laws from a recorded eviction trace; returns the
        store names written (`lifetime/trace/<region>/<gpu>`)."""
        from repro.core.transient.revocation import LifetimeModel

        from .traces import lifetimes_from_trace, load_trace

        p = path or self.config.trace_path
        if not p:
            return []
        events = load_trace(p)
        cells = sorted({(e.region, e.gpu) for e in events
                        if e.kind == "eviction"},
                       key=lambda c: (c[0] or "", c[1] or ""))
        written = []
        for region, gpu in cells:
            lifetimes = lifetimes_from_trace(events, region=region, gpu=gpu)
            if lifetimes.size < 3:
                continue
            est = LifetimeModel.fit(region or "trace", gpu or "any", lifetimes)
            name = f"lifetime/trace/{region or 'any'}/{gpu or 'any'}"
            if name in self.store:
                self.store.update(name, est, note="trace-refit")
            else:
                self.store.register(name, est, note="trace")
            written.append(name)
        return written

    # --------------------------------------------------------------- helpers
    def _fire(self, kind: str, payload: dict) -> None:
        if self._emit is not None:
            self._emit(kind, payload)
