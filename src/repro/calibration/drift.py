"""CUSUM drift detection over controller deviations (docs/calibration.md).

One `Controller.check` deviation above the 6.7 % threshold can be noise or
a transient; a *persistent* shift is what should trigger a refit. The
detector accumulates the excess deviation above an `allowance` per check
(the classic one-sided CUSUM statistic):

    s <- max(0, s + (deviation - allowance))

and alarms when `s` crosses `threshold`. Mitigations reset the statistic
— the §VI-B levers (compression / extra PS) change the cluster itself, so
deviation accumulated against the pre-mitigation prediction is void, and
a refit right after a mitigation would bake the degraded speed into the
model and mask the bottleneck the controller just fixed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class CusumDetector:
    """One-sided CUSUM on prediction deviation (fractional, signed:
    positive = measured slower than predicted)."""
    allowance: float = 0.05      # per-check slack before accumulating
    threshold: float = 0.15      # alarm level for the cumulative excess
    two_sided: bool = False      # also alarm on measured >> predicted

    def __post_init__(self) -> None:
        self.s_pos = 0.0
        self.s_neg = 0.0
        self.alarms: List[dict] = []

    def observe(self, deviation: Optional[float]) -> bool:
        """Feed one check's deviation; True when drift is confirmed.
        A confirming observation resets the statistic (the refit that
        follows re-baselines the model)."""
        if deviation is None:
            return False
        d = float(deviation)
        self.s_pos = max(0.0, self.s_pos + (d - self.allowance))
        self.s_neg = max(0.0, self.s_neg + (-d - self.allowance))
        fired = self.s_pos >= self.threshold or (
            self.two_sided and self.s_neg >= self.threshold)
        if fired:
            self.alarms.append({"deviation": d, "s_pos": self.s_pos,
                                "s_neg": self.s_neg})
            self.reset()
        return fired

    def reset(self) -> None:
        self.s_pos = 0.0
        self.s_neg = 0.0

    @property
    def statistic(self) -> float:
        return self.s_pos
