"""`repro.calibration` — the unified calibration layer (docs/calibration.md).

One `Estimator` protocol over every predictor in the repo, a versioned
`ModelStore` replacing module-level calibration globals, CUSUM drift
detection + online refit (`Recalibrator`), recorded-trace ingestion, and
PROFET/Habitat-style transfer to unmeasured (gpu, region) cells.
"""
from .drift import CusumDetector
from .estimator import (ClusterSpeedEstimator, Estimator, params_hash,
                        score_predictions)
from .recalibrator import RecalibrationConfig, Recalibrator
from .store import ModelStore, Snapshot
from .traces import (TraceEvent, eviction_hazard_windows,
                     lifetimes_from_trace, load_trace, parse_trace,
                     price_hazard_windows)
from .transfer import (fit_p24_effects, holdout_p24_report,
                       transfer_lifetime_model, transfer_p24,
                       transfer_step_time_model)

__all__ = [
    "ClusterSpeedEstimator", "CusumDetector", "Estimator", "ModelStore",
    "RecalibrationConfig", "Recalibrator", "Snapshot", "TraceEvent",
    "eviction_hazard_windows", "fit_p24_effects", "holdout_p24_report",
    "lifetimes_from_trace", "load_trace", "params_hash", "parse_trace",
    "price_hazard_windows", "score_predictions", "transfer_lifetime_model",
    "transfer_p24", "transfer_step_time_model",
]
