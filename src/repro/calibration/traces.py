"""Recorded provider-trace ingestion (docs/calibration.md §traces).

A *trace file* is a recorded market history — eviction timestamps and/or
spot-price samples for one (provider, region, gpu) cell — in JSON Lines
(one object per line) or a single JSON array. Recognized records:

  {"kind": "eviction", "t_h": 3.2, "lifetime_h": 3.2,
   "region": "us-central1", "gpu": "v100"}          # censored: true when
                                                    # the server survived
  {"kind": "price", "t_h": 0.0, "price": 0.11,
   "region": "us-east-1", "gpu": "v100"}

Two consumers share this parser:

* the `Recalibrator` refits lifetime laws from the observed (censored)
  lifetimes (`lifetimes_from_trace`);
* the chaos `TraceInjector` replays the same file as a `FaultTimeline`
  (hazard windows from eviction clusters and price excursions), so a
  recorded bad afternoon becomes a reproducible scenario.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One record of a provider trace (hours relative to trace start)."""
    t_h: float
    kind: str                         # "eviction" | "price"
    region: Optional[str] = None
    gpu: Optional[str] = None
    lifetime_h: Optional[float] = None
    censored: bool = False            # eviction records: survived horizon
    price: Optional[float] = None

    @classmethod
    def from_record(cls, rec: Mapping) -> "TraceEvent":
        kind = rec.get("kind")
        if kind not in ("eviction", "price"):
            raise ValueError(f"trace record kind {kind!r} not one of "
                             "('eviction', 'price'): {rec!r}"
                             .format(rec=rec))
        if "t_h" not in rec:
            raise ValueError(f"trace record missing 't_h': {rec!r}")
        return cls(t_h=float(rec["t_h"]), kind=kind,
                   region=rec.get("region"), gpu=rec.get("gpu"),
                   lifetime_h=(None if rec.get("lifetime_h") is None
                               else float(rec["lifetime_h"])),
                   censored=bool(rec.get("censored", False)),
                   price=(None if rec.get("price") is None
                          else float(rec["price"])))


def parse_trace(text: str) -> List[TraceEvent]:
    """Parse trace text: a JSON array, or JSON Lines (blank lines and
    `#` comment lines allowed). Events come back sorted by time."""
    stripped = text.lstrip()
    if stripped.startswith("["):
        records = json.loads(text)
    else:
        records = []
        for i, line in enumerate(text.splitlines(), 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"trace line {i} is not JSON: {e}") from e
    events = [TraceEvent.from_record(r) for r in records]
    return sorted(events, key=lambda e: e.t_h)


def load_trace(path: str) -> List[TraceEvent]:
    with open(path) as f:
        return parse_trace(f.read())


def lifetimes_from_trace(events: Sequence[TraceEvent],
                         region: Optional[str] = None,
                         gpu: Optional[str] = None) -> np.ndarray:
    """Observed lifetimes (hours) from the eviction records, optionally
    filtered to one (region, gpu). Censored records (survived the
    recording horizon) come back as np.inf — the same convention the
    `LifetimeLaw` samplers use, so `LifetimeModel.fit` consumes the
    array directly."""
    out = []
    for e in events:
        if e.kind != "eviction":
            continue
        if region is not None and e.region is not None and e.region != region:
            continue
        if gpu is not None and e.gpu is not None and e.gpu != gpu:
            continue
        if e.censored:
            out.append(np.inf)
        else:
            out.append(e.lifetime_h if e.lifetime_h is not None else e.t_h)
    return np.asarray(out, float)


def eviction_hazard_windows(events: Sequence[TraceEvent], n_workers: int,
                            bucket_h: float = 1.0
                            ) -> List[Tuple[float, float, float, Optional[str]]]:
    """Bucket eviction timestamps into `(start_h, end_h, hazard_per_h,
    region)` windows: the empirical hazard is the eviction count per
    bucket divided by the exposed fleet-hours (`n_workers * bucket_h`) —
    the rate a `PreemptionWave` reproduces in expectation."""
    if bucket_h <= 0:
        raise ValueError("bucket_h must be positive")
    by_bucket: Dict[Tuple[int, Optional[str]], int] = {}
    for e in events:
        if e.kind != "eviction" or e.censored:
            continue
        key = (int(e.t_h // bucket_h), e.region)
        by_bucket[key] = by_bucket.get(key, 0) + 1
    out = []
    for (b, region), count in sorted(by_bucket.items(),
                                     key=lambda kv: (kv[0][0],
                                                     kv[0][1] or "")):
        hazard = count / (max(n_workers, 1) * bucket_h)
        out.append((b * bucket_h, (b + 1) * bucket_h, hazard, region))
    return out


def price_hazard_windows(events: Sequence[TraceEvent], bid: float,
                         hazard_per_excess: float = 2.0
                         ) -> List[Tuple[float, float, float]]:
    """Contiguous spans where the recorded price meets/exceeds `bid`,
    as `(start_h, end_h, hazard_per_h)` windows. The hazard scales with
    the mean fractional excess over the bid (`hazard_per_excess` per
    unit of excess) — a price pinned 50 % over the bid revokes harder
    than one grazing it."""
    if bid <= 0:
        raise ValueError("bid must be positive")
    prices = [e for e in events if e.kind == "price" and e.price is not None]
    out: List[Tuple[float, float, float]] = []
    span_start: Optional[float] = None
    excesses: List[float] = []
    last_t: Optional[float] = None
    for e in prices:
        over = e.price >= bid
        if over and span_start is None:
            span_start = e.t_h
            excesses = []
        if over:
            excesses.append((e.price - bid) / bid)
        if not over and span_start is not None:
            out.append((span_start, e.t_h,
                        hazard_per_excess * float(np.mean(excesses))))
            span_start = None
        last_t = e.t_h
    if span_start is not None and last_t is not None and last_t > span_start:
        out.append((span_start, last_t,
                    hazard_per_excess * float(np.mean(excesses))))
    return [(a, b, h) for a, b, h in out if h > 0]
