"""Tiny synchronous event bus wiring the Session facade to the runtime.

The trainer (and any future provider/backend) emits flat `(kind, payload)`
events; the Session forwards them onto a bus so callers can observe a run
without threading callbacks through every layer. Kinds emitted today:

  step        {step, loss}
  epoch       {step, kind, member_id, epoch, n_alive}
  checkpoint  {step, sizes}
  detection   {step, bottleneck, action, deviation}
  restore     {step}

Subscribe to a specific kind or to "*" for everything. Handlers run inline
on the training thread — keep them cheap.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Dict, List, Tuple

Handler = Callable[[str, Dict[str, Any]], None]


@dataclasses.dataclass
class Event:
    kind: str
    payload: Dict[str, Any]


class EventBus:
    def __init__(self, keep_history: int = 10_000):
        self._subs: Dict[str, List[Handler]] = defaultdict(list)
        self._keep = keep_history
        self.history: List[Event] = []

    def subscribe(self, kind: str, handler: Handler) -> Handler:
        """Register `handler` for `kind` ("*" = all). Returns the handler so
        this can be used as a decorator via `bus.on(kind)`."""
        self._subs[kind].append(handler)
        return handler

    def on(self, kind: str) -> Callable[[Handler], Handler]:
        return lambda fn: self.subscribe(kind, fn)

    def emit(self, kind: str, /, **payload: Any) -> None:
        # `kind` is positional-only so payloads may themselves carry a
        # "kind" key (e.g. the trainer's epoch events)
        if self._keep:
            self.history.append(Event(kind, payload))
            if len(self.history) > self._keep:
                del self.history[: len(self.history) - self._keep]
        for handler in (*self._subs.get(kind, ()), *self._subs.get("*", ())):
            handler(kind, payload)

    def of_kind(self, kind: str) -> List[Event]:
        return [e for e in self.history if e.kind == kind]
