"""Tiny synchronous event bus wiring the Session facade to the runtime.

The trainer (and any future provider/backend) emits flat `(kind, payload)`
events; the Session forwards them onto a bus so callers can observe a run
without threading callbacks through every layer. Kinds emitted today:

  step                {step, loss}
  epoch               {step, kind, member_id, epoch, n_alive}
  checkpoint          {step, sizes}
  checkpoint_failed   {step, failures[, attempts, error]}
                                              (chaos ckpt-store outage;
                                               attempts/error appear when a
                                               resilience retry gave up)
  detection           {step, bottleneck, action, deviation, model_version}
  restore             {step}
  mitigation          {step, action, n_ps, grad_compression, ...}
  fault               {step, fault, ...}      (chaos injections)
  handler_error       {kind, handler, error}  (a subscriber raised)

Recovery kinds (resilience enabled — docs/resilience.md):

  retry               {op, attempt, outcome, backoff_s[, error]}
                                              (outcome: ok|fail|gave_up)
  restore_fallback    {step, depth, error}    (a corrupt generation skipped)
  restore_failed      {error}                 (every generation bad: fresh init)
  lease_handover      {step, holder, revoked_member}
  degradation         {step, tier, n_alive, roster_size}
                                              (tier: continue|shrink|pause,
                                               emitted on transitions only)

Calibration kinds (recalibration armed — docs/calibration.md):

  model_drift         {step, deviation, model_version}
                                              (CUSUM confirmed a persistent
                                               prediction/measurement shift)
  model_refit         {step, model_version, old_speed, new_speed, n_obs}
                                              (the cluster_speed estimator
                                               refit from profiler history;
                                               model_version is the new
                                               ModelStore version)

Subscribe to a specific kind or to "*" for everything. Handlers run inline
on the training thread — keep them cheap. A handler that raises is
*isolated*: the exception is swallowed, `handler_errors` is incremented and
a `handler_error` event is emitted, so one bad observer can never kill the
training loop it is observing.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Dict, List, Tuple

Handler = Callable[[str, Dict[str, Any]], None]


@dataclasses.dataclass
class Event:
    kind: str
    payload: Dict[str, Any]


class EventBus:
    def __init__(self, keep_history: int = 10_000):
        self._subs: Dict[str, List[Handler]] = defaultdict(list)
        self._keep = keep_history
        self.history: List[Event] = []
        #: total subscriber exceptions swallowed by `emit`
        self.handler_errors = 0

    def subscribe(self, kind: str, handler: Handler) -> Handler:
        """Register `handler` for `kind` ("*" = all). Returns the handler so
        this can be used as a decorator via `bus.on(kind)`."""
        self._subs[kind].append(handler)
        return handler

    def on(self, kind: str) -> Callable[[Handler], Handler]:
        return lambda fn: self.subscribe(kind, fn)

    def emit(self, kind: str, /, **payload: Any) -> None:
        # `kind` is positional-only so payloads may themselves carry a
        # "kind" key (e.g. the trainer's epoch events)
        if self._keep:
            self.history.append(Event(kind, payload))
            if len(self.history) > self._keep:
                del self.history[: len(self.history) - self._keep]
        failures: List[Tuple[Handler, Exception]] = []
        for handler in (*self._subs.get(kind, ()), *self._subs.get("*", ())):
            try:
                handler(kind, payload)
            except Exception as e:  # isolate observers from the run
                self.handler_errors += 1
                failures.append((handler, e))
        # report after the delivery loop so one bad handler cannot starve
        # the rest; never recurse on handler_error itself (a raising
        # handler_error subscriber would otherwise loop forever)
        if failures and kind != "handler_error":
            for handler, e in failures:
                self.emit("handler_error", kind=kind,
                          handler=getattr(handler, "__qualname__",
                                          repr(handler)),
                          error=f"{type(e).__name__}: {e}")

    def of_kind(self, kind: str) -> List[Event]:
        return [e for e in self.history if e.kind == kind]
