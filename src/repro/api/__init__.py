"""`repro.api` — the unified programmatic surface of the CM-DARE stack.

    from repro.api import Session
    s = Session.from_arch("qwen3-1.7b")
    s.plan(...); s.simulate(...); s.train(...); s.predict(...); s.serve(...)

See `repro.api.session` for the full facade, `repro.api.events` for the
observation bus, `repro.api.serving` for the decode loop. The `python -m
repro` CLI (`repro.__main__`) is a thin shell over this package.
"""
from repro.api.events import Event, EventBus  # noqa: F401
from repro.api.serving import ServeReport, generate  # noqa: F401
from repro.api.session import PredictionReport, Session  # noqa: F401
from repro.core.transient.fleet import (FleetEnsemble, SimResult,  # noqa: F401
                                        SimStats)
