"""Programmatic serving loop over the continuous-batching gateway.

`generate()` keeps its old one-call surface (batched prefill + decode,
shared `jit_cache` trace) but now runs through
`repro.serving.GatewayEngine`: every request occupies a slot with its own
decode position, so the same engine — and the same traced step — also
backs staggered multi-tenant admission, not just the lockstep case.

This refactor also retires a sampling bug the old loop carried: the first
generated token was always `argmax`, even with `temperature > 0` (two
seeds could never diverge before token 1). Sampling now happens in-trace
behind one per-slot temperature gate for every token, the first included.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.serving.engine import GatewayEngine


@dataclasses.dataclass
class ServeReport:
    arch: str
    batch: int
    prompt_len: int
    tokens_generated: int
    prefill_seconds: float
    decode_seconds: float
    tokens_per_second: float
    sample_tokens: List[int]
    generated: object  # (batch, tokens) array
    #: per-iteration decode wall-time percentiles, milliseconds
    decode_ms_p50: float = 0.0
    decode_ms_p95: float = 0.0
    decode_ms_p99: float = 0.0


def generate(cfg: ModelConfig, params=None, *, batch: int = 4,
             prompt_len: int = 32, tokens: int = 16,
             temperature: float = 0.0, seed: int = 1,
             prompt=None) -> ServeReport:
    """Prefill a (random or given) prompt via repeated decode — cache-
    consistent for every family — then sample `tokens` new tokens."""
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode path")
    max_len = prompt_len + tokens
    eng = GatewayEngine(cfg, params, slots=batch, max_len=max_len,
                        seed=seed)

    if prompt is None:
        prompt = jax.random.randint(jax.random.PRNGKey(seed),
                                    (batch, prompt_len), 0, cfg.vocab_size)
    for slot in range(batch):
        eng.join(slot, rid=slot, prompt=[int(t) for t in prompt[slot]],
                 max_new=tokens, temperature=temperature)

    # all slots prefill in lockstep: the first prompt_len iterations feed
    # prompt tokens; the last of those emits each request's first token
    out: Dict[int, List[int]] = {}
    t0 = time.monotonic()
    for _ in range(prompt_len - 1):
        eng.step()
    prefill_s = time.monotonic() - t0

    t0 = time.monotonic()
    n_prefill_steps = len(eng.step_seconds)
    while eng.busy():
        for ev in eng.step():
            if ev["done"]:
                out[ev["rid"]] = ev["tokens"]
    decode_s = time.monotonic() - t0

    decode_times = eng.step_seconds[n_prefill_steps:]
    eng.step_seconds = decode_times
    pct = eng.decode_percentiles_ms()
    gen = jnp.asarray([out[slot] for slot in range(batch)], jnp.int32)
    return ServeReport(
        arch=cfg.name, batch=batch, prompt_len=prompt_len,
        tokens_generated=tokens, prefill_seconds=prefill_s,
        decode_seconds=decode_s,
        tokens_per_second=tokens * batch / max(decode_s, 1e-9),
        sample_tokens=gen[0, :10].tolist(), generated=gen,
        decode_ms_p50=pct["p50"], decode_ms_p95=pct["p95"],
        decode_ms_p99=pct["p99"])
