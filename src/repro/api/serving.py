"""Programmatic serving loop: batched prefill + token-by-token decode
against the KV cache / SSM state. Extracted from the old `launch/serve.py`
launcher so `Session.serve` and the CLI share one implementation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import jit_cache
from repro.models import api


@dataclasses.dataclass
class ServeReport:
    arch: str
    batch: int
    prompt_len: int
    tokens_generated: int
    prefill_seconds: float
    decode_seconds: float
    tokens_per_second: float
    sample_tokens: List[int]
    generated: object  # (batch, tokens) array


def generate(cfg: ModelConfig, params=None, *, batch: int = 4,
             prompt_len: int = 32, tokens: int = 16,
             temperature: float = 0.0, seed: int = 1,
             prompt=None) -> ServeReport:
    """Prefill a (random or given) prompt via repeated decode — cache-
    consistent for every family — then sample `tokens` new tokens."""
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode path")
    if params is None:
        params, _ = api.init(cfg, jax.random.PRNGKey(0))
    max_len = prompt_len + tokens
    state, _ = api.init_decode_state(cfg, batch, max_len)

    key = jax.random.PRNGKey(seed)
    if prompt is None:
        prompt = jax.random.randint(key, (batch, prompt_len), 0,
                                    cfg.vocab_size)

    # the jitted decode step is memoized per ModelConfig: repeated
    # Session.serve calls (and fresh Sessions on the same arch) reuse one
    # traced callable instead of re-jitting every generate()
    step = jit_cache.cached(
        "decode_step", (cfg,),
        lambda: jax.jit(lambda p, s, t, i: api.decode_step(p, cfg, s, t, i)))

    t0 = time.monotonic()
    logits = None
    for i in range(prompt_len):
        logits, state = step(params, state, prompt[:, i], jnp.int32(i))
    prefill_s = time.monotonic() - t0

    toks = jnp.argmax(logits, -1)
    out = [toks]
    t0 = time.monotonic()
    for i in range(tokens - 1):
        logits, state = step(params, state, toks, jnp.int32(prompt_len + i))
        if temperature > 0:
            key, sub = jax.random.split(key)
            toks = jax.random.categorical(sub, logits / temperature, -1)
        else:
            toks = jnp.argmax(logits, -1)
        out.append(toks)
    decode_s = time.monotonic() - t0
    gen = jnp.stack(out, 1)
    return ServeReport(
        arch=cfg.name, batch=batch, prompt_len=prompt_len,
        tokens_generated=tokens, prefill_seconds=prefill_s,
        decode_seconds=decode_s,
        tokens_per_second=tokens * batch / max(decode_s, 1e-9),
        sample_tokens=gen[0, :10].tolist(), generated=gen)
