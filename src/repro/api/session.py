"""`Session` — the single programmatic surface for the CM-DARE loop.

The paper's framework (Fig 1) is measure -> model -> mitigate; this facade
exposes it as one object so launchers, examples, benchmarks and notebooks
stop hand-wiring configs -> models -> trainer -> perf models -> fleet sim:

    s = Session.from_arch("qwen3-1.7b")
    plan = s.plan(gpu="v100", n_workers=4)          # §V-C launch planner
    sim = s.simulate(n_workers=4, gpu="v100")       # §VI-A fleet simulator
    pred = s.predict(n_workers=4, gpu="v100")       # Eq (4)/(5) + §III models
    rep = s.train(steps=50)                         # elastic trainer + bus
    out = s.serve(tokens=16)                        # prefill/decode loop

plan/simulate/predict take `provider="gcp"|"aws"|"azure"` (docs/providers.md)
to run the same models over a different transient market; the default is the
paper's GCP preemptible fleet.

All run-shaped knobs default from the Session's `RunConfig`; every method
takes overrides. Training wires the profiler + bottleneck Controller through
the Session's `EventBus` (`session.bus.subscribe("step", fn)` etc.).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

from repro.configs import ARCH_IDS, RunConfig, get_config
from repro.configs.base import ModelConfig
from repro.api.events import EventBus
from repro.core import jit_cache
from repro.api.serving import ServeReport, generate
from repro.core.perf_model.cluster_model import (Eq4Inputs, PSBottleneckModel,
                                                 WorkerSpec, cluster_speed,
                                                 expected_revocations,
                                                 predict_total_time)
from repro.core.scheduler import LaunchPlan, plan_launch
from repro.core.trainer import MembershipEvent, TrainReport, TransientTrainer
from repro.core.transient.fleet import (FleetEnsemble, FleetSim, SimResult,
                                        SimStats, SimWorker)
from repro.core.transient.replacement import ReplacementModel
from repro.core.transient.startup import StartupModel
from repro.data.pipeline import ShardedLoader, source_for_config
from repro.dist.compression import compression_ratio
from repro.dist.elastic import Member
from repro.providers import FleetProvider, get_provider

# Sequential-checkpoint write bandwidth assumed when no measurement is
# available yet (§IV: T_c scales ~linearly with checkpoint size).
_CKPT_BYTES_PER_S = 200e6
_CKPT_BASE_S = 0.25


@dataclasses.dataclass
class PredictionReport:
    """Composed §III/§IV/§V predictions for one (model, cluster) pairing."""
    arch: str
    gpu: str
    region: str
    provider: str
    n_workers: int
    model_gflops: float
    model_bytes: float
    worker_speed: float          # steps/s solo (§III predictor)
    cluster_speed: float         # steps/s, PS-capped (Fig 4)
    ps_bottlenecked: bool
    ps_capacity: float           # PS ceiling, compression-scaled (§VI-B)
    grad_compression: str        # wire scheme the capacity model assumed
    payload_bytes: float         # per-push update size under that scheme
    checkpoint_seconds: float    # T_c (§IV)
    provision_seconds: float     # T_p (§V-B)
    replacement_seconds: float   # T_s (Fig 10)
    expected_revocations: float  # Eq (5)
    total_time_seconds: float    # Eq (4)


class Session:
    """One model + run configuration, and every CM-DARE capability on it."""

    def __init__(self, cfg: ModelConfig, run: Optional[RunConfig] = None,
                 *, arch: Optional[str] = None, bus: Optional[EventBus] = None,
                 provider: object = "gcp"):
        self.cfg = cfg
        self.run = run or RunConfig()
        self.arch = arch or cfg.name
        self.bus = bus or EventBus()
        if self.run.compilation_cache_dir:
            # persistent XLA cache: repeated chaos/live runs skip re-jit
            jit_cache.enable_persistent_cache(self.run.compilation_cache_dir)
        # session-default transient market; plan/simulate/predict take a
        # per-call `provider=` override (name or FleetProvider instance)
        self.provider: FleetProvider = get_provider(provider)
        self.trainer: Optional[TransientTrainer] = None
        self.last_report: Optional[TrainReport] = None
        self._last_state = None     # final TrainState of the last train()
        self._gens = None           # lazily calibrated §III generators
        self._n_tensors = None      # lazily counted parameter-tree leaves
        self._models = None         # lazily built calibration ModelStore

    # ------------------------------------------------------------ creation
    @classmethod
    def from_arch(cls, arch: str, *, smoke: bool = True,
                  run: Optional[RunConfig] = None,
                  bus: Optional[EventBus] = None,
                  provider: object = "gcp",
                  **run_overrides) -> "Session":
        """Resolve a registered architecture id (see `repro.configs`).

        `run_overrides` are `RunConfig` fields (lr, total_steps, ...);
        `provider` sets the session's default transient market.
        """
        if arch not in ARCH_IDS:
            raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_IDS)}")
        run = run or RunConfig()
        if run_overrides:
            run = dataclasses.replace(run, **run_overrides)
        return cls(get_config(arch, smoke=smoke), run, arch=arch, bus=bus,
                   provider=provider)

    # ---------------------------------------------------------- model meta
    def describe(self) -> Dict[str, object]:
        cfg = self.cfg
        return {
            "arch": self.arch, "family": cfg.family,
            "n_layers": cfg.n_layers, "d_model": cfg.d_model,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "optimizer": self.run.optimizer,
        }

    def model_gflops(self, seq_len: Optional[int] = None,
                     per_worker_batch: int = 8) -> float:
        """C_m for the §III predictors: forward GFLOPs per worker step."""
        seq = seq_len or 64
        return self.cfg.flops_per_token(seq) * seq * per_worker_batch / 1e9

    def model_bytes(self) -> float:
        """Checkpoint/update payload (fp32 params)."""
        return 4.0 * self.cfg.param_count()

    def n_tensors(self) -> int:
        """Variable count of the parameter tree — the per-tensor RPC term
        of the PS capacity law (Table III), which compression does NOT
        shrink (one RPC per variable regardless of payload)."""
        if self._n_tensors is None:
            import jax

            from repro.models import api as model_api
            self._n_tensors = len(jax.tree.leaves(
                model_api.param_shapes(self.cfg)))
        return self._n_tensors

    # ------------------------------------------------------ §III speed
    @property
    def models(self):
        """The session's calibration `ModelStore` (docs/calibration.md):
        every predictor resolves through this one handle. Seeded from the
        static paper calibrations — the exact memoized instances, so the
        unarmed path stays bit-identical — and updated in place by the
        `Recalibrator` when `train(recalibration=...)` is armed."""
        if self._models is None:
            from repro.calibration import ModelStore
            self._models = ModelStore.with_static_calibrations()
        return self._models

    def _generators(self):
        if self._gens is None:
            store = self.models
            self._gens = {name.split("/", 1)[1]: store.current(name)
                          for name in store.names()
                          if name.startswith("step_time/")}
        return self._gens

    def _provider(self, provider: Optional[object]) -> FleetProvider:
        """Resolve a per-call override against the session default."""
        return self.provider if provider is None else get_provider(provider)

    def _check_fleet(self, gpu: str, region: Optional[str] = None,
                     provider: Optional[FleetProvider] = None) -> None:
        """The speed models only cover the measured GPUs, and each provider
        only sells certain (region, gpu) cells — fail with the options."""
        gens = self._generators()
        if gpu not in gens:
            raise ValueError(f"no calibrated speed model for {gpu!r}; "
                             f"available: {sorted(gens)}")
        prov = provider or self.provider
        if region is None:
            prov.check_gpu_offered(gpu)
        else:
            prov.check_offered(region, gpu)

    def predict_worker_speed(self, gpu: str = "v100",
                             seq_len: Optional[int] = None,
                             per_worker_batch: int = 8,
                             provider: Optional[object] = None) -> float:
        """Solo steps/s on `gpu` from the calibrated §III step-time model.

        The speed model is hardware-only; `provider` only scopes the
        does-this-market-sell-this-GPU validation."""
        self._check_fleet(gpu, provider=self._provider(provider))
        c_m = self.model_gflops(seq_len, per_worker_batch)
        return 1.0 / self._generators()[gpu].step_time(c_m)

    def checkpoint_seconds(self) -> float:
        """T_c estimate (§IV linear law) until a measured value exists."""
        if self.trainer is not None and self.trainer.ckpt.last_save_seconds:
            return self.trainer.ckpt.last_save_seconds
        return _CKPT_BASE_S + self.model_bytes() / _CKPT_BYTES_PER_S

    # ------------------------------------------------------ §V-C planner
    def plan(self, gpu: str = "v100", n_workers: int = 4,
             steps: Optional[int] = None,
             checkpoint_interval: Optional[int] = None,
             t_c: Optional[float] = None,
             hours: Optional[List[int]] = None,
             region: Optional[str] = None,
             seed: int = 0,
             provider: Optional[object] = None,
             samples: int = 200,
             n_ps: Optional[int] = None,
             score: str = "eq4",
             engine: str = "batched",
             resilience: Optional[object] = None
             ) -> Tuple[LaunchPlan, List[LaunchPlan]]:
        """Revocation-aware (region, launch-hour) planning for this model.

        `region=None` scores every region offering `gpu`; pass a region to
        constrain the plan to it. `provider` picks the transient market
        (default: the session's, normally "gcp"). `samples` sets the
        Monte-Carlo draws per (region, hour) cell — every returned
        `LaunchPlan` carries the binomial `revocation_stderr` of its
        E[revocations] estimate. `n_ps` (optional) additionally caps the
        cluster speed with the Fig 4 PS capacity model for this model's
        payload under `run.grad_compression` — the §VI-B recalibration,
        so a compressed plan sees the raised ceiling.

        `score="sim"` replaces the Eq (4) point estimate with a full
        fleet-simulation ensemble per cell (`samples` trajectories on
        `engine` — "batched", "event", or "jit"), so every plan also
        carries realized
        time/cost percentiles and the `finished` censoring count —
        simulation-backed planning instead of the closed form alone.
        A sim-scored sweep ALWAYS simulates under the Fig 4 PS capacity
        for this model (defaulting to one PS when `n_ps` is not given),
        matching what `simulate()`/`predict()` would report for the
        chosen cell; the eq4 score keeps its historic uncapped Σ sp_i
        composition unless `n_ps` is passed.

        `resilience` (default: the session `run.resilience`) is honored
        under score="sim": the simulated cells price in quorum pauses and
        restore-retry stalls (docs/resilience.md).
        """
        prov = self._provider(provider)
        # validate (gpu, region) BEFORE the MC sweep so a typo'd region
        # fails immediately instead of after seconds of discarded work
        self._check_fleet(gpu, region, prov)
        ps = None
        if n_ps is not None or score == "sim":
            ps = PSBottleneckModel(self.model_bytes(),
                                   1 if n_ps is None else n_ps,
                                   n_tensors=self.n_tensors(),
                                   compression=self.run.grad_compression)
        best, plans = plan_launch(
            gpu, n_workers, self.predict_worker_speed(gpu, provider=prov),
            n_w=self.run.total_steps if steps is None else steps,
            i_c=(self.run.checkpoint_interval if checkpoint_interval is None
                 else checkpoint_interval),
            t_c=t_c if t_c is not None else self.checkpoint_seconds(),
            hours=hours, seed=seed, provider=prov, samples=samples,
            # the session's real model complexity, so plan() and predict()
            # agree on the Fig 10 replacement term for the same cell
            model_gflops=self.model_gflops(), ps=ps,
            score=score, engine=engine, model_bytes=self.model_bytes(),
            # constrain BEFORE scoring: under score="sim" every discarded
            # cell would have cost a full ensemble
            region=region,
            resilience=(self.run.resilience if resilience is None
                        else resilience))
        return best, plans

    # ------------------------------------------------- §VI-A fleet sim
    def simulate(self, n_workers: int = 4, gpu: str = "v100",
                 region: Optional[str] = None,
                 counts: Optional[Dict[str, int]] = None,
                 steps: Optional[int] = None,
                 checkpoint_interval: Optional[int] = None,
                 n_ps: int = 1, seed: int = 0, replace: bool = True,
                 handover: bool = True,
                 max_hours: float = 48.0,
                 provider: Optional[object] = None,
                 start_hour: float = 0.0,
                 samples: int = 1,
                 engine: str = "batched",
                 chaos: object = None,
                 resilience: Optional[object] = None):
        """Discrete-event simulation on a transient cluster.

        Either a homogeneous (`n_workers` x `gpu`) cluster or an explicit
        heterogeneous `counts` mapping gpu -> count. `provider` picks the
        transient market; `region=None` uses that market's default region;
        `start_hour` is the local launch hour (diurnal lifetime laws).

        `samples=1` (default) runs one trajectory and returns a
        `SimResult`, bit-identical to the pre-ensemble behavior for a
        fixed seed. `samples>1` runs a `FleetSim.run_many` ensemble and
        returns a `FleetEnsemble` whose `.stats` is the p50/p90/mean
        `SimStats` summary; `engine` picks the trajectory stepper —
        "batched" (default) is the lockstep array engine, "event" the
        per-trajectory discrete-event loop kept as the parity oracle,
        "jit" the same lockstep rounds compiled into one jitted JAX
        program for mega-ensembles (docs/performance.md has the
        selection guide).

        The simulated PS capacity uses this model's variable count and
        `run.grad_compression`, exactly like `Session.predict` — so
        predicted-vs-simulated error (§VI-A) stays meaningful for
        compressed runs.

        `chaos` (a `repro.chaos.FaultTimeline`, or anything honoring its
        interface) scripts faults into the simulated fleet — see
        `Session.chaos` for the scenario-level entry point.

        `resilience` (a `repro.resilience.ResilienceConfig`; default: the
        session `run.resilience`) arms quorum degradation and
        restore-retry stalls in the simulated fleet (docs/resilience.md)
        — identically on every engine.
        """
        sim, n_steps = self._fleet_sim(
            n_workers=n_workers, gpu=gpu, region=region, counts=counts,
            steps=steps, checkpoint_interval=checkpoint_interval, n_ps=n_ps,
            seed=seed, replace=replace, handover=handover,
            provider=provider, chaos=chaos, resilience=resilience)
        if samples > 1:
            return sim.run_many(n_steps, samples, max_hours=max_hours,
                                start_hour=start_hour, engine=engine)
        return sim.run(n_steps, max_hours=max_hours, start_hour=start_hour)

    def _fleet_sim(self, *, n_workers: int = 4, gpu: str = "v100",
                   region: Optional[str] = None,
                   counts: Optional[Dict[str, int]] = None,
                   steps: Optional[int] = None,
                   checkpoint_interval: Optional[int] = None,
                   n_ps: int = 1, seed: int = 0, replace: bool = True,
                   handover: bool = True,
                   provider: Optional[object] = None,
                   chaos: object = None,
                   resilience: Optional[object] = None
                   ) -> Tuple[FleetSim, int]:
        """Construct the configured `FleetSim` (and the resolved step
        budget) without running it — `simulate()`'s builder, shared with
        the chaos runner, which needs the sim object itself for the
        shared-draws ground-truth hash."""
        prov = self._provider(provider)
        region = region or prov.default_region
        counts = counts or {gpu: n_workers}
        for g in counts:
            self._check_fleet(g, region, prov)
        n_steps = self.run.total_steps if steps is None else steps
        i_c = (self.run.checkpoint_interval if checkpoint_interval is None
               else checkpoint_interval)
        t_c = self.checkpoint_seconds()
        if i_c == 0:  # no checkpointing: one interval past the run's end
            i_c, t_c = n_steps + 1, 0.0
        c_m = self.model_gflops()
        gens = self._generators()
        workers, wid = [], 0
        for g, n in counts.items():
            for _ in range(n):
                workers.append(SimWorker(wid, g, region,
                                         1.0 / gens[g].step_time(c_m)))
                wid += 1
        sim = FleetSim(
            workers, model_gflops=c_m, model_bytes=self.model_bytes(),
            step_speed_of=lambda g: 1.0 / gens[g].step_time(c_m),
            checkpoint_interval_steps=i_c, checkpoint_time_s=t_c, n_ps=n_ps,
            seed=seed, replace=replace, handover=handover,
            price_of={g: prov.price(g) for g in counts}, provider=prov,
            n_tensors=self.n_tensors(),
            grad_compression=self.run.grad_compression, chaos=chaos,
            resilience=(self.run.resilience if resilience is None
                        else resilience))
        return sim, n_steps

    # ---------------------------------------------------- chaos scenarios
    def chaos(self, scenario: str = "all", *, engine: str = "batched",
              live: bool = True, samples: int = 32, seed: int = 0,
              smoke: bool = False) -> Dict[str, object]:
        """Run scripted fault scenarios against this model and score the
        detection/mitigation loop against the recorded ground truth.

        `scenario` is a registered scenario name (see
        `repro.chaos.list_scenarios()`) or `"all"`. Each scenario runs as
        a fleet-simulation ensemble (`samples` faulted + baseline
        trajectories on `engine` — "batched", "event" or "jit" — plus an
        engine-vs-event parity probe);
        scenarios with a live plan additionally drive the real
        `TransientTrainer` under a virtual clock (`live=False` skips
        that). `smoke=True` also checks each scenario's `expect` gates
        and sets the scorecard's `passed` flag accordingly.

        Returns the JSON-serializable scorecard `python -m repro chaos`
        prints.
        """
        from repro.chaos import runner as chaos_runner
        return chaos_runner.run_scenarios(
            scenario, session=self, engine=engine, live=live,
            samples=samples, seed=seed, smoke=smoke)

    # ------------------------------------------------ Eq (4)/(5) predict
    def predict(self, n_workers: int = 4, gpu: str = "v100",
                region: Optional[str] = None,
                steps: Optional[int] = None,
                checkpoint_interval: Optional[int] = None,
                n_ps: int = 1, t_c: Optional[float] = None,
                seed: int = 0,
                provider: Optional[object] = None) -> PredictionReport:
        """Compose the §III speed, §IV checkpoint and §V revocation models
        into the Eq (4) end-to-end wall-clock prediction. `provider` picks
        the transient market; `region=None` uses its default region."""
        prov = self._provider(provider)
        region = region or prov.default_region
        self._check_fleet(gpu, region, prov)
        n_w = self.run.total_steps if steps is None else steps
        i_c = (self.run.checkpoint_interval if checkpoint_interval is None
               else checkpoint_interval)
        worker_speed = self.predict_worker_speed(gpu, provider=prov)
        # the capacity ceiling reflects the run's wire scheme (§VI-B): a
        # compressed payload raises the network term by 1/compression_ratio
        # while the per-tensor RPC term stays — RPC-bound models (many
        # small tensors) keep their ceiling
        ps = PSBottleneckModel(self.model_bytes(), n_ps,
                               n_tensors=self.n_tensors(),
                               compression=self.run.grad_compression)
        workers = [WorkerSpec(gpu, worker_speed)] * n_workers
        sp = cluster_speed(workers, ps)
        hours = n_w / sp / 3600.0
        lifetime = prov.lifetime_model(region, gpu)
        horizon = min(hours, prov.max_lifetime_hours)
        probs = [lifetime.prob_revoked_within(horizon)] * n_workers
        t_c = t_c if t_c is not None else self.checkpoint_seconds()
        if i_c == 0:  # no checkpointing: zero pauses, Eq (4) stays defined
            i_c, t_c = n_w, 0.0
        t_p = StartupModel(seed, prov).mean_total(gpu)
        t_s = ReplacementModel(seed, prov).cold_start_s(self.model_gflops())
        total = predict_total_time(sp, Eq4Inputs(n_w, i_c, t_c, t_p, t_s,
                                                 probs))
        return PredictionReport(
            arch=self.arch, gpu=gpu, region=region, provider=prov.name,
            n_workers=n_workers,
            model_gflops=self.model_gflops(),
            model_bytes=self.model_bytes(), worker_speed=worker_speed,
            cluster_speed=sp, ps_bottlenecked=ps.is_bottlenecked(workers),
            ps_capacity=ps.capacity_steps_per_s(),
            grad_compression=self.run.grad_compression,
            payload_bytes=self.model_bytes()
            * compression_ratio(self.run.grad_compression),
            checkpoint_seconds=t_c, provision_seconds=t_p,
            replacement_seconds=t_s,
            expected_revocations=expected_revocations(probs),
            total_time_seconds=total)

    # ----------------------------------------------------- elastic train
    def train(self, steps: Optional[int] = None, *, global_batch: int = 8,
              seq_len: int = 64,
              members: int = 1,
              events: Optional[List[MembershipEvent]] = None,
              holder: str = "worker-0",
              checkpoint_dir: Optional[str] = None,
              predicted_speed: Optional[float] = None,
              check_every: int = 10,
              resume: bool = True,
              mode: str = "sync",
              ps_model: Optional[PSBottleneckModel] = None,
              workers: Optional[List[WorkerSpec]] = None,
              worker_step_times: Optional[List[float]] = None,
              clock=None,
              resilience: Optional[object] = None,
              recalibration: Optional[object] = None) -> TrainReport:
        """Run the transient-aware elastic trainer; profiler + Controller
        observations stream onto `self.bus`.

        `mode="sync"` (default) is the elastic synchronous runtime;
        `mode="async_ps"` runs the §II asynchronous-PS emulation
        (`core/ps_async.py`) over the same model and data — per-update
        `async_step` events and a final `staleness` event (the staleness
        histogram plus per-worker paces and realized update counts) land on the bus.

        `resume=True` restores from `checkpoint_dir` when a checkpoint
        exists (lease permitting), which is how a replacement chief
        continues a run (pass a new `holder`). `ps_model`/`workers` arm
        the §VI-B mitigation loop: the Controller attributes deviations
        to PS saturation and the trainer acts mid-run
        (add a PS / enable compression) and re-derives its prediction.
        `clock` (a zero-arg callable returning seconds) replaces the
        profiler's wall clock — the chaos harness injects virtual time so
        detection latency is deterministic across machines.
        `resilience` (a `repro.resilience.ResilienceConfig`; default: the
        session `run.resilience`) arms the recovery layer — retried
        checkpoint saves/restores with checksum validation and
        generation fallback, retried replacement joins, and quorum-based
        degradation (docs/resilience.md).
        `recalibration` (a `repro.calibration.RecalibrationConfig`;
        default: the session `run.recalibration`) arms the online
        drift/refit loop: CUSUM drift detection over Controller
        deviations, `model_drift`/`model_refit` events on the bus, and
        the refit `cluster_speed` estimator versioned in `self.models`
        (docs/calibration.md). Unarmed (None), every static calibration
        is bit-identical to the pre-calibration-layer behavior.
        """
        if mode == "async_ps":
            # the §II emulation has no checkpointing, membership events or
            # controller loop — reject sync-only arguments loudly rather
            # than silently dropping e.g. a checkpoint_dir the caller is
            # relying on
            unsupported = {"events": events, "checkpoint_dir": checkpoint_dir,
                           "predicted_speed": predicted_speed,
                           "ps_model": ps_model, "workers": workers,
                           "resilience": resilience,
                           "recalibration": recalibration}
            bad = sorted(k for k, v in unsupported.items() if v)
            if bad:
                raise ValueError(
                    f"mode='async_ps' does not support: {', '.join(bad)} "
                    "(no checkpointing/controller loop in the emulation)")
            return self._train_async_ps(
                steps, global_batch=global_batch, seq_len=seq_len,
                members=members, worker_step_times=worker_step_times)
        if mode != "sync":
            raise ValueError(f"unknown train mode {mode!r}; "
                             f"known: ('sync', 'async_ps')")
        if worker_step_times:
            raise ValueError("worker_step_times applies to "
                             "mode='async_ps' only (sync pacing is "
                             "measured, not configured)")
        steps = self.run.total_steps if steps is None else steps
        run = self.run
        if checkpoint_dir is not None:
            run = dataclasses.replace(run, checkpoint_dir=checkpoint_dir)
        elif run.checkpoint_dir == RunConfig.checkpoint_dir:
            # default path: keep resume-across-invocations but namespace by
            # arch so different models never restore each other's trees
            run = dataclasses.replace(
                run, checkpoint_dir=os.path.join(run.checkpoint_dir,
                                                 self.arch))
        src = source_for_config(self.cfg, seq_len, seed=run.seed)
        loader = ShardedLoader(src, global_batch)
        recal_cfg = (run.recalibration if recalibration is None
                     else recalibration)
        recalibrator = None
        if recal_cfg is not None:
            from repro.calibration import Recalibrator
            recalibrator = Recalibrator(config=recal_cfg, store=self.models)
            if getattr(recal_cfg, "trace_path", None):
                recalibrator.ingest_trace()
        trainer = TransientTrainer(
            self.cfg, run, loader,
            members=[Member(i) for i in range(members)], holder=holder,
            predicted_speed=predicted_speed,
            on_event=lambda kind, payload: self.bus.emit(kind, **payload),
            ps_model=ps_model, workers=workers, clock=clock,
            resilience=(run.resilience if resilience is None
                        else resilience),
            recalibrator=recalibrator)
        self.trainer = trainer
        # NOTE: `run` (with the resolved checkpoint_dir) lives on the
        # trainer only — per-call overrides never mutate self.run
        state, start = (trainer.restore_or_init() if resume
                        else (trainer.init_state(), 0))
        state, report = trainer.run_steps(state, steps, events=events,
                                          check_every=check_every)
        self._last_state = state
        self.last_report = report
        return report

    def _train_async_ps(self, steps: Optional[int], *, global_batch: int,
                        seq_len: int, members: int,
                        worker_step_times: Optional[List[float]]
                        ) -> TrainReport:
        """§II async-PS emulation as a Session mode (the ROADMAP item).

        Workers push gradients computed at stale parameter snapshots; pace
        differences produce the staleness the paper studies. Events:
        `async_step` per applied update, then one `staleness` event with
        the histogram, per-worker paces and realized update counts.
        """
        import time as _time

        import jax.numpy as jnp

        from repro.core.ps_async import async_sgd
        from repro.launch import steps as steps_mod
        from repro.models import api as model_api

        steps = self.run.total_steps if steps is None else steps
        src = source_for_config(self.cfg, seq_len, seed=self.run.seed)
        loader = ShardedLoader(src, global_batch)
        params, _ = model_api.init(self.cfg)
        # default pace spread mirrors the paper's K80-vs-V100 heterogeneity
        paces = worker_step_times or [0.1 * (1 + i) for i in range(members)]

        def loss_fn(p, batch):
            return model_api.loss_fn(p, self.cfg, batch)

        def data(worker, key):
            batch_np = loader.next_global(1)
            return ({k: jnp.asarray(v) for k, v in batch_np.items()},)

        t0 = _time.monotonic()
        final_params, trace = async_sgd(
            loss_fn, params, data, paces, lr=self.run.lr,
            total_updates=steps, seed=self.run.seed,
            on_update=lambda info: self.bus.emit("async_step", **info))
        # serve() after an async train must see the trained weights, just
        # like the sync path
        self._last_state = steps_mod.TrainState(
            final_params, (), jnp.zeros((), jnp.int32))
        self.bus.emit("staleness",
                      hist=dict(sorted(trace.staleness_hist.items())),
                      worker_updates=trace.worker_updates,
                      worker_step_time=trace.worker_step_time,
                      mode="async_ps")
        report = TrainReport(
            steps_run=trace.applied_updates,
            final_loss=trace.losses[-1] if trace.losses else float("nan"),
            losses=trace.losses, speed=None, epochs=1, checkpoints=0,
            restores=0, detections=[],
            wall_seconds=_time.monotonic() - t0)
        self.last_report = report
        return report

    # ------------------------------------------------------------- serve
    def serve(self, tokens: int = 16, *, batch: int = 4,
              prompt_len: int = 32, temperature: float = 0.0,
              seed: int = 1) -> ServeReport:
        # serve the exact final weights of the last train() (the trainer's
        # checkpoint may lag by up to checkpoint_interval-1 steps)
        params = (self._last_state.params
                  if self._last_state is not None else None)
        report = generate(self.cfg, params, batch=batch,
                          prompt_len=prompt_len, tokens=tokens,
                          temperature=temperature, seed=seed)
        self.bus.emit("serve", arch=report.arch, batch=report.batch,
                      tokens=report.tokens_generated,
                      tokens_per_second=round(report.tokens_per_second, 3),
                      decode_ms_p50=round(report.decode_ms_p50, 4),
                      decode_ms_p95=round(report.decode_ms_p95, 4),
                      decode_ms_p99=round(report.decode_ms_p99, 4))
        return report

    def plan_serving(self, *,
                     replica_counts=(2, 4, 8),
                     providers=("gcp", "aws"),
                     regions=None,
                     gpu: str = "v100",
                     workload=None,
                     slo=None,
                     batch_ceiling: int = 8,
                     policy=None,
                     resilience: Optional[object] = None,
                     samples: int = 8,
                     horizon_s: float = 3600.0,
                     seed: int = 0):
        """SLO-aware serving fleet planning (docs/serving.md).

        The serving sibling of `plan()`: scores every (replica_count,
        provider, region) cell with a full `ServingFleetSim` ensemble —
        revocations from each market's lifetime law, drain/handover under
        the session's resilience config — and ranks meets-SLO-first, then
        cheapest $/1k completed requests. The per-token decode time comes
        from this session's calibrated §III step-time model for `gpu`, so
        the plan prices this model's actual decode speed, not a constant.
        """
        from repro.serving import (ServingSLO, ServingWorkload,
                                   plan_serving)
        workload = workload or ServingWorkload()
        slo = slo or ServingSLO()
        # decode-round seconds on `gpu`: one token across the batch costs
        # one model step at the serving batch's complexity
        token_time_s = 1.0 / self.predict_worker_speed(
            gpu, seq_len=workload.prompt_tokens + workload.max_tokens,
            per_worker_batch=batch_ceiling)
        res = self.run.resilience if resilience is None else resilience
        best, plans = plan_serving(
            workload, slo, replica_counts=replica_counts,
            providers=providers, regions=regions, gpu=gpu,
            token_time_s=token_time_s, batch_ceiling=batch_ceiling,
            policy=policy, resilience=res, horizon_s=horizon_s,
            samples=samples, seed=seed)
        self.bus.emit("plan_serving", gpu=gpu, cells=len(plans),
                      best_provider=best.provider,
                      best_replicas=best.replicas,
                      best_meets_slo=best.meets_slo,
                      best_cost_per_1k=best.cost_per_1k)
        return best, plans
