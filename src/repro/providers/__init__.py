"""Multi-cloud `FleetProvider` layer (docs/providers.md, DESIGN.md §5).

One interface owns everything that differs between transient-GPU markets
— the (region, gpu) offering grid, revocation-lifetime laws, startup and
replacement-time models, and hourly pricing — so the paper's Eq (4)/(5)
machinery plans, simulates and predicts on any of them:

    from repro.providers import get_provider
    aws = get_provider("aws")
    aws.lifetime_model("us-east-1", "v100").prob_revoked_within(12.0)

Built-in adapters: `gcp` (the paper's Table V / Fig 8-9 calibrations,
bit-for-bit), `aws` (uncapped price-signal hazard, 2-min notice), `azure`
(eviction-rate tiers, 30 s notice). `provider=` parameters across
`repro.core.transient`, `repro.core.scheduler` and `repro.api.Session`
accept either a registry name or a `FleetProvider` instance.
"""
from repro.providers.base import (FleetProvider, LifetimeLaw,  # noqa: F401
                                  Offering, ReplacementAnchors,
                                  StartupStages)
from repro.providers.registry import (available_providers,  # noqa: F401
                                      get_provider, register_provider)
from repro.providers.gcp import GCP, GCPPreemptible  # noqa: F401
from repro.providers.aws import AWS, AWSSpot  # noqa: F401
from repro.providers.azure import AZURE, AzureLowPriority  # noqa: F401
