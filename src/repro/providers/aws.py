"""AWS EC2 Spot adapter.

What changes relative to the paper's GCP market (docs/providers.md):

* **No 24 h lifetime cap** — spot instances run until the market reclaims
  them, so the lifetime law is an *uncapped* non-homogeneous hazard rather
  than GCP's truncated Weibull with a point mass at 24 h.
* **Price-signal-driven hazard** — interruptions happen when the spot
  price (demand) rises through the fleet's bid, so the hazard follows a
  diurnal demand signal per region: lambda(t) = base * signal(local hour).
  Base rates are calibrated to Spot-Advisor-style interruption-frequency
  buckets (probability of interruption within 24 h).
* **2-minute interruption notice** — long enough for an interruption
  handler to flush a checkpoint (`graceful_checkpoint_on_warning=True`),
  unlike the 30 s GCP notice stock frameworks ignore (§V).

Catalog note: AWS never sold P100s — K80s are p2.* and V100s are p3.*,
which is why `p100` is absent from this market's offerings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import numpy as np

from repro.providers.base import (FleetProvider, LifetimeLaw, Offering,
                                  ReplacementAnchors, StartupStages,
                                  conditional_mean_from_cdf)
from repro.providers.registry import register_provider

# Sampling horizon for the uncapped law: lifetimes beyond this return inf
# ("survived the simulated window"), mirroring GCP's 24 h point mass.
SPOT_HORIZON_H = 168.0


def demand_signal(hour, peak_hour: float, amplitude: float):
    """Relative spot-price/demand level at a local hour (scalar or array):
    a business-hours bump on a flat base (max value 1 + amplitude)."""
    h = np.asarray(hour, float) % 24.0
    d = np.minimum(np.abs(h - peak_hour), 24.0 - np.abs(h - peak_hour))
    return 1.0 + amplitude * np.exp(-(d ** 2) / (2 * 3.5 ** 2))


@dataclasses.dataclass
class PriceSignalLifetime(LifetimeLaw):
    """Uncapped lifetime under a diurnal price-driven hazard.

    hazard(t) = base_hazard * demand_signal(start_hour + t); the CDF and
    inverse are computed on a time grid (no closed form).
    """
    region: str
    gpu: str
    p24: float            # interruption probability within 24 h (advisor)
    peak_hour: float
    amplitude: float
    horizon_h: float = SPOT_HORIZON_H

    def __post_init__(self):
        # base hazard so that the *average-signal* 24 h survival matches
        # the advisor bucket: integral of hazard over 24 h = -ln(1-p24)
        mean_sig = float(np.mean(demand_signal(
            np.linspace(0.0, 24.0, 97), self.peak_hour, self.amplitude)))
        self.base_hazard = -math.log(max(1.0 - self.p24, 1e-9)) \
            / (24.0 * mean_sig)
        # the cumulative-hazard grid only depends on the launch hour mod
        # 24 — cache it so MC planning (200 samples per cell) does not
        # rebuild an identical grid per sample
        self._grid_cache: Dict[float, Tuple[np.ndarray, np.ndarray]] = {}

    def _grid(self, start_hour: float) -> Tuple[np.ndarray, np.ndarray]:
        # quantize the launch hour to 15 min: bounds the cache at 96
        # entries and lets simulator join events (continuous start_hour)
        # hit it; well within the hazard model's fidelity
        key = round(float(start_hour) % 24.0 * 4.0) / 4.0
        hit = self._grid_cache.get(key)
        if hit is None:
            ts = np.linspace(0.0, self.horizon_h, 2048)
            lam = self.base_hazard * demand_signal(
                key + ts, self.peak_hour, self.amplitude)
            cum = np.concatenate([[0.0], np.cumsum(
                0.5 * (lam[1:] + lam[:-1]) * np.diff(ts))])
            hit = self._grid_cache[key] = (ts, cum)
        return hit

    def cdf(self, t_hours: np.ndarray, start_hour: float = 0.0) -> np.ndarray:
        ts, cum = self._grid(start_hour)
        lam_t = np.interp(np.asarray(t_hours, float), ts, cum)
        return 1.0 - np.exp(-lam_t)

    def prob_revoked_within(self, t_hours: float) -> float:
        return float(self.cdf(np.array([t_hours]))[0])

    def sample(self, rng: np.random.Generator, n: int = 1,
               start_hour: float = 0.0) -> np.ndarray:
        ts, cum = self._grid(start_hour)
        target = -np.log(1.0 - rng.uniform(size=n))
        # right=np.inf: targets beyond the horizon's cumulative hazard
        # survived the sampling window
        return np.interp(target, cum, ts, right=np.inf)

    def params_hash(self) -> str:
        # override the LifetimeLaw default: include the derived
        # base_hazard (the fitted quantity) and skip the grid cache
        from repro.calibration.estimator import params_hash
        return params_hash("price_signal", self.region, self.gpu, self.p24,
                           self.peak_hour, self.amplitude, self.horizon_h,
                           self.base_hazard)

    #: single-column consumption: one uniform through the inverse
    #: cumulative hazard (keeps the engines' pre-drawn pools minimal)
    SAMPLE_UNIFORMS_K = 1

    def sample_from_uniforms(self, U: np.ndarray,
                             start_hours: np.ndarray) -> np.ndarray:
        """Fleet-engine replacement-join sampler (LifetimeLaw contract):
        inverse cumulative hazard of column 0, per-row launch hour. Rows
        are grouped by the 15-min-quantized hazard grid their hour maps
        to, so the cache behaves exactly as under `sample`."""
        U = np.atleast_2d(np.asarray(U, float))
        hours = np.asarray(start_hours, float)
        target = -np.log(1.0 - U[:, 0])
        out = np.empty(len(target))
        keys = np.round(hours % 24.0 * 4.0) / 4.0
        for key in np.unique(keys):
            rows = keys == key
            ts, cum = self._grid(float(key))
            out[rows] = np.interp(target[rows], cum, ts, right=np.inf)
        return out

    def mean_time_to_revocation(self) -> float:
        p_h = self.prob_revoked_within(self.horizon_h)
        return conditional_mean_from_cdf(self.cdf, p_h, self.horizon_h)


# (region, gpu) -> (p24 interruption bucket, demand peak local hour,
# demand amplitude). p2=K80, p3=V100; no P100 SKU ever existed on EC2.
SPOT_MARKETS: Dict[Tuple[str, str], Tuple[float, float, float]] = {
    ("us-east-1", "k80"): (0.20, 11.0, 0.9),
    ("us-east-1", "v100"): (0.45, 13.0, 1.4),   # chronically tight p3 pool
    ("us-west-2", "k80"): (0.12, 10.0, 0.7),
    ("us-west-2", "v100"): (0.32, 12.0, 1.1),
    ("eu-west-1", "k80"): (0.16, 9.0, 0.8),
    ("eu-west-1", "v100"): (0.26, 10.0, 1.0),
    ("ap-northeast-1", "v100"): (0.38, 14.0, 1.2),
}

# per-GPU-server $/h: (on-demand, typical spot) — p2.xlarge / p3.2xlarge
_PRICES = {"k80": (0.90, 0.27), "v100": (3.06, 0.918)}

# Spot fulfillment adds a capacity-evaluation step to provisioning and the
# AMI/EBS warm-up dominates staging.
_STAGES = {"k80": StartupStages(32.0, 31.0, 12.0, 9.0),
           "v100": StartupStages(36.0, 34.0, 12.0, 12.0)}


class AWSSpot(FleetProvider):
    name = "aws"
    display_name = "AWS EC2 Spot"
    warning_seconds = 120.0       # the 2-minute interruption notice
    max_lifetime_hours = math.inf
    graceful_checkpoint_on_warning = True
    default_region = "us-east-1"

    def __init__(self):
        self._laws = {key: PriceSignalLifetime(key[0], key[1], *params)
                      for key, params in SPOT_MARKETS.items()}

    def offerings(self) -> Tuple[Offering, ...]:
        return tuple(Offering(r, g) for (r, g) in SPOT_MARKETS)

    def lifetime_model(self, region: str, gpu: str) -> LifetimeLaw:
        self.check_offered(region, gpu)
        return self._laws[(region, gpu)]

    def startup_stages(self, gpu: str) -> StartupStages:
        return _STAGES[gpu]

    def replacement_anchors(self) -> ReplacementAnchors:
        # heavier base image pull than GCP's minimal images, same
        # graph-setup complexity slope (framework-side, cloud-agnostic)
        return ReplacementAnchors(82.4, 16.1, 0.72)

    def price(self, gpu: str, transient: bool = True) -> float:
        od, spot = _PRICES[gpu]
        return spot if transient else od


AWS = register_provider(AWSSpot())
