"""`FleetProvider` — the per-cloud contract behind CM-DARE's fleet models.

The paper measured one market (GCP preemptible, §V); everything the
measurement loop calibrated there — which (region, GPU) cells exist, how
long servers live before revocation, how long they take to start and to
rejoin a job, and what they cost per hour — is exactly what differs between
transient markets. A `FleetProvider` owns those five things, so the Eq (4)/
(5) machinery, the launch planner and the fleet simulator run unchanged on
any market (docs/providers.md walks through adding one).

Contract summary (docs/DESIGN.md §5):

  offerings()            which (region, gpu) cells the market sells
  lifetime_model(r, g)   a `LifetimeLaw` for that cell (revocation CDF)
  startup_stages(g)      provisioning/staging/running stage means (§V-B)
  replacement_anchors()  cold/warm rejoin-time anchors (Fig 10)
  price(g)               hourly $ (transient and on-demand)

plus three scalars that shape simulation semantics: `warning_seconds`
(revocation notice length), `max_lifetime_hours` (GCP's 24 h cap; `inf`
for uncapped markets) and `graceful_checkpoint_on_warning` (whether the
runtime is assumed to flush a checkpoint inside the notice window — the
paper observed stock frameworks do NOT react to GCP's 30 s notice).
"""
from __future__ import annotations

import abc
import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np


class LifetimeLaw(abc.ABC):
    """Distribution of one (region, gpu) cell's transient-server lifetime.

    `sample` returns hours, with `np.inf` meaning "survived the sampling
    horizon" (the 24 h cap on GCP; a soft horizon on uncapped markets).
    """

    @abc.abstractmethod
    def cdf(self, t_hours: np.ndarray) -> np.ndarray:
        """P(lifetime <= t) for an array of horizons (hours)."""

    @abc.abstractmethod
    def prob_revoked_within(self, t_hours: float) -> float:
        """Pr(R_i) for Eq (5): probability of revocation within t_hours."""

    @abc.abstractmethod
    def sample(self, rng: np.random.Generator, n: int = 1,
               start_hour: float = 0.0) -> np.ndarray:
        """Sample lifetimes (hours); np.inf = survived the horizon."""

    def sample_batch(self, rng: np.random.Generator, n: int,
                     start_hour: float = 0.0) -> np.ndarray:
        """Batched sampling for the Monte-Carlo engine. The default
        delegates to `sample`, which every built-in adapter already
        implements as a vectorized draw; override only when the batched
        path differs from the scalar one (e.g. GCP's diurnal thinning)."""
        return self.sample(rng, int(n), start_hour)

    #: Columns of the pre-drawn uniform block `sample_from_uniforms`
    #: may consume per lifetime (the fleet engines pre-draw
    #: (trajectories, slots, SAMPLE_UNIFORMS_K) pools per replacement
    #: generation).
    SAMPLE_UNIFORMS_K: int = 33

    #: Optional vectorized sampler from pre-drawn uniforms — the fleet
    #: engines' replacement-join path (fleet_batched.FleetDraws). Set to
    #: a method `(U: (m, K) uniforms, start_hours: (m,) local hours) ->
    #: (m,) lifetimes` that is a *deterministic function of U* with the
    #: same distribution as `sample` (the draw path may differ, e.g.
    #: inverse-transform instead of ziggurat exponentials), vectorized
    #: over per-sample start hours. Leave as None and the engines fall
    #: back to one counter-based RNG stream per replacement — correct
    #: for any custom law, just slower.
    sample_from_uniforms = None

    @abc.abstractmethod
    def mean_time_to_revocation(self) -> float:
        """Conditional mean lifetime of revoked servers (hours)."""

    # ------------------------------------------- Estimator-protocol surface
    def residuals(self, lifetimes_h) -> np.ndarray:
        """Fit residuals against observed lifetimes: for each finite
        observation, empirical CDF minus model CDF at that point (signed;
        positive = the law under-predicts early revocations). The
        calibration layer uses these to decide whether a law still
        matches the market it was fit on."""
        lt = np.asarray(lifetimes_h, float)
        finite = np.sort(lt[np.isfinite(lt)])
        if finite.size == 0:
            return np.empty(0)
        # Hazen plotting positions for the empirical CDF, scaled by the
        # finite fraction so the survival mass is accounted for
        emp = (np.arange(1, finite.size + 1) - 0.5) / lt.size
        return emp - np.asarray(self.cdf(finite), float)

    def score(self, lifetimes_h) -> Dict[str, float]:
        """Goodness-of-fit summary over `residuals` (Estimator protocol)."""
        r = self.residuals(lifetimes_h)
        if r.size == 0:
            raise ValueError("LifetimeLaw.score: no finite lifetimes")
        return {"n": int(r.size), "mae": float(np.abs(r).mean()),
                "max_abs": float(np.abs(r).max())}

    def params_hash(self) -> str:
        """Stable digest of the law's fitted parameters. The default
        hashes every public scalar/array field in name order; laws with
        non-field state (hazard grids, caches) override this."""
        from repro.calibration.estimator import params_hash as _phash
        parts: list = [type(self).__name__]
        fields = (dataclasses.fields(self)
                  if dataclasses.is_dataclass(self) else None)
        names = ([f.name for f in fields] if fields is not None
                 else sorted(k for k in vars(self) if not k.startswith("_")))
        for name in names:
            v = getattr(self, name)
            if isinstance(v, (str, int, float, np.ndarray)):
                parts.extend([name, v])
        return _phash(*parts)


@dataclasses.dataclass(frozen=True)
class Offering:
    """One sellable (region, gpu) cell of a transient market."""
    region: str
    gpu: str


@dataclasses.dataclass(frozen=True)
class StartupStages:
    """§V-B startup decomposition: mean seconds per stage for transient
    servers, plus how much faster the on-demand staging stage is."""
    provisioning: float
    staging: float
    running: float
    ondemand_staging_discount: float = 0.0

    def means(self, transient: bool = True) -> Tuple[float, float, float]:
        s = self.staging
        if not transient:
            s = max(5.0, s - self.ondemand_staging_discount)
        return self.provisioning, s, self.running


@dataclasses.dataclass(frozen=True)
class ReplacementAnchors:
    """Fig 10 rejoin-overhead anchors: seconds = base + slope * C_m."""
    cold_base: float
    warm_base: float
    complexity_slope: float

    def cold_start_s(self, c_m_gflops: float) -> float:
        return self.cold_base + self.complexity_slope * c_m_gflops

    def warm_start_s(self, c_m_gflops: float) -> float:
        return self.warm_base + 0.5 * self.complexity_slope * c_m_gflops


class FleetProvider(abc.ABC):
    """One transient-GPU market: offerings, lifetimes, startup, pricing."""

    #: registry key (``--provider`` value), e.g. ``"gcp"``
    name: str = ""
    #: human-readable market name for reports
    display_name: str = ""
    #: seconds of revocation notice the market gives
    warning_seconds: float = 0.0
    #: hard lifetime cap in hours (math.inf when the market has none)
    max_lifetime_hours: float = math.inf
    #: whether the runtime checkpoints inside the warning window when the
    #: notice is long enough (>= T_c); False reproduces the paper's stock
    #: behavior of ignoring the notice
    graceful_checkpoint_on_warning: bool = False
    #: region used when a caller does not pick one
    default_region: str = ""

    # ------------------------------------------------------------- catalog
    @abc.abstractmethod
    def offerings(self) -> Tuple[Offering, ...]:
        """Every sellable (region, gpu) cell."""

    def regions_offering(self, gpu: str) -> List[str]:
        return sorted({o.region for o in self.offerings() if o.gpu == gpu})

    def gpus(self) -> List[str]:
        return sorted({o.gpu for o in self.offerings()})

    def is_offered(self, region: str, gpu: str) -> bool:
        # cached: this sits in the MC-planner/simulator hot loop (one
        # check per lifetime sample); the catalog is immutable
        cache = getattr(self, "_offerings_cache", None)
        if cache is None:
            cache = frozenset(self.offerings())
            self._offerings_cache = cache
        return Offering(region, gpu) in cache

    def check_gpu_offered(self, gpu: str) -> None:
        """Raise ValueError naming this market's GPUs when `gpu` is sold
        in no region (the single source of that error message)."""
        if not self.regions_offering(gpu):
            raise ValueError(
                f"{self.display_name or self.name} does not offer {gpu!r}; "
                f"available GPUs: {self.gpus()}")

    def check_offered(self, region: str, gpu: str) -> None:
        """Raise ValueError naming the alternatives when a cell is not
        sold — mirrors Session._check_fleet's error style."""
        if self.is_offered(region, gpu):
            return
        self.check_gpu_offered(gpu)
        raise ValueError(
            f"({region!r}, {gpu!r}) is not offered by "
            f"{self.display_name or self.name}; regions with {gpu}: "
            f"{self.regions_offering(gpu)}")

    # -------------------------------------------------------------- models
    @abc.abstractmethod
    def lifetime_model(self, region: str, gpu: str) -> LifetimeLaw:
        """The revocation-lifetime law of one offered cell."""

    @abc.abstractmethod
    def startup_stages(self, gpu: str) -> StartupStages:
        """§V-B provisioning/staging/running stage means for `gpu`."""

    @abc.abstractmethod
    def replacement_anchors(self) -> ReplacementAnchors:
        """Fig 10 cold/warm rejoin anchors for this market's images."""

    # ------------------------------------------------------------- pricing
    @abc.abstractmethod
    def price(self, gpu: str, transient: bool = True) -> float:
        """Hourly price per server ($/h)."""

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<FleetProvider {self.name}>"


def conditional_mean_from_cdf(cdf, p_total: float,
                              horizon_hours: float) -> float:
    """Mean lifetime of revoked servers from a CDF: E[T | T <= horizon],
    shared by adapters whose laws have no closed-form mean."""
    ts = np.linspace(0.0, horizon_hours, 2000)
    c = np.asarray(cdf(ts), float) / max(p_total, 1e-12)
    return float(np.trapezoid(1.0 - np.clip(c, 0.0, 1.0), ts))
