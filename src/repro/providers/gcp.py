"""GCP preemptible adapter — the paper's measured market, verbatim.

This adapter owns no numbers of its own: it re-exposes the Table V /
Fig 8-9 lifetime calibrations (`core/transient/revocation.py`), the Fig 6
startup stage means, the Fig 10 replacement anchors and the 2019-era GCP
price sheet (`core/perf_model/features.py`) through the `FleetProvider`
contract, so `provider="gcp"` (the default everywhere) is bit-for-bit the
pre-provider behavior: same objects, same RNG consumption, same outputs.
"""
from __future__ import annotations

from typing import Tuple

from repro.core.perf_model.features import GPU_SPECS
from repro.core.transient.replacement import (_COLD_BASE, _COMPLEXITY_SLOPE,
                                              _WARM_BASE)
from repro.core.transient.revocation import (MAX_LIFETIME_H,
                                             REGION_GPU_PARAMS, TABLE5_RATES)
from repro.core.transient.startup import _ONDEMAND_DISCOUNT, _STAGE_MEANS
from repro.providers.base import (FleetProvider, LifetimeLaw, Offering,
                                  ReplacementAnchors, StartupStages)
from repro.providers.registry import register_provider

# The calibrated LifetimeModel predates the provider layer and must stay
# import-cycle-free, so it satisfies LifetimeLaw structurally; register it
# as a virtual subclass for isinstance-based checks.
from repro.core.transient.revocation import LifetimeModel
LifetimeLaw.register(LifetimeModel)


class GCPPreemptible(FleetProvider):
    name = "gcp"
    display_name = "GCP preemptible"
    warning_seconds = 30.0        # ACPI G2 soft-off notice
    max_lifetime_hours = MAX_LIFETIME_H
    # §V finding: stock frameworks do not react to the preemption notice
    graceful_checkpoint_on_warning = False
    default_region = "us-central1"

    def offerings(self) -> Tuple[Offering, ...]:
        return tuple(Offering(r, g) for (r, g), rate in TABLE5_RATES.items()
                     if rate is not None)

    def lifetime_model(self, region: str, gpu: str) -> LifetimeLaw:
        self.check_offered(region, gpu)
        # the exact calibrated LifetimeModel instances — not copies — so
        # sampling consumes the RNG identically to the pre-provider code
        return REGION_GPU_PARAMS[(region, gpu)]

    def startup_stages(self, gpu: str) -> StartupStages:
        p, s, r = _STAGE_MEANS[gpu]
        return StartupStages(p, s, r, _ONDEMAND_DISCOUNT[gpu])

    def replacement_anchors(self) -> ReplacementAnchors:
        return ReplacementAnchors(_COLD_BASE, _WARM_BASE, _COMPLEXITY_SLOPE)

    def price(self, gpu: str, transient: bool = True) -> float:
        spec = GPU_SPECS[gpu]
        return spec.transient_price if transient else spec.hourly_price


GCP = register_provider(GCPPreemptible())
