"""Azure low-priority (spot) VM adapter.

What changes relative to the paper's GCP market (docs/providers.md):

* **Eviction-rate tiers** — Azure publishes per-(region, size) eviction
  rates in coarse buckets rather than continuous market prices; each
  offered cell is assigned a tier and modeled as a *memoryless* constant
  hazard (exponential lifetime) matching the tier's 24 h eviction
  probability. No diurnal structure: capacity-triggered evictions follow
  datacenter load balancing, not a visible price signal.
* **No lifetime cap** — like AWS and unlike GCP's 24 h ceiling.
* **30 s eviction notice** (Scheduled Events) — same length as GCP's, but
  delivered through a queryable metadata endpoint that checkpoint hooks
  poll, so the runtime is assumed to use it when T_c fits in the window.

Catalog: NC6 (K80), NC6s_v2 (P100), NC6s_v3 (V100) across four regions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import numpy as np

from repro.providers.base import (FleetProvider, LifetimeLaw, Offering,
                                  ReplacementAnchors, StartupStages,
                                  conditional_mean_from_cdf)
from repro.providers.registry import register_provider

# Eviction-rate tiers: portal bucket label -> P(evicted within 24 h).
EVICTION_TIERS: Dict[str, float] = {
    "0-5%": 0.05, "5-10%": 0.10, "10-15%": 0.15,
    "15-20%": 0.20, "20%+": 0.30,
}

AZURE_HORIZON_H = 168.0


@dataclasses.dataclass
class TieredEvictionLifetime(LifetimeLaw):
    """Constant-hazard (exponential) lifetime from an eviction-rate tier."""
    region: str
    gpu: str
    tier: str
    horizon_h: float = AZURE_HORIZON_H

    def __post_init__(self):
        self.p24 = EVICTION_TIERS[self.tier]
        self.hazard_per_h = -math.log(1.0 - self.p24) / 24.0

    def cdf(self, t_hours: np.ndarray) -> np.ndarray:
        # saturate at the sampling horizon so the closed form agrees with
        # sample()'s "inf = survived the horizon" convention (Eq (5)
        # predictions vs MC/simulation consistency)
        t = np.minimum(np.asarray(t_hours, float), self.horizon_h)
        return 1.0 - np.exp(-self.hazard_per_h * t)

    def prob_revoked_within(self, t_hours: float) -> float:
        return float(self.cdf(np.array([t_hours]))[0])

    def sample(self, rng: np.random.Generator, n: int = 1,
               start_hour: float = 0.0) -> np.ndarray:
        # memoryless: start_hour is irrelevant by construction
        t = rng.exponential(1.0 / self.hazard_per_h, size=n)
        return np.where(t > self.horizon_h, np.inf, t)

    def params_hash(self) -> str:
        # override the LifetimeLaw default: the tier resolves to the
        # fitted (p24, hazard) pair — hash those, not just the label
        from repro.calibration.estimator import params_hash
        return params_hash("tiered_eviction", self.region, self.gpu,
                           self.tier, self.horizon_h, self.p24,
                           self.hazard_per_h)

    #: single-column consumption: one uniform through the inverse
    #: exponential CDF (keeps the engines' pre-drawn pools minimal)
    SAMPLE_UNIFORMS_K = 1

    def sample_from_uniforms(self, U: np.ndarray,
                             start_hours: np.ndarray) -> np.ndarray:
        """Fleet-engine replacement-join sampler (LifetimeLaw contract):
        inverse-transform exponential of column 0 — same distribution as
        `sample`'s ziggurat draw, deterministic in the uniform block.
        Memoryless, so `start_hours` is irrelevant by construction."""
        U = np.atleast_2d(np.asarray(U, float))
        t = -np.log(1.0 - U[:, 0]) / self.hazard_per_h
        return np.where(t > self.horizon_h, np.inf, t)

    def mean_time_to_revocation(self) -> float:
        p_h = self.prob_revoked_within(self.horizon_h)
        return conditional_mean_from_cdf(self.cdf, p_h, self.horizon_h)


# (region, gpu) -> eviction tier. GPU capacity is scarcest in eastus;
# southeastasia NC pools are small and churn the most.
LP_MARKETS: Dict[Tuple[str, str], str] = {
    ("eastus", "k80"): "10-15%",
    ("eastus", "p100"): "15-20%",
    ("eastus", "v100"): "20%+",
    ("southcentralus", "k80"): "5-10%",
    ("southcentralus", "p100"): "10-15%",
    ("southcentralus", "v100"): "15-20%",
    ("westeurope", "k80"): "0-5%",
    ("westeurope", "p100"): "5-10%",
    ("westeurope", "v100"): "10-15%",
    ("southeastasia", "k80"): "15-20%",
    ("southeastasia", "v100"): "20%+",
}

# per-GPU-server $/h: (pay-as-you-go, low-priority) — NC6 / NC6s_v2 / v3
_PRICES = {"k80": (0.90, 0.18), "p100": (2.07, 0.414),
           "v100": (3.06, 0.612)}

# Azure VM allocation is the slow stage (fabric placement), staging is
# comparable to GCP; low-priority adds allocation retries.
_STAGES = {"k80": StartupStages(41.0, 36.0, 15.0, 10.0),
           "p100": StartupStages(43.0, 40.0, 15.0, 14.0),
           "v100": StartupStages(45.0, 42.0, 15.0, 15.0)}


class AzureLowPriority(FleetProvider):
    name = "azure"
    display_name = "Azure low-priority"
    warning_seconds = 30.0        # Scheduled Events eviction notice
    max_lifetime_hours = math.inf
    graceful_checkpoint_on_warning = True
    default_region = "southcentralus"

    def __init__(self):
        self._laws = {key: TieredEvictionLifetime(key[0], key[1], tier)
                      for key, tier in LP_MARKETS.items()}

    def offerings(self) -> Tuple[Offering, ...]:
        return tuple(Offering(r, g) for (r, g) in LP_MARKETS)

    def lifetime_model(self, region: str, gpu: str) -> LifetimeLaw:
        self.check_offered(region, gpu)
        return self._laws[(region, gpu)]

    def eviction_tier(self, region: str, gpu: str) -> str:
        self.check_offered(region, gpu)
        return LP_MARKETS[(region, gpu)]

    def startup_stages(self, gpu: str) -> StartupStages:
        return _STAGES[gpu]

    def replacement_anchors(self) -> ReplacementAnchors:
        # managed-disk reattach makes cold rejoin slowest of the three
        return ReplacementAnchors(88.9, 17.5, 0.72)

    def price(self, gpu: str, transient: bool = True) -> float:
        payg, lp = _PRICES[gpu]
        return lp if transient else payg


AZURE = register_provider(AzureLowPriority())
