"""Provider registry: name -> FleetProvider singleton.

Adapters self-register at import time (repro.providers.__init__ imports
them all), so `get_provider("gcp"|"aws"|"azure")` works out of the box and
third-party adapters only need a `register_provider` call.
"""
from __future__ import annotations

from typing import Dict, List, Union

from repro.providers.base import FleetProvider

_REGISTRY: Dict[str, FleetProvider] = {}

ProviderLike = Union[str, FleetProvider]


def register_provider(provider: FleetProvider) -> FleetProvider:
    """Register (or replace) a provider under `provider.name`."""
    if not provider.name:
        raise ValueError("provider.name must be a non-empty registry key")
    _REGISTRY[provider.name] = provider
    return provider


def available_providers() -> List[str]:
    return sorted(_REGISTRY)


def get_provider(provider: ProviderLike) -> FleetProvider:
    """Resolve a registry name to its provider; FleetProvider instances
    pass through, so every `provider=` parameter takes either form."""
    if isinstance(provider, FleetProvider):
        return provider
    if provider not in _REGISTRY:
        raise KeyError(f"unknown provider {provider!r}; "
                       f"known: {available_providers()}")
    return _REGISTRY[provider]
