"""Replica state machine + the revocable fleet model behind it.

A serving replica lives on a transient instance: it is ACTIVE (admitting
and decoding), DRAINING (a revocation notice arrived — it finishes what
it holds but admits nothing new), or DOWN (revoked; a replacement is
provisioning). The invariant the property tests pin: **a replica admits
if and only if it is ACTIVE** — a drained or down replica never takes a
request, however briefly.

`ReplicaSet` compiles the fleet against a provider exactly the way the
training `FleetSim` does: per-(trajectory, slot, generation) lifetimes
from keyed counter-based streams (bit-identical whichever engine asks,
in whatever order), optionally thinned by a chaos `FaultTimeline`'s
hazard windows, and a deterministic replacement delay from the §V-B
`StartupModel` stage means.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

ACTIVE, DRAINING, DOWN = "active", "draining", "down"

#: stream tag for replica lifetime draws (cf. injectors._TAG_INITIAL)
_TAG_LIFETIME = 0x5EF1E


@dataclasses.dataclass
class Replica:
    """One serving slot's current incarnation."""
    slot: int
    gen: int = 0
    status: str = ACTIVE
    joined_s: float = 0.0
    death_s: float = math.inf     # revocation instant
    drain_s: float = math.inf     # notice instant (death - warning), if any
    rejoin_s: float = math.inf    # replacement join instant while DOWN
    drained: bool = False         # notice already processed

    def can_admit(self) -> bool:
        """The admission invariant: ACTIVE only — never while draining,
        never while down."""
        return self.status == ACTIVE

    def start_drain(self) -> None:
        if self.status == ACTIVE:
            self.status = DRAINING
        self.drained = True

    def kill(self, now: float, startup_s: float) -> None:
        self.status = DOWN
        self.rejoin_s = now + startup_s

    def rejoin(self, now: float, lifetime_s: float,
               warning_s: float) -> None:
        self.gen += 1
        self.status = ACTIVE
        self.joined_s = now
        self.death_s = now + lifetime_s
        # clamp to `now`: a replacement living shorter than the warning
        # window must not schedule its drain notice in the past
        self.drain_s = (max(now, self.death_s - warning_s)
                        if warning_s > 0 else math.inf)
        self.rejoin_s = math.inf
        self.drained = False


class ReplicaSet:
    """`n` replicas on one provider's (region, gpu) cell.

    Owns the keyed lifetime streams and the chaos thinning so the event
    and batched simulator engines consume identical revocation times.
    `seed` is the scenario seed (not the per-trajectory one) — the same
    convention as `FaultTimeline`.
    """

    def __init__(self, n: int, provider, region: Optional[str] = None,
                 gpu: str = "v100", seed: int = 0, chaos=None):
        from repro.core.transient.startup import StartupModel
        from repro.providers import get_provider

        if n < 1:
            raise ValueError(f"need at least one replica, got {n}")
        self.n = int(n)
        self.provider = get_provider(provider)
        self.region = region or self.provider.default_region
        self.gpu = gpu
        self.provider.check_offered(self.region, gpu)
        self.seed = int(seed) % (2 ** 32)
        self.law = self.provider.lifetime_model(self.region, gpu)
        #: deterministic replacement delay (mean of the §V-B stages) —
        #: stochastic startup would add nothing to the serving story but
        #: would complicate the two-engine parity contract
        self.startup_s = StartupModel(seed, self.provider).mean_total(gpu)
        self.warning_s = float(self.provider.warning_seconds)
        self.price_per_h = self.provider.price(gpu)
        self.chaos = chaos

    # ------------------------------------------------------------- roster
    def roster(self) -> List[Tuple[int, str, str, float]]:
        """(wid, gpu, region, speed) tuples — the `FaultTimeline` shape."""
        return [(i, self.gpu, self.region, 1.0) for i in range(self.n)]

    # ---------------------------------------------------------- lifetimes
    def _raw_lifetime_h(self, traj: int, slot: int, gen: int,
                        start_hour: float) -> float:
        rng = np.random.default_rng(np.random.SeedSequence(
            (self.seed, _TAG_LIFETIME, int(traj), int(slot), int(gen))))
        return float(self.law.sample(rng, 1, start_hour=start_hour)[0])

    def initial_lifetimes_h(self, n_traj: int) -> np.ndarray:
        """(n_traj, n) hour matrix for generation 0, chaos-thinned. Drawn
        per (traj, slot) keyed stream, then transformed once as a matrix
        — `FaultTimeline.transform_initial`'s contract."""
        lt = np.array([[self._raw_lifetime_h(tj, sl, 0, 0.0)
                        for sl in range(self.n)] for tj in range(n_traj)])
        if self.chaos is not None:
            lt = self.chaos.transform_initial(lt)
        return lt

    def replacement_lifetime_h(self, traj: int, slot: int, gen: int,
                               elapsed_h: float) -> float:
        """One replacement's lifetime (hours), chaos-thinned at its join
        time. Keyed per (traj, slot, gen): identical whichever engine
        asks first."""
        lt = self._raw_lifetime_h(traj, slot, gen, elapsed_h % 24.0)
        if self.chaos is not None:
            lt = float(self.chaos.transform_joins(
                np.array([lt]), np.array([traj]), np.array([slot]),
                np.array([gen]), np.array([elapsed_h]))[0])
        return lt

    def fresh(self, traj: int, lifetimes_h: np.ndarray,
              warned: bool) -> List[Replica]:
        """Generation-0 replicas for one trajectory. `warned` arms the
        drain notice (resilience on a market that gives warnings)."""
        out = []
        for sl in range(self.n):
            death = float(lifetimes_h[sl]) * 3600.0
            r = Replica(slot=sl, death_s=death)
            if warned and self.warning_s > 0 and math.isfinite(death):
                r.drain_s = max(0.0, death - self.warning_s)
            out.append(r)
        return out
