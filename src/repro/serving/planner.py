"""Serving fleet planner: score (replica_count, provider, region) cells
against a latency SLO with the batched serving simulator.

The serving analogue of `core.scheduler.plan_launch`: instead of asking
"which (region, launch-hour) finishes N training steps cheapest", it asks
"which fleet shape serves this request stream inside the p99 SLO at the
lowest $/1k completed requests". Every cell is scored by a full
`ServingFleetSim` ensemble — realized pooled p50/p99 latency, shed and
drop fractions, revocation counts and replica-hours cost — so the ranking
prices in each market's revocation law and warning contract, not just its
hourly rate.

Ranking is SLO-first, then cheapest: cells meeting the SLO sort above
cells that miss it, and within each group by $/1k completed requests
(ties: lower p99, fewer replicas, then provider/region name — fully
deterministic, which the pinned golden-ranking test relies on).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.degradation import ServingDegradationPolicy
from repro.serving.replica import ReplicaSet
from repro.serving.simulator import (ServingFleetSim, ServingWorkload,
                                     summarize_serving)


@dataclasses.dataclass(frozen=True)
class ServingSLO:
    """What the fleet owes the workload."""
    p99_latency_s: float = 10.0
    max_shed_frac: float = 0.1        # admission-control 429s tolerated
    max_drop_frac: float = 0.0        # in-flight losses tolerated


@dataclasses.dataclass
class ServingPlan:
    """One scored (replicas, provider, region) cell."""
    provider: str
    region: str
    gpu: str
    replicas: int
    meets_slo: bool
    latency_p50_s: float
    latency_p99_s: float
    completed_frac: float
    shed_frac: float
    drop_frac: float
    cost_per_1k: float                # $ per 1k completed requests
    expected_cost: float              # mean replica-hours $ per trajectory
    revocations: float
    samples: int
    token_time_s: float


def _score_cell(workload: ServingWorkload, slo: ServingSLO, *,
                replicas: int, provider: str, region: Optional[str],
                gpu: str, token_time_s: float, batch_ceiling: int,
                policy: Optional[ServingDegradationPolicy],
                resilience, horizon_s: float, samples: int,
                seed: int) -> ServingPlan:
    rset = ReplicaSet(replicas, provider, region=region, gpu=gpu,
                      seed=seed)
    sim = ServingFleetSim(rset, workload, policy=policy,
                          resilience=resilience,
                          token_time_s=token_time_s,
                          batch_ceiling=batch_ceiling,
                          horizon_s=horizon_s, seed=seed)
    results = sim.run_many(samples, engine="batched")
    n = max(workload.n_requests, 1)
    lat = np.concatenate([r.latencies_s for r in results]) \
        if results else np.empty(0)
    p50 = float(np.percentile(lat, 50)) if lat.size else math.inf
    p99 = float(np.percentile(lat, 99)) if lat.size else math.inf
    completed = float(np.mean([r.completed for r in results]))
    shed = float(np.mean([r.shed for r in results]))
    drop = float(np.mean([r.dropped_inflight for r in results]))
    cost = float(np.mean([r.cost for r in results]))
    cost_1k = cost / completed * 1000.0 if completed > 0 else math.inf
    meets = (p99 <= slo.p99_latency_s
             and shed / n <= slo.max_shed_frac
             and drop / n <= slo.max_drop_frac)
    return ServingPlan(
        provider=rset.provider.name, region=rset.region, gpu=gpu,
        replicas=replicas, meets_slo=meets,
        latency_p50_s=round(p50, 6), latency_p99_s=round(p99, 6),
        completed_frac=round(completed / n, 6),
        shed_frac=round(shed / n, 6), drop_frac=round(drop / n, 6),
        cost_per_1k=round(cost_1k, 6), expected_cost=round(cost, 6),
        revocations=round(float(np.mean([r.revocations
                                         for r in results])), 6),
        samples=samples, token_time_s=round(token_time_s, 9))


def plan_serving(workload: ServingWorkload,
                 slo: Optional[ServingSLO] = None, *,
                 replica_counts: Sequence[int] = (2, 4, 8),
                 providers: Sequence[str] = ("gcp", "aws"),
                 regions: Optional[Sequence[Optional[str]]] = None,
                 gpu: str = "v100",
                 token_time_s: float = 0.05,
                 batch_ceiling: int = 8,
                 policy: Optional[ServingDegradationPolicy] = None,
                 resilience=None,
                 horizon_s: float = 3600.0,
                 samples: int = 8,
                 seed: int = 0
                 ) -> Tuple[ServingPlan, List[ServingPlan]]:
    """Score the grid and return (best, all plans ranked best-first).

    `regions=None` scores each provider's default region (the grid stays
    small and every ensemble is a real simulation); pass explicit region
    names to widen it. Unoffered (provider, region, gpu) cells are
    skipped rather than failing the whole sweep.
    """
    slo = slo or ServingSLO()
    plans: List[ServingPlan] = []
    for prov in providers:
        for region in (regions if regions is not None else [None]):
            for n in replica_counts:
                try:
                    plans.append(_score_cell(
                        workload, slo, replicas=n, provider=prov,
                        region=region, gpu=gpu,
                        token_time_s=token_time_s,
                        batch_ceiling=batch_ceiling, policy=policy,
                        resilience=resilience, horizon_s=horizon_s,
                        samples=samples, seed=seed))
                except ValueError:
                    continue        # (region, gpu) not offered there
    if not plans:
        raise ValueError("no (replicas, provider, region) cell offers "
                         f"gpu {gpu!r}")
    plans.sort(key=lambda p: (not p.meets_slo, p.cost_per_1k,
                              p.latency_p99_s, p.replicas, p.provider,
                              p.region))
    return plans[0], plans
