"""Bounded admission queue with deadline-aware load shedding.

The gateway's front door (docs/serving.md): a request is *admitted* when
the queue has room, waits FIFO within its priority class, and is *shed*
(the HTTP-429 analogue) when the queue is full on arrival or when its
queue time exceeds `queue_budget_s` before a replica picks it up — a
request the user would have abandoned anyway is never dispatched.

Shedding on budget expiry records the expiry instant (`enqueued_s +
budget`), not the instant the expiry was noticed, so scorecards are
independent of when the engine happened to look — the same
order-independence contract the fleet engines' keyed draws follow.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.serving.requests import Request


class AdmissionQueue:
    """FIFO-within-priority bounded queue (priority 0 pops first)."""

    def __init__(self, capacity: int = 64,
                 queue_budget_s: float = math.inf) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.queue_budget_s = float(queue_budget_s)
        self._by_prio: Dict[int, deque] = {}
        self._size = 0
        #: (request, reason, shed_time) terminal shed records
        self.shed: List[Tuple[Request, str, float]] = []

    def __len__(self) -> int:
        return self._size

    # ----------------------------------------------------------- admission
    def offer(self, req: Request, now: float) -> bool:
        """Admit `req` or shed it with reason ``queue_full``."""
        if self._size >= self.capacity:
            self.shed.append((req, "queue_full", now))
            return False
        self._enqueue(req, now, front=False)
        return True

    def requeue_front(self, req: Request, now: float) -> None:
        """Hand a revoked replica's in-flight request back to the head of
        its priority class. Handovers bypass the capacity bound — the
        request was already admitted once; bouncing it now would turn a
        *warned* revocation into a drop."""
        self._enqueue(req, now, front=True)

    def _enqueue(self, req: Request, now: float, front: bool) -> None:
        req.enqueued_s = now
        req.deadline_s = now + self.queue_budget_s
        dq = self._by_prio.setdefault(req.priority, deque())
        (dq.appendleft if front else dq.append)(req)
        self._size += 1

    # ------------------------------------------------------------ dispatch
    def pop(self, now: float) -> Optional[Request]:
        """Next dispatchable request (highest class, FIFO inside it),
        shedding every expired request encountered on the way."""
        self.shed_expired(now)
        for prio in sorted(self._by_prio):
            dq = self._by_prio[prio]
            if dq:
                self._size -= 1
                return dq.popleft()
        return None

    def shed_expired(self, now: float) -> int:
        """Shed every queued request whose budget expired by `now`;
        returns how many. Shed time is the expiry instant."""
        n = 0
        for dq in self._by_prio.values():
            keep = deque()
            while dq:
                req = dq.popleft()
                if now > req.deadline_s:
                    self.shed.append((req, "queue_budget", req.deadline_s))
                    self._size -= 1
                    n += 1
                else:
                    keep.append(req)
            dq.extend(keep)
        return n

    def next_deadline(self) -> float:
        """Earliest budget expiry among queued requests (inf when none) —
        the simulator's shed-event candidate."""
        return min((req.deadline_s for dq in self._by_prio.values()
                    for req in dq), default=math.inf)

    def drain(self) -> List[Request]:
        """Remove and return everything still queued (end-of-run sweep)."""
        out = [req for prio in sorted(self._by_prio)
               for req in self._by_prio[prio]]
        self._by_prio.clear()
        self._size = 0
        return out
