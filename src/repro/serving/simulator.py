"""Revocation-tolerant serving-fleet simulator (docs/serving.md).

Models a continuous-batching inference fleet on revocable instances the
way `core.transient.fleet` models a training fleet: replicas decode in
fixed-cost *rounds* (one token per active request per round — the
continuous-batching cost model, where a decode iteration costs the same
whatever the occupancy up to the batch ceiling), requests wait in one
global `AdmissionQueue`, and the provider's `LifetimeLaw` decides when a
replica is revoked mid-flight.

Resilience semantics (armed = a `ResilienceConfig` is attached):

* **warned revocation** (AWS-style notice): the replica *drains* — it
  stops admitting at the notice and keeps decoding; whatever is still
  unfinished at the revocation hands over to survivors with its decode
  progress intact. Armed fleets drop zero in-flight requests on warned
  revocations — the serve_wave acceptance gate.
* **silent revocation** (GCP-style, stock frameworks ignore the notice):
  in-flight requests restart from scratch via requeue-with-retry — one
  `RetryPolicy.backoff` delay per attempt from keyed uniforms, dropped
  when attempts exhaust.
* **hedged re-dispatch**: a request in service past `hedge_timeout_s`
  (a straggling replica) is pulled back to the head of the queue and
  re-dispatched to a survivor.
* unarmed, every in-flight request on a revoked replica is dropped —
  warned or not.

Two engines, one trajectory core: ``engine="event"`` drives each
trajectory with a lazy-invalidation heap; ``engine="batched"`` recomputes
the candidate set as NumPy arrays and min-reduces. Both consume the same
keyed draws (`ReplicaSet` lifetimes, arrival/demand/priority streams,
retry jitter), so results agree within 1e-6 — the same parity contract
the training engines carry, enforced by the chaos runner's probe.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.degradation import ServingDegradationPolicy
from repro.serving.queue import AdmissionQueue
from repro.serving.replica import ACTIVE, DOWN, Replica, ReplicaSet
from repro.serving.requests import (COMPLETED, DROPPED, SHED, Request,
                                    RequestOutcome)

# keyed-stream tags (fixed forever; cf. chaos.injectors._TAG_INITIAL)
_TAG_ARRIVAL = 0x5E8A1
_TAG_DEMAND = 0x5E8A2
_TAG_PRIORITY = 0x5E8A3
_TAG_RETRY = 0x5E8A4

# event ranks — the (time, rank, idx) total order both engines share
_ROUND, _DRAIN, _DEATH, _JOIN, _ARRIVE, _REQUEUE, _HEDGE = range(7)


@dataclasses.dataclass(frozen=True)
class ServingWorkload:
    """One open-loop request stream: Poisson arrivals at
    `arrival_rate_per_s`, uniform token demands on
    [min_tokens, max_tokens], `high_priority_frac` of requests in
    priority class 0 (the rest class 1 — shed first under degradation)."""
    n_requests: int = 200
    arrival_rate_per_s: float = 2.0
    prompt_tokens: int = 32
    min_tokens: int = 8
    max_tokens: int = 32
    high_priority_frac: float = 0.25
    queue_capacity: int = 64
    queue_budget_s: float = 30.0
    hedge_timeout_s: float = 0.0           # 0 = hedging off


@dataclasses.dataclass(frozen=True)
class ServingScript:
    """A scenario's serving fleet, attached as `Scenario.serving`."""
    replicas: int = 4
    batch_ceiling: int = 8
    token_time_s: float = 0.05             # decode-round seconds at speed 1
    horizon_s: float = 3600.0
    workload: ServingWorkload = ServingWorkload()
    policy: ServingDegradationPolicy = ServingDegradationPolicy()


@dataclasses.dataclass
class ServingSimResult:
    """One trajectory's scorecard."""
    traj: int
    completed: int = 0
    shed_queue_full: int = 0
    shed_budget: int = 0
    shed_degraded: int = 0
    shed_horizon: int = 0
    dropped_inflight: int = 0
    dropped_warned: int = 0                # in-flight lost to WARNED revs
    handovers: int = 0
    requeues: int = 0
    hedges: int = 0
    revocations: int = 0
    warned_revocations: int = 0
    replacements: int = 0
    degraded_events: List[dict] = dataclasses.field(default_factory=list)
    recovery_cycles: int = 0               # degraded -> full transitions
    tokens_served: int = 0
    cost: float = 0.0
    total_time_s: float = 0.0
    latencies_s: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0))

    @property
    def shed(self) -> int:
        return (self.shed_queue_full + self.shed_budget
                + self.shed_degraded + self.shed_horizon)

    def latency_percentile(self, q: float) -> float:
        if self.latencies_s.size == 0:
            return math.inf
        return float(np.percentile(self.latencies_s, q))


def summarize_serving(results: List[ServingSimResult]) -> Dict[str, float]:
    """Ensemble means + pooled latency percentiles (JSON-serializable)."""
    lat = np.concatenate([r.latencies_s for r in results]) \
        if results else np.empty(0)
    mean = lambda f: round(float(np.mean([f(r) for r in results])), 6)
    return {
        "samples": len(results),
        "completed_mean": mean(lambda r: r.completed),
        "shed_mean": mean(lambda r: r.shed),
        "shed_degraded_mean": mean(lambda r: r.shed_degraded),
        "dropped_inflight_mean": mean(lambda r: r.dropped_inflight),
        "dropped_warned_total": int(sum(r.dropped_warned for r in results)),
        "handovers_mean": mean(lambda r: r.handovers),
        "requeues_mean": mean(lambda r: r.requeues),
        "hedges_mean": mean(lambda r: r.hedges),
        "revocations_mean": mean(lambda r: r.revocations),
        "replacements_mean": mean(lambda r: r.replacements),
        "recovery_cycles_mean": mean(lambda r: r.recovery_cycles),
        "degraded_events_mean": mean(lambda r: len(r.degraded_events)),
        "tokens_served_mean": mean(lambda r: r.tokens_served),
        "cost_mean": mean(lambda r: r.cost),
        "latency_p50_s": (round(float(np.percentile(lat, 50)), 6)
                          if lat.size else None),
        "latency_p99_s": (round(float(np.percentile(lat, 99)), 6)
                          if lat.size else None),
    }


class ServingDraws:
    """Keyed per-trajectory workload streams — identical on any engine."""

    def __init__(self, seed: int, workload: ServingWorkload, traj: int):
        self.seed = int(seed) % (2 ** 32)
        self.traj = int(traj)
        wl = workload
        n = wl.n_requests

        def stream(tag):
            return np.random.default_rng(
                np.random.SeedSequence((self.seed, tag, self.traj)))

        inter = (-np.log1p(-stream(_TAG_ARRIVAL).random(n))
                 / max(wl.arrival_rate_per_s, 1e-12))
        self.arrival_s = np.cumsum(inter)
        span = wl.max_tokens - wl.min_tokens + 1
        self.demand = (wl.min_tokens
                       + np.floor(stream(_TAG_DEMAND).random(n)
                                  * span).astype(int).clip(0, span - 1))
        self.priority = np.where(
            stream(_TAG_PRIORITY).random(n) < wl.high_priority_frac, 0, 1)

    def retry_u(self, rid: int, attempt: int) -> float:
        """Backoff-jitter uniform keyed per (traj, request, attempt)."""
        return float(np.random.default_rng(np.random.SeedSequence(
            (self.seed, _TAG_RETRY, self.traj, int(rid),
             int(attempt)))).random())


class _Entry:
    """One in-service request on a replica."""
    __slots__ = ("rid", "left", "hedge_s")

    def __init__(self, rid: int, left: int, hedge_s: float):
        self.rid, self.left, self.hedge_s = rid, left, hedge_s


class _Trajectory:
    """One trajectory's full state + event handlers. The two engine
    drivers differ ONLY in how they pick the next (time, rank, idx)."""

    def __init__(self, sim: "ServingFleetSim", traj: int,
                 lifetimes_h: np.ndarray):
        self.sim = sim
        self.traj = traj
        wl = sim.workload
        self.draws = ServingDraws(sim.seed, wl, traj)
        self.warned = sim.rset.warning_s > 0
        self.replicas = sim.rset.fresh(traj, lifetimes_h,
                                       warned=sim.armed and self.warned)
        n = sim.rset.n
        self.queue = AdmissionQueue(wl.queue_capacity, wl.queue_budget_s)
        self.active: List[List[_Entry]] = [[] for _ in range(n)]
        self.boarding: List[List[_Entry]] = [[] for _ in range(n)]
        self.round_end = [math.inf] * n
        self.entry_of: Dict[int, Tuple[_Entry, int]] = {}
        self.requests: Dict[int, Request] = {}
        self.served: Dict[int, int] = {}
        self.pending_requeue: Dict[int, float] = {}
        self.outcomes: Dict[int, RequestOutcome] = {}
        self.res = ServingSimResult(traj=traj)
        self.resolved = 0
        self.ai = 0
        self.tier = "full"
        self.spawned: List[Tuple[float, int, int]] = []
        # initial events
        if wl.n_requests:
            self.spawned.append((float(self.draws.arrival_s[0]), _ARRIVE, 0))
        for r in self.replicas:
            if math.isfinite(r.death_s):
                self.spawned.append((r.death_s, _DEATH, r.slot))
            if math.isfinite(r.drain_s):
                self.spawned.append((r.drain_s, _DRAIN, r.slot))

    # ------------------------------------------------------------ helpers
    def _speed(self, slot: int, t: float) -> float:
        tl = self.sim.rset.chaos
        if tl is None:
            return 1.0
        return float(tl.speed_mults(np.array([t]))[0, slot])

    def _round_time(self, slot: int, t: float) -> float:
        return self.sim.token_time_s / max(self._speed(slot, t), 1e-9)

    def _ceiling(self) -> int:
        return self.sim.policy.batch_ceiling(self.tier,
                                             self.sim.batch_ceiling)

    def _free(self, slot: int) -> int:
        return max(0, self._ceiling() - len(self.active[slot])
                   - len(self.boarding[slot]))

    def _finish(self, rid: int, status: str, t: float, reason: str = "",
                tokens: int = 0) -> None:
        req = self.requests[rid]
        self.outcomes[rid] = RequestOutcome(
            rid=rid, status=status, arrival_s=req.arrival_s, finished_s=t,
            priority=req.priority, tokens=tokens, reason=reason)
        self.resolved += 1

    def _sync_shed(self) -> None:
        """Move AdmissionQueue shed records into terminal outcomes."""
        while self.queue.shed:
            req, reason, t = self.queue.shed.pop(0)
            self._finish(req.rid, SHED, t, reason)
            if reason == "queue_full":
                self.res.shed_queue_full += 1
            else:
                self.res.shed_budget += 1

    def _retier(self, t: float) -> None:
        n_alive = sum(1 for r in self.replicas if r.status != DOWN)
        new = self.sim.policy.tier(n_alive, self.sim.rset.n)
        if new != self.tier:
            self.res.degraded_events.append(
                {"t_s": round(t, 6), "tier": new, "from": self.tier,
                 "alive": n_alive})
            if new == "full":
                self.res.recovery_cycles += 1
            self.tier = new

    # --------------------------------------------------------------- pump
    def _pump(self, t: float) -> None:
        """Dispatch queued requests onto admitting replicas (most free
        slots first; ties to the lowest slot). An idle replica starts a
        round immediately; a busy one boards the request for its next
        round boundary — token-level continuous batching."""
        self.queue.shed_expired(t)
        self._sync_shed()
        while len(self.queue):
            cands = [r for r in self.replicas
                     if r.can_admit() and self._free(r.slot) > 0]
            if not cands:
                break
            rep = max(cands, key=lambda r: (self._free(r.slot), -r.slot))
            req = self.queue.pop(t)
            self._sync_shed()
            if req is None:
                break
            if (self.sim.policy.sheds_low_priority(self.tier)
                    and req.priority > 0):
                self._finish(req.rid, SHED, t, "degraded")
                self.res.shed_degraded += 1
                continue
            cap = self.sim.policy.token_cap(self.tier, req.max_tokens)
            left = min(req.remaining, cap)
            hedge_s = (t + self.sim.workload.hedge_timeout_s
                       if self.sim.armed
                       and self.sim.workload.hedge_timeout_s > 0
                       else math.inf)
            e = _Entry(req.rid, left, hedge_s)
            self.entry_of[req.rid] = (e, rep.slot)
            if math.isfinite(hedge_s):
                self.spawned.append((hedge_s, _HEDGE, req.rid))
            if self.round_end[rep.slot] == math.inf:
                self.active[rep.slot].append(e)
                self.round_end[rep.slot] = t + self._round_time(rep.slot, t)
                self.spawned.append((self.round_end[rep.slot], _ROUND,
                                     rep.slot))
            else:
                self.boarding[rep.slot].append(e)

    # ------------------------------------------------------------ handlers
    def on_arrive(self, i: int, t: float) -> None:
        req = Request(rid=i, arrival_s=t,
                      prompt_tokens=self.sim.workload.prompt_tokens,
                      max_tokens=int(self.draws.demand[i]),
                      priority=int(self.draws.priority[i]))
        self.requests[i] = req
        self.served[i] = 0
        self.ai += 1
        if self.ai < self.sim.workload.n_requests:
            self.spawned.append((float(self.draws.arrival_s[self.ai]),
                                 _ARRIVE, self.ai))
        self.queue.offer(req, t)
        self._sync_shed()
        self._pump(t)

    def on_round(self, slot: int, t: float) -> None:
        still: List[_Entry] = []
        for e in self.active[slot]:
            e.left -= 1
            self.served[e.rid] += 1
            self.res.tokens_served += 1
            if e.left == 0:
                self.entry_of.pop(e.rid, None)
                req = self.requests[e.rid]
                req.remaining = 0
                self._finish(e.rid, COMPLETED, t, tokens=self.served[e.rid])
                self.res.completed += 1
            else:
                still.append(e)
        self.active[slot] = still + self.boarding[slot]
        self.boarding[slot] = []
        if self.active[slot]:
            self.round_end[slot] = t + self._round_time(slot, t)
            self.spawned.append((self.round_end[slot], _ROUND, slot))
        else:
            self.round_end[slot] = math.inf
        self._pump(t)

    def on_drain(self, slot: int, t: float) -> None:
        self.replicas[slot].start_drain()

    def on_death(self, slot: int, t: float) -> None:
        rep = self.replicas[slot]
        sim = self.sim
        inflight = self.active[slot] + self.boarding[slot]
        self.active[slot], self.boarding[slot] = [], []
        self.round_end[slot] = math.inf
        self.res.cost += max(0.0, t - rep.joined_s) / 3600.0 \
            * sim.rset.price_per_h
        self.res.revocations += 1
        if self.warned:
            self.res.warned_revocations += 1
        for e in inflight:
            self.entry_of.pop(e.rid, None)
            req = self.requests[e.rid]
            req.remaining = e.left
            if sim.armed and self.warned:
                # drain handover: survivors resume the remaining tokens
                self.res.handovers += 1
                self.queue.requeue_front(req, t)
            elif sim.armed:
                # silent revocation: restart from scratch after backoff
                req.attempts += 1
                if req.attempts <= sim.retry.max_attempts:
                    req.remaining = req.max_tokens
                    delay = sim.retry.backoff(
                        req.attempts, self.draws.retry_u(e.rid,
                                                         req.attempts))
                    ready = t + delay
                    self.pending_requeue[e.rid] = ready
                    self.spawned.append((ready, _REQUEUE, e.rid))
                    self.res.requeues += 1
                else:
                    self._finish(e.rid, DROPPED, t, "retries_exhausted")
                    self.res.dropped_inflight += 1
            else:
                self._finish(e.rid, DROPPED, t, "revoked")
                self.res.dropped_inflight += 1
                if self.warned:
                    self.res.dropped_warned += 1
        rep.kill(t, sim.rset.startup_s)
        self.spawned.append((rep.rejoin_s, _JOIN, slot))
        self._retier(t)
        self._pump(t)

    def on_join(self, slot: int, t: float) -> None:
        rep = self.replicas[slot]
        sim = self.sim
        lt_h = sim.rset.replacement_lifetime_h(self.traj, slot,
                                               rep.gen + 1, t / 3600.0)
        rep.rejoin(t, lt_h * 3600.0,
                   sim.rset.warning_s if sim.armed else 0.0)
        self.res.replacements += 1
        if math.isfinite(rep.death_s):
            self.spawned.append((rep.death_s, _DEATH, slot))
        if math.isfinite(rep.drain_s):
            self.spawned.append((rep.drain_s, _DRAIN, slot))
        self._retier(t)
        self._pump(t)

    def on_requeue(self, rid: int, t: float) -> None:
        del self.pending_requeue[rid]
        self.queue.offer(self.requests[rid], t)
        self._sync_shed()
        self._pump(t)

    def on_hedge(self, rid: int, t: float) -> None:
        e, slot = self.entry_of.pop(rid)
        for pool in (self.active, self.boarding):
            if e in pool[slot]:
                pool[slot].remove(e)
        if not self.active[slot] and not self.boarding[slot]:
            self.round_end[slot] = math.inf
        req = self.requests[rid]
        req.remaining = e.left
        self.res.hedges += 1
        self.queue.requeue_front(req, t)
        self._pump(t)

    _HANDLERS = {_ROUND: on_round, _DRAIN: on_drain, _DEATH: on_death,
                 _JOIN: on_join, _ARRIVE: on_arrive, _REQUEUE: on_requeue,
                 _HEDGE: on_hedge}

    def handle(self, rank: int, idx: int, t: float) -> None:
        self._HANDLERS[rank](self, idx, t)

    def valid(self, rank: int, idx: int, t: float) -> bool:
        """Lazy-invalidation test shared with the batched candidate set."""
        if rank == _ARRIVE:
            return True
        if rank == _ROUND:
            return (self.replicas[idx].status != DOWN
                    and self.round_end[idx] == t)
        if rank == _DRAIN:
            r = self.replicas[idx]
            return r.status == ACTIVE and not r.drained and r.drain_s == t
        if rank == _DEATH:
            r = self.replicas[idx]
            return r.status != DOWN and r.death_s == t
        if rank == _JOIN:
            r = self.replicas[idx]
            return r.status == DOWN and r.rejoin_s == t
        if rank == _REQUEUE:
            return self.pending_requeue.get(idx) == t
        if rank == _HEDGE:
            got = self.entry_of.get(idx)
            return got is not None and got[0].hedge_s == t
        return False

    # ----------------------------------------------------- batched driver
    def candidates(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """All currently-valid (time, rank, idx) candidates as arrays —
        the batched engine min-reduces these instead of keeping a heap."""
        ts: List[float] = []
        rk: List[int] = []
        ix: List[int] = []

        def add(t, rank, idx):
            if math.isfinite(t):
                ts.append(t)
                rk.append(rank)
                ix.append(idx)

        if self.ai < self.sim.workload.n_requests:
            add(float(self.draws.arrival_s[self.ai]), _ARRIVE, self.ai)
        for r in self.replicas:
            if r.status == DOWN:
                add(r.rejoin_s, _JOIN, r.slot)
            else:
                add(r.death_s, _DEATH, r.slot)
                add(self.round_end[r.slot], _ROUND, r.slot)
                if r.status == ACTIVE and not r.drained:
                    add(r.drain_s, _DRAIN, r.slot)
        for rid, ready in self.pending_requeue.items():
            add(ready, _REQUEUE, rid)
        for rid, (e, _slot) in self.entry_of.items():
            add(e.hedge_s, _HEDGE, rid)
        return np.asarray(ts), np.asarray(rk), np.asarray(ix)

    # ------------------------------------------------------------ wrap-up
    def finalize(self, t_end: float) -> ServingSimResult:
        self.queue.shed_expired(min(t_end, self.sim.horizon_s))
        self._sync_shed()
        for req in self.queue.drain():
            self._finish(req.rid, SHED, t_end, "horizon")
            self.res.shed_horizon += 1
        for rid in list(self.entry_of):
            self.entry_of.pop(rid)
            self._finish(rid, DROPPED, t_end, "horizon")
            self.res.dropped_inflight += 1
        for rid in list(self.pending_requeue):
            del self.pending_requeue[rid]
            self._finish(rid, DROPPED, t_end, "horizon")
            self.res.dropped_inflight += 1
        lat = [o.latency_s for o in self.outcomes.values()
               if o.status == COMPLETED]
        self.res.latencies_s = np.sort(np.asarray(lat, float))
        self.res.total_time_s = max(
            (o.finished_s for o in self.outcomes.values()), default=0.0)
        for r in self.replicas:
            if r.status != DOWN:
                self.res.cost += max(0.0, self.res.total_time_s
                                     - r.joined_s) / 3600.0 \
                    * self.sim.rset.price_per_h
        return self.res


class ServingFleetSim:
    """`run_many(n, engine=...)` over the trajectory core above."""

    def __init__(self, rset: ReplicaSet, workload: ServingWorkload,
                 *, policy: Optional[ServingDegradationPolicy] = None,
                 resilience=None, token_time_s: float = 0.05,
                 batch_ceiling: int = 8, horizon_s: float = 3600.0,
                 seed: int = 0):
        from repro.resilience import RetryPolicy
        self.rset = rset
        self.workload = workload
        self.policy = policy or ServingDegradationPolicy()
        self.resilience = resilience
        self.armed = resilience is not None
        self.retry = (resilience.retry if resilience is not None
                      else RetryPolicy())
        self.token_time_s = float(token_time_s)
        self.batch_ceiling = int(batch_ceiling)
        self.horizon_s = float(horizon_s)
        self.seed = int(seed)

    # ------------------------------------------------------------- engines
    def _run_event(self, core: _Trajectory) -> ServingSimResult:
        heap: List[Tuple[float, int, int]] = []
        for ev in core.spawned:
            heapq.heappush(heap, ev)
        core.spawned.clear()
        t = 0.0
        n = self.workload.n_requests
        while heap and core.resolved < n:
            t_ev, rank, idx = heapq.heappop(heap)
            if t_ev > self.horizon_s:
                t = self.horizon_s
                break
            if not core.valid(rank, idx, t_ev):
                continue
            t = t_ev
            core.handle(rank, idx, t)
            for ev in core.spawned:
                if math.isfinite(ev[0]):
                    heapq.heappush(heap, ev)
            core.spawned.clear()
        return core.finalize(min(t, self.horizon_s))

    def _run_batched(self, core: _Trajectory) -> ServingSimResult:
        t = 0.0
        n = self.workload.n_requests
        while core.resolved < n:
            core.spawned.clear()
            ts, rk, ix = core.candidates()
            if ts.size == 0:
                break
            # min over (time, rank, idx) — identical to the heap's order
            k = int(np.lexsort((ix, rk, ts))[0])
            if ts[k] > self.horizon_s:
                t = self.horizon_s
                break
            t = float(ts[k])
            core.handle(int(rk[k]), int(ix[k]), t)
        return core.finalize(min(t, self.horizon_s))

    # ---------------------------------------------------------------- API
    def run_many(self, samples: int = 8,
                 engine: str = "batched") -> List[ServingSimResult]:
        if engine not in ("batched", "event"):
            raise ValueError(f"unknown serving engine {engine!r}; "
                             "known: ('batched', 'event')")
        init = self.rset.initial_lifetimes_h(samples)
        out = []
        for traj in range(samples):
            core = _Trajectory(self, traj, init[traj])
            out.append(self._run_event(core) if engine == "event"
                       else self._run_batched(core))
        return out

    def run(self, traj: int = 0, engine: str = "batched",
            samples: int = 1) -> ServingSimResult:
        """Single trajectory (drawn from a `samples`-wide initial matrix
        so results match the same index of `run_many(samples)`)."""
        init = self.rset.initial_lifetimes_h(max(samples, traj + 1))
        core = _Trajectory(self, traj, init[traj])
        return (self._run_event(core) if engine == "event"
                else self._run_batched(core))
