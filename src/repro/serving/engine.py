"""Continuous-batching gateway engine over the real model.

One `GatewayEngine` owns a fixed pool of decode *slots* backed by a
single shared decode state (KV cache / SSM state) of shape
``(slots, max_len)``. Requests join and retire independently: each slot
carries its own write position, so a request can prefill its prompt while
its neighbours are mid-generation — the per-slot vector `cache_index`
path the model layers grew for exactly this.

The jitted step is memoized through `core.jit_cache` under
``("serve_step", (cfg, slots, max_len))``: every gateway session on the
same (ModelConfig, pool shape) — and every `Session.serve` call — shares
one traced callable. Joins are handled *inside* the trace with a reset
mask that zeroes the joining slot's rows along each state leaf's named
``batch`` axis, so admitting a request never re-triggers compilation.

Sampling happens in the same trace: per-slot temperatures, categorical
when a slot's temperature is positive and argmax otherwise. This is also
where the old `generate()` first-token bug dies — the first sampled
token goes through the same temperature gate as every later one.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import jit_cache
from repro.models import api


def _axis_leaves(axes) -> List[Optional[tuple]]:
    """Flatten an axes tree (leaves are name tuples / None) in the same
    order `tree_flatten` walks the matching value tree."""
    return jax.tree_util.tree_leaves(
        axes, is_leaf=lambda x: x is None or isinstance(x, tuple))


def _reset_by_batch_axis(state, axes, mask):
    """Zero `mask`-selected rows of every state leaf along its named
    ``batch`` axis (family-agnostic: transformer caches carry batch at
    dim 0 or 1 under "layers"; ssm/hybrid leaves likewise)."""
    vals, treedef = jax.tree_util.tree_flatten(state)
    out = []
    for v, ax in zip(vals, _axis_leaves(axes)):
        if ax is not None and "batch" in ax:
            d = ax.index("batch")
            shape = [1] * v.ndim
            shape[d] = v.shape[d]
            v = jnp.where(mask.reshape(shape), jnp.zeros_like(v), v)
        out.append(v)
    return jax.tree_util.tree_unflatten(treedef, out)


class GatewayEngine:
    """Slot-level continuous batching over one model's decode state."""

    def __init__(self, cfg: ModelConfig, params=None, *, slots: int = 4,
                 max_len: int = 64, seed: int = 1):
        if cfg.family == "audio":
            raise ValueError("encoder-only arch has no decode path")
        if params is None:
            params, _ = api.init(cfg, jax.random.PRNGKey(0))
        self.cfg = cfg
        self.params = params
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.state, self._axes = api.init_decode_state(cfg, slots, max_len)
        self.key = jax.random.PRNGKey(seed)

        # per-slot host-side bookkeeping
        self.rid: List[Optional[int]] = [None] * slots
        self._pending: List[deque] = [deque() for _ in range(slots)]
        self._pos = np.zeros(slots, np.int32)       # next write position
        self._last = np.zeros(slots, np.int32)      # last sampled token
        self._temp = np.zeros(slots, np.float32)
        self._budget = np.zeros(slots, np.int64)    # tokens still owed
        self._emitted: List[List[int]] = [[] for _ in range(slots)]
        self._join_mask = np.zeros(slots, bool)     # reset on next step
        self.step_seconds: List[float] = []         # per-iteration wall time

        axes = self._axes

        def build():
            def f(params, state, toks, pos, reset, temps, key):
                state = _reset_by_batch_axis(state, axes, reset)
                logits, state = api.decode_step(params, cfg, state, toks,
                                                pos)
                greedy = jnp.argmax(logits, -1)
                safe = jnp.where(temps > 0, temps, 1.0)
                sampled = jax.random.categorical(
                    key, logits / safe[:, None], -1)
                return jnp.where(temps > 0, sampled, greedy), state
            return jax.jit(f)

        self._step = jit_cache.cached("serve_step", (cfg, slots, max_len),
                                      build)

    # ----------------------------------------------------------- admission
    def free_slots(self) -> List[int]:
        return [i for i in range(self.slots) if self.rid[i] is None]

    def busy(self) -> bool:
        return any(r is not None for r in self.rid)

    def join(self, slot: int, rid: int, prompt: Sequence[int],
             max_new: int, temperature: float = 0.0) -> None:
        """Seat request `rid` in `slot`; its prompt prefills token-by-token
        on subsequent `step()` calls while other slots keep decoding."""
        if self.rid[slot] is not None:
            raise ValueError(f"slot {slot} is occupied by rid "
                             f"{self.rid[slot]}")
        prompt = list(int(t) for t in prompt)
        if not prompt:
            raise ValueError(f"rid {rid}: empty prompt")
        if max_new < 1:
            raise ValueError(f"rid {rid}: max_new must be >= 1")
        if len(prompt) + max_new > self.max_len:
            raise ValueError(
                f"rid {rid}: prompt_len {len(prompt)} + max_new {max_new} "
                f"exceeds max_len {self.max_len}")
        self.rid[slot] = rid
        self._pending[slot] = deque(prompt)
        self._pos[slot] = 0
        self._temp[slot] = temperature
        self._budget[slot] = max_new
        self._emitted[slot] = []
        self._join_mask[slot] = True

    def release(self, slot: int) -> List[int]:
        """Evict a slot (retire or external cancel); returns what it had
        emitted so far."""
        out = self._emitted[slot]
        self.rid[slot] = None
        self._pending[slot] = deque()
        self._emitted[slot] = []
        self._budget[slot] = 0
        return out

    # ------------------------------------------------------------- decode
    def step(self) -> List[Dict]:
        """One decode iteration across all occupied slots. Returns one
        event per slot that emitted a token this step:
        ``{"slot", "rid", "token", "done", "tokens"?}`` — prefill steps
        emit nothing for their slot."""
        active = [i for i in range(self.slots) if self.rid[i] is not None]
        if not active:
            return []
        toks = np.zeros(self.slots, np.int32)
        for i in active:
            toks[i] = (self._pending[i].popleft() if self._pending[i]
                       else self._last[i])
        reset = self._join_mask.copy()
        self._join_mask[:] = False
        self.key, sub = jax.random.split(self.key)

        t0 = time.monotonic()
        nxt, self.state = self._step(
            self.params, self.state, jnp.asarray(toks),
            jnp.asarray(self._pos), jnp.asarray(reset),
            jnp.asarray(self._temp), sub)
        nxt = np.asarray(jax.block_until_ready(nxt))
        self.step_seconds.append(time.monotonic() - t0)

        events: List[Dict] = []
        for i in active:
            self._pos[i] += 1
            if self._pending[i]:
                continue                      # still prefilling
            tok = int(nxt[i])
            self._last[i] = tok
            self._emitted[i].append(tok)
            done = len(self._emitted[i]) >= self._budget[i]
            ev = {"slot": i, "rid": self.rid[i], "token": tok,
                  "done": done}
            if done:
                ev["tokens"] = self.release(i)
            events.append(ev)
        return events

    # ------------------------------------------------------------ metrics
    def decode_percentiles_ms(self) -> Dict[str, float]:
        """p50/p95/p99 of per-iteration wall time, milliseconds."""
        if not self.step_seconds:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
        arr = np.asarray(self.step_seconds) * 1e3
        return {"p50": float(np.percentile(arr, 50)),
                "p95": float(np.percentile(arr, 95)),
                "p99": float(np.percentile(arr, 99))}
