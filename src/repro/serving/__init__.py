"""`repro.serving` — revocation-tolerant serving gateway (docs/serving.md).

Three layers, mirroring the training stack's split:

* **Gateway** (`GatewayEngine`): continuous batching over the real
  model — per-slot decode positions in one shared KV/SSM state, in-trace
  join resets and sampling, `jit_cache`-shared traced step.
* **Admission & policy** (`AdmissionQueue`, `ServingDegradationPolicy`):
  bounded queueing with deadline sheds, and quorum-style capacity tiers
  stepped down before the latency SLO breaks.
* **Fleet** (`ReplicaSet`, `ServingFleetSim`, `plan_serving`): replicas
  on revocable instances under provider lifetime laws — warned-revocation
  drain + handover, silent-revocation requeue-with-retry, hedged
  re-dispatch — scored as event/batched parity ensembles and ranked
  against an SLO.
"""
from repro.serving.degradation import (ServingDegradationPolicy,  # noqa: F401
                                       TIERS)
from repro.serving.engine import GatewayEngine  # noqa: F401
from repro.serving.planner import (ServingPlan, ServingSLO,  # noqa: F401
                                   plan_serving)
from repro.serving.queue import AdmissionQueue  # noqa: F401
from repro.serving.replica import (ACTIVE, DOWN, DRAINING,  # noqa: F401
                                   Replica, ReplicaSet)
from repro.serving.requests import (COMPLETED, DROPPED, SHED,  # noqa: F401
                                    Request, RequestOutcome)
from repro.serving.simulator import (ServingFleetSim,  # noqa: F401
                                     ServingScript, ServingSimResult,
                                     ServingWorkload, summarize_serving)
