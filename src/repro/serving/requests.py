"""Request objects shared by the gateway engine and the fleet simulator.

A `Request` is one user generation: it arrives at `arrival_s`, wants
`max_tokens` decoded tokens, and carries a priority class (0 = high;
higher numbers shed first under degradation). The real gateway attaches
the actual prompt token ids; the fleet simulator only needs the counts.

`remaining` tracks decode progress so a warned-revocation handover can
move a half-served request to a survivor without losing tokens; a silent
revocation resets it to `max_tokens` (stock restart-from-scratch, the
progress the paper's §V revocation accounting charges you for).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

#: terminal states a request can end in — exactly one of these per request
COMPLETED = "completed"
SHED = "shed"           # admission control: queue full / budget / degraded
DROPPED = "dropped"     # lost in-flight to a revocation (or retries exhausted)


@dataclasses.dataclass
class Request:
    rid: int
    arrival_s: float
    prompt_tokens: int
    max_tokens: int
    priority: int = 1                      # 0 = high; sheds last
    prompt: Optional[Sequence[int]] = None  # token ids (real gateway only)
    deadline_s: float = math.inf           # absolute queue-time budget expiry

    # mutable serving state
    remaining: int = -1                    # decode tokens still owed
    attempts: int = 0                      # requeue-with-retry count
    enqueued_s: float = 0.0                # last time it entered a queue

    def __post_init__(self) -> None:
        if self.max_tokens < 1:
            raise ValueError(f"request {self.rid}: max_tokens must be >= 1")
        if self.remaining < 0:
            self.remaining = self.max_tokens


@dataclasses.dataclass
class RequestOutcome:
    """Terminal record for one request (the scorecard unit)."""
    rid: int
    status: str                            # COMPLETED / SHED / DROPPED
    arrival_s: float
    finished_s: float
    priority: int
    tokens: int = 0                        # tokens actually decoded
    reason: str = ""                       # shed/drop cause
    token_ids: Optional[List[int]] = None  # real gateway only

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.arrival_s
