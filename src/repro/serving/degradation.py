"""SLO-graceful degradation tiers for the serving fleet.

Mirrors the training-side `resilience.DegradationPolicy` (quorum tiers on
the alive fraction of the roster), but the levers are serving-shaped: cap
generation length, shrink the per-replica batch ceiling, and finally shed
non-priority traffic — stepping capacity down *before* the latency SLO is
violated rather than after. Effects are cumulative by severity: a fleet
degraded enough to shed low-priority traffic is also running the reduced
token cap and the shrunk batch ceiling.

Tier transitions are what the chaos evaluator scores: the simulator emits
one ``serving_degraded`` record per change, and a return to ``full``
after any degraded tier counts as a recovery cycle (the serve_wave gate).
The defaults (all thresholds 0) never degrade, so an unarmed fleet is
behavior-preserving — the same convention as `DegradationPolicy`.
"""
from __future__ import annotations

import dataclasses

#: severity order, mildest first — `severity()` indexes into this
TIERS = ("full", "reduce_tokens", "shrink_batch", "shed_low_priority")


@dataclasses.dataclass(frozen=True)
class ServingDegradationPolicy:
    """Alive-fraction thresholds, most severe checked first:
    ``frac < shed_below`` → shed_low_priority; ``frac <
    shrink_batch_below`` → shrink_batch; ``frac < reduce_tokens_below``
    → reduce_tokens; else full."""
    reduce_tokens_below: float = 0.0
    shrink_batch_below: float = 0.0
    shed_below: float = 0.0
    token_factor: float = 0.5
    batch_factor: float = 0.5

    def tier(self, n_alive: int, n_total: int) -> str:
        frac = n_alive / max(n_total, 1)
        if frac < self.shed_below:
            return "shed_low_priority"
        if frac < self.shrink_batch_below:
            return "shrink_batch"
        if frac < self.reduce_tokens_below:
            return "reduce_tokens"
        return "full"

    @staticmethod
    def severity(tier: str) -> int:
        return TIERS.index(tier)

    # ------------------------------------------------- cumulative effects
    def token_cap(self, tier: str, max_tokens: int) -> int:
        """Generation-length ceiling under `tier` (>= 1)."""
        if self.severity(tier) >= TIERS.index("reduce_tokens"):
            return max(1, int(round(max_tokens * self.token_factor)))
        return max_tokens

    def batch_ceiling(self, tier: str, ceiling: int) -> int:
        """Per-replica concurrent-request ceiling under `tier` (>= 1)."""
        if self.severity(tier) >= TIERS.index("shrink_batch"):
            return max(1, int(round(ceiling * self.batch_factor)))
        return ceiling

    def sheds_low_priority(self, tier: str) -> bool:
        return tier == "shed_low_priority"
