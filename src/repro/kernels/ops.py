"""jit'd dispatch wrappers for the Pallas kernels.

On non-TPU backends (this container) kernels run in interpret mode — the
kernel body executes in Python on CPU, validating the exact TPU program logic.
Backward passes: flash attention has a full Pallas bwd; ssd/rmsnorm use
custom_vjp with an XLA bwd over the ref (kernel accelerates fwd, bwd is
recompute — documented in docs/DESIGN.md §1, kernels layer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import event_select as es
from repro.kernels import flash_attention as fa
from repro.kernels import ref
from repro.kernels import rmsnorm as rn
from repro.kernels import ssd_scan as ss


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# event select: Pallas on TPU, XLA reference elsewhere (interpret-mode Pallas
# would run the kernel body row-block by row-block in Python — far slower
# than the fused XLA min/argmin, so CPU/GPU fall back automatically)
# ---------------------------------------------------------------------------
def event_select(ev):
    """(n, m) candidate-event times, inf = masked -> (min_t (n,), argmin
    (n,) int32), ties broken by lowest column. Not differentiable."""
    if _interpret():
        return ref.event_select_ref(ev)
    return es.event_select_fwd(ev, interpret=False)


# ---------------------------------------------------------------------------
# flash attention with custom vjp (Pallas fwd + Pallas bwd)
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, block_q=fa.DEFAULT_BLOCK_Q,
                    block_k=fa.DEFAULT_BLOCK_K):
    out, _ = fa.flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                                    block_k=block_k, interpret=_interpret())
    return out


def _fa_fwd(q, k, v, causal, block_q, block_k):
    out, lse = fa.flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                                      block_k=block_k, interpret=_interpret())
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, block_q, block_k, res, do):
    q, k, v, out, lse = res
    dq, dk, dv = fa.flash_attention_bwd(q, k, v, out, lse, do, causal=causal,
                                        block_q=block_q, block_k=block_k,
                                        interpret=_interpret())
    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# SSD scan: Pallas fwd, ref-recompute bwd
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd_scan(x, dt, A, B, C, chunk=128):
    return ss.ssd_scan_fwd(x, dt, A, B, C, chunk=chunk,
                           interpret=_interpret())


def _ssd_fwd(x, dt, A, B, C, chunk):
    y = ss.ssd_scan_fwd(x, dt, A, B, C, chunk=chunk, interpret=_interpret())
    return y, (x, dt, A, B, C)


def _ssd_bwd(chunk, res, dy):
    x, dt, A, B, C = res
    _, vjp = jax.vjp(lambda *a: ref.ssd_scan_ref(*a, chunk=chunk),
                     x, dt, A, B, C)
    return vjp(dy)


ssd_scan.defvjp(_ssd_fwd, _ssd_bwd)


# ---------------------------------------------------------------------------
# RMSNorm: Pallas fwd, analytic bwd (jnp)
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, scale, eps=1e-5):
    return rn.rmsnorm_fwd(x, scale, eps=eps, interpret=_interpret())


def _rn_fwd(x, scale, eps):
    return rn.rmsnorm_fwd(x, scale, eps=eps, interpret=_interpret()), (x, scale)


def _rn_bwd(eps, res, dy):
    x, scale = res
    _, vjp = jax.vjp(lambda xx, ss_: ref.rmsnorm_ref(xx, ss_, eps=eps), x, scale)
    return vjp(dy)


rmsnorm.defvjp(_rn_fwd, _rn_bwd)
