"""Fused masked min-reduction + argmin event-select Pallas kernel.

One lockstep round of the fleet engines reduces an `(n, m)` candidate-event
matrix (revocation timers ++ join timers, `inf` = masked/disarmed) to the
per-trajectory next event: its time and its column. Fusing the min and the
tie-broken argmin into one row-blocked pass keeps the event matrix in VMEM
for a single HBM round-trip; ties resolve to the lowest column index
(NumPy `argmin` semantics, which the parity contract in docs/DESIGN.md §2
pins across all three engines). All-masked rows return (`inf`, 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _event_select_kernel(ev_ref, t_ref, i_ref):
    ev = ev_ref[...]
    m = ev.shape[1]
    mn = jnp.min(ev, axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, ev.shape, 1)
    # lowest column attaining the min; all-masked (all-inf) rows hit the
    # `inf == inf` branch on every column and resolve to 0
    arg = jnp.min(jnp.where(ev == mn[:, None], cols, m), axis=1)
    t_ref[...] = mn.astype(t_ref.dtype)
    i_ref[...] = jnp.where(arg == m, 0, arg).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def event_select_fwd(ev, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret=False):
    """ev: (n, m) candidate event times, inf = masked.

    Returns `(t, i)`: per-row min time (n,) and its tie-broken-low column
    index (n,) int32.
    """
    n, m = ev.shape
    br = min(block_rows, max(n, 1))
    pad = (-n) % br
    evf = jnp.pad(ev, ((0, pad), (0, 0)),
                  constant_values=jnp.inf) if pad else ev
    nblocks = evf.shape[0] // br
    t, i = pl.pallas_call(
        _event_select_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((br, m), lambda b: (b, 0))],
        out_specs=[pl.BlockSpec((br,), lambda b: (b,)),
                   pl.BlockSpec((br,), lambda b: (b,))],
        out_shape=[jax.ShapeDtypeStruct((evf.shape[0],), ev.dtype),
                   jax.ShapeDtypeStruct((evf.shape[0],), jnp.int32)],
        interpret=interpret,
    )(evf)
    if pad:
        t, i = t[:n], i[:n]
    return t, i
