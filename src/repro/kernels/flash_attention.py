"""FlashAttention for TPU in Pallas: fwd + bwd kernels with explicit BlockSpec
VMEM tiling, causal + GQA. Grid iterates KV blocks in the minor-most dimension
so the online-softmax accumulators live in VMEM scratch across iterations
(the canonical TPU pattern — sequential grid, MXU-aligned 128x128 tiles).

Validated on CPU via interpret=True against ref.flash_attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *, scale, causal, block_q, block_k,
                nk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1  # block intersects causal tri

    @pl.when(jnp.asarray(run))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l_safe)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret",
                              "scale"))
def flash_attention_fwd(q, k, v, *, causal=True, scale=None,
                        block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                        interpret=False):
    """q:(B,Sq,H,hd) k,v:(B,Sk,KV,hd) -> (out, lse). GQA via head mapping."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    # (B,S,H,hd) -> (B,H,S,hd) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, nq, nk)
    out_t, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        scratch_shapes=[
            # VMEM accumulators carried across the sequential ik dimension
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out_t.transpose(0, 2, 1, 3), lse


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------
def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   dq_acc, *, scale, causal, block_q, block_k, nk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start, k_start = iq * block_q, ik * block_k
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(jnp.asarray(run))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_acc[...] += jax.lax.dot(ds.astype(k.dtype), k,
                                   preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc,
                    *, scale, causal, block_q, block_k, nq):
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start, k_start = iq * block_q, ik * block_k
    run = True
    if causal:
        run = q_start + block_q - 1 >= k_start

    @pl.when(jnp.asarray(run))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                      # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (bk, hd)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale             # (bq, bk)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (bk, hd)

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret",
                              "scale"))
def flash_attention_bwd(q, k, v, out, lse, do, *, causal=True, scale=None,
                        block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                        interpret=False):
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq, nk = Sq // block_q, Sk // block_k

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    dot = do.transpose(0, 2, 1, 3)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1).transpose(0, 2, 1)            # (B,H,Sq)

    kw = dict(scale=scale, causal=causal, block_q=block_q, block_k=block_k)
    q_spec = pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0))
    kv_spec_q = pl.BlockSpec((1, 1, block_k, hd),
                             lambda b, h, i, j, G=G: (b, h // G, j, 0))
    lse_spec = pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i))

    dq_t = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, nk=nk, **kw),
        grid=(B, H, nq, nk),
        in_specs=[q_spec, kv_spec_q, kv_spec_q, q_spec, lse_spec, lse_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)

    # dk/dv per Q-head; group-summed outside the kernel (GQA)
    q_spec_k = pl.BlockSpec((1, 1, block_q, hd), lambda b, h, j, i: (b, h, i, 0))
    kv_spec_k = pl.BlockSpec((1, 1, block_k, hd),
                             lambda b, h, j, i, G=G: (b, h // G, j, 0))
    kvh_spec = pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j, i: (b, h, j, 0))
    lse_spec_k = pl.BlockSpec((1, 1, block_q), lambda b, h, j, i: (b, h, i))
    dkh_t, dvh_t = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, nq=nq, **kw),
        grid=(B, H, nk, nq),
        in_specs=[q_spec_k, kv_spec_k, kv_spec_k, q_spec_k, lse_spec_k,
                  lse_spec_k],
        out_specs=[kvh_spec, kvh_spec],
        out_shape=[jax.ShapeDtypeStruct((B, H, Sk, hd), k.dtype),
                   jax.ShapeDtypeStruct((B, H, Sk, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)

    dq = dq_t.transpose(0, 2, 1, 3)
    dk = dkh_t.reshape(B, KV, G, Sk, hd).sum(2).transpose(0, 2, 1, 3)
    dv = dvh_t.reshape(B, KV, G, Sk, hd).sum(2).transpose(0, 2, 1, 3)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)
