"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

Grid = (batch, heads, n_chunks) with the chunk axis minor-most: the recurrent
state (n, p) lives in VMEM scratch and is carried across sequential chunk
iterations — the matmul-form SSD maps the intra-chunk work onto the MXU
((L,n)@(n,L), (L,L)@(L,p), (n,L)@(L,p)) while the cross-chunk recurrence is a
rank-1 state update per chunk. This is the TPU-native adaptation of the CUDA
SSD kernel (arXiv:2405.21060): no warp shuffles — tiles + sequential grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state,
                *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, :, 0].astype(jnp.float32)           # (L, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (L,)
    A = a_ref[0].astype(jnp.float32)                 # ()
    B = b_ref[0, :, 0].astype(jnp.float32)           # (L, n)
    C = c_ref[0, :, 0].astype(jnp.float32)           # (L, n)

    da = dt * A                                      # (L,)
    cum = jnp.cumsum(da)                             # (L,)
    # intra-chunk masked decay matrix
    seg = cum[:, None] - cum[None, :]                # (L, L)
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(causal, seg, -jnp.inf))
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * decay * dt[None, :]            # (L, L)
    y_diag = jax.lax.dot(scores, x, preferred_element_type=jnp.float32)

    # off-diagonal: contribution of the carried state
    decay_in = jnp.exp(cum)                          # (L,)
    y_off = jax.lax.dot(C * decay_in[:, None], state[...],
                        preferred_element_type=jnp.float32)  # (L, p)

    # state update: S <- exp(sum da) * S + sum_l decay_out_l dt_l B_l x_l^T
    chunk_sum = cum[-1]
    decay_out = jnp.exp(chunk_sum - cum)             # (L,)
    bw = B * (decay_out * dt)[:, None]               # (L, n)
    new_state = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    state[...] = state[...] * jnp.exp(chunk_sum) + new_state

    y_ref[0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan_fwd(x, dt, A, B, C, *, chunk: int = 128, interpret=False):
    """x:(b,s,h,p) dt:(b,s,h) A:(h,) B,C:(b,s,g,n) -> y:(b,s,h,p).

    h % g == 0 (groups broadcast to heads via the BlockSpec index map).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    grid = (b, h, nc)
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda ib, ih, ic, rep=rep: (ib, ic, ih // rep, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda ib, ih, ic, rep=rep: (ib, ic, ih // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y
