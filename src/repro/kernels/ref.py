"""Pure-jnp oracles for every Pallas kernel. Tests sweep shapes/dtypes and
assert_allclose kernel-vs-ref; the model code paths also use these refs when
kernels are disabled.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True, scale=None):
    """q:(B,Sq,H,hd) k,v:(B,Sk,KV,hd) -> (B,Sq,H,hd), fp32 softmax."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool), k.shape[1] - Sq)
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def event_select_ref(ev):
    """Oracle for the event-select kernel: per-row masked min + argmin over
    an (n, m) candidate-event matrix (inf = masked), ties broken by lowest
    column index. All-masked rows return (inf, 0) — NumPy argmin semantics,
    the contract all three fleet engines share (docs/DESIGN.md §2)."""
    t = jnp.min(ev, axis=1)
    i = jnp.argmin(ev, axis=1).astype(jnp.int32)
    return t, i


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_scan_ref(x, dt, A, B, C, chunk: int = 64):
    """Oracle for the Mamba2 SSD kernel — delegates to models.ssm.ssd."""
    from repro.models.ssm import ssd
    return ssd(x.astype(jnp.float32), dt.astype(jnp.float32), A,
               B.astype(jnp.float32), C.astype(jnp.float32),
               chunk=chunk).astype(x.dtype)
