"""Fused RMSNorm Pallas kernel: one HBM round-trip per row block (vs separate
square/mean/rsqrt/mul HLOs). Rows blocked to VMEM; reduction in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_fwd(x, scale, *, eps: float = 1e-5,
                block_rows: int = DEFAULT_BLOCK_ROWS, interpret=False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    br = min(block_rows, rows)
    # pad rows to a multiple of the block
    pad = (-rows) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    nblocks = xf.shape[0] // br
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
