"""Mamba2 LM: embedding + scanned mamba2 blocks + head (attention-free)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import _stack, scan_layers


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = [
        {"ln": L.init_rmsnorm(cfg.d_model), "mixer": S.init_mamba2(keys[i], cfg)}
        for i in range(cfg.n_layers)
    ]
    return {
        "embed": L._dense_init(keys[-1], (cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), scale=0.02),
        "layers": _stack(blocks),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "lm_head": L._dense_init(keys[-2], (cfg.d_model, cfg.vocab_size),
                                 ("embed", "vocab")),
    }


def forward(params, cfg: ModelConfig, tokens, positions=None,
            input_embeds=None):
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = constrain(x, "batch", "seq", "embed")

    def body(x, lp):
        h, _ = S.mamba2_block(lp["mixer"], cfg,
                              L.rmsnorm(lp["ln"], x, cfg.norm_eps),
                              use_kernel=cfg.use_pallas)
        return x + h, None

    x, _ = scan_layers(body, x, params["layers"], cfg)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.dtype)
    return constrain(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def init_state(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    conv_shape, ssm_shape = S.mamba2_state_shape(cfg, batch)
    n = cfg.n_layers
    return {
        "conv": L.Param(jnp.zeros((n,) + conv_shape, dtype),
                        ("layers", "batch", None, "conv_dim")),
        "ssm": L.Param(jnp.zeros((n,) + ssm_shape, dtype),
                       ("layers", "batch", "ssm_heads", "ssm_state", None)),
    }


def decode_step(params, cfg: ModelConfig, state, tokens, index):
    x = params["embed"].astype(cfg.dtype)[tokens][:, None]

    def body(x, xs):
        lp, (cs, ss) = xs
        h, new_st = S.mamba2_block(lp["mixer"], cfg,
                                   L.rmsnorm(lp["ln"], x, cfg.norm_eps),
                                   state=(cs, ss))
        return x + h, new_st

    x, new_states = scan_layers(body, x, (params["layers"],
                                          (state["conv"], state["ssm"])), cfg)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["lm_head"].astype(cfg.dtype))[:, 0]
    return constrain(logits, "batch", "vocab"), \
        {"conv": new_states[0], "ssm": new_states[1]}
