"""Shared neural-net layers: norms, RoPE/M-RoPE, GQA + MLA attention (train,
prefill and single-token decode paths), SwiGLU MLP, grouped-capacity MoE.

Param convention: every parameter is created as ``Param(value, axes)`` where
``axes`` is a tuple of *logical* axis names (see dist/sharding.py). The model
api splits the tree into (values, axes) so the launcher can derive
NamedShardings without a parallel spec tree drifting out of sync.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain


# ---------------------------------------------------------------------------
# Param container
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Param:
    value: jnp.ndarray
    axes: Tuple[Optional[str], ...]


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def _dense_init(key, shape, axes, scale=None, dtype=jnp.float32) -> Param:
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    v = jax.random.normal(key, shape, dtype) * scale
    return Param(v, axes)


def _zeros(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def _ones(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int) -> Dict[str, Param]:
    return {"scale": _ones((d,), ("embed",))}


def rmsnorm(params, x, eps: float = 1e-5, use_kernel: bool = False):
    scale = params["scale"]
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.rmsnorm(x, scale, eps=eps)
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(scale, x, eps: float = 1e-5):
    """qk-norm: rmsnorm over the head_dim of (B,S,H,hd)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _quant_int8(x):
    """Per-(…, last-dim) symmetric int8 quantization: returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x32 / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard / partial / M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(rot_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               rot_frac: float = 1.0,
               mrope_sections: Tuple[int, ...] = ()) -> jnp.ndarray:
    """x: (B,S,H,hd). positions: (B,S) or (3,B,S) for M-RoPE."""
    hd = x.shape[-1]
    rot_dim = int(hd * rot_frac)
    if rot_dim == 0:
        return x
    rot_dim -= rot_dim % 2
    inv = rope_freqs(rot_dim, theta)  # (rot_dim/2,)
    if mrope_sections:
        assert positions.ndim == 3, "M-RoPE needs (3,B,S) positions"
        secs = mrope_sections
        assert sum(secs) == rot_dim // 2, (secs, rot_dim)
        parts = []
        off = 0
        for i, s in enumerate(secs):
            ang = positions[i][..., None].astype(jnp.float32) * inv[off:off + s]
            parts.append(ang)
            off += s
        angles = jnp.concatenate(parts, axis=-1)  # (B,S,rot_dim/2)
    else:
        angles = positions[..., None].astype(jnp.float32) * inv  # (B,S,rot_dim/2)
    cos = jnp.cos(angles)[:, :, None, :]  # (B,S,1,rot_dim/2)
    sin = jnp.sin(angles)[:, :, None, :]
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., : rot_dim // 2], xr[..., rot_dim // 2:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA). Chunked online-softmax full attention keeps peak memory
# O(S * chunk) instead of O(S^2) — same math as kernels/ref.py oracle.
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig) -> Dict[str, Param]:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H, hd), ("embed", "heads", None)),
        "wk": _dense_init(ks[1], (d, KV, hd), ("embed", "kv_heads", None)),
        "wv": _dense_init(ks[2], (d, KV, hd), ("embed", "kv_heads", None)),
        "wo": _dense_init(ks[3], (H, hd, d), ("heads", None, "embed"),
                          scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = _ones((hd,), (None,))
        p["k_norm"] = _ones((hd,), (None,))
    return p


def _chunked_attn(q, k, v, causal: bool, q_offset, chunk: int = 1024):
    """q:(B,Sq,H,hd) k,v:(B,Sk,KV,hd) -> (B,Sq,H,hd). GQA by head broadcast.

    Scans over query chunks with a full online-softmax against k/v; O(Sq/chunk)
    steps, peak score memory B*chunk*Sk per head group.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    vd = v.shape[-1]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scale = 1.0 / math.sqrt(hd)
    if Sq <= chunk:
        return _attn_block(qg, k, v, causal, q_offset, 0, scale
                           ).reshape(B, Sq, H, vd)
    n = Sq // chunk
    assert Sq % chunk == 0, (Sq, chunk)
    qc = qg.reshape(B, n, chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def body(i, qi):
        out = _attn_block(qi, k, v, causal, q_offset, i * chunk, scale)
        return i + 1, out

    _, oc = lax.scan(body, 0, qc)
    return oc.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, vd)


def _attn_block(qg, k, v, causal, q_offset, block_start, scale):
    """qg:(B,sq,KV,G,hd) against full k,v:(B,Sk,KV,hd)."""
    B, sq, KV, G, hd = qg.shape
    Sk = k.shape[1]
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = q_offset + block_start + jnp.arange(sq)
        kpos = jnp.arange(Sk)
        mask = kpos[None, :] <= qpos[:, None]  # (sq,Sk)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.astype(qg.dtype)


def _cache_store(buf, val, index):
    """Write a decode-step slice into ``buf`` at position ``index`` (axis 1).

    ``index`` is either a scalar — lockstep decode, every row at the same
    depth (the original `dynamic_update_slice` path, bit-identical) — or a
    (B,) vector of per-row positions for continuous batching, where each
    slot sits at its own depth. The vector path requires S == 1 steps.
    """
    val = val.astype(buf.dtype)
    if jnp.ndim(index) == 0:
        return lax.dynamic_update_slice(
            buf, val, (0, index) + (0,) * (buf.ndim - 2))
    return buf.at[jnp.arange(buf.shape[0]), index].set(val[:, 0])


def _cache_valid(index, S, Sk, n_between):
    """Mask of attendable key positions: kpos <= index + S - 1, shaped with
    ``n_between`` singleton dims between the (optional) batch dim and Sk so
    it broadcasts against the decode logits."""
    kpos = jnp.arange(Sk).reshape((1,) * (n_between + 1) + (Sk,))
    last = index + S - 1
    if jnp.ndim(index) == 0:
        return kpos <= last
    return kpos <= last.reshape((-1,) + (1,) * (n_between + 1))


def attention(params, cfg: ModelConfig, x, positions,
              cache: Optional[Dict[str, jnp.ndarray]] = None,
              cache_index=None):
    """Full attention. If ``cache`` given: decode path (x is (B,1,d)); returns
    (out, new_cache). Otherwise train/prefill; returns (out, None)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = head_rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = head_rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if cfg.partial_rotary > 0:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary,
                       cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary,
                       cfg.mrope_sections)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)

    if cache is not None:
        if cfg.kv_quant:
            # int8 KV cache: per-(token, head) scales — halves the decode
            # memory roofline (the dominant term for every decode cell)
            kq, ks_ = _quant_int8(k)
            vq, vs_ = _quant_int8(v)
            ck = _cache_store(cache["k"], kq, cache_index)
            cv = _cache_store(cache["v"], vq, cache_index)
            cks = _cache_store(cache["k_scale"], ks_, cache_index)
            cvs = _cache_store(cache["v_scale"], vs_, cache_index)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            ck = ck.astype(jnp.bfloat16) * cks[..., None].astype(jnp.bfloat16)
            cv = cv.astype(jnp.bfloat16) * cvs[..., None].astype(jnp.bfloat16)
        else:
            ck = _cache_store(cache["k"], k, cache_index)
            cv = _cache_store(cache["v"], v, cache_index)
            new_cache = {"k": ck, "v": cv}
        ck = constrain(ck, "batch", "kv_seq", "kv_heads", None)
        cv = constrain(cv, "batch", "kv_seq", "kv_heads", None)
        Sk = ck.shape[1]
        valid = _cache_valid(cache_index, S, Sk, 3)
        KV = ck.shape[2]
        G = cfg.n_heads // KV
        qg = q.reshape(B, S, KV, G, cfg.head_dim)
        logits = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                            ck.astype(jnp.float32)) / math.sqrt(cfg.head_dim)
        logits = jnp.where(valid, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgqs,bskh->bqkgh", w, cv.astype(jnp.float32))
        out = out.reshape(B, S, cfg.n_heads, cfg.head_dim).astype(x.dtype)
    else:
        new_cache = None
        if cfg.use_pallas:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=cfg.causal)
        else:
            out = _chunked_attn(q, k, v, cfg.causal, 0)
    out = constrain(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return constrain(y, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2): latent-compressed KV. Train path materializes
# per-head K/V; decode path uses the absorbed formulation against the compact
# (c_kv, k_rope) cache — the technique's memory win.
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig) -> Dict[str, Param]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": _dense_init(ks[0], (d, H, qk_head), ("embed", "heads", None)),
        "wdkv": _dense_init(ks[1], (d, m.kv_lora_rank), ("embed", "qk_lora")),
        "wkrope": _dense_init(ks[2], (d, m.qk_rope_head_dim), ("embed", None)),
        "wuk": _dense_init(ks[3], (m.kv_lora_rank, H, m.qk_nope_head_dim),
                           ("qk_lora", "heads", None)),
        "wuv": _dense_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim),
                           ("qk_lora", "heads", None)),
        "wo": _dense_init(ks[5], (H, m.v_head_dim, d), ("heads", None, "embed"),
                          scale=1.0 / math.sqrt(H * m.v_head_dim)),
        "kv_norm": _ones((m.kv_lora_rank,), (None,)),
    }


def mla_attention(params, cfg: ModelConfig, x, positions,
                  cache: Optional[Dict[str, jnp.ndarray]] = None,
                  cache_index=None):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    scale = 1.0 / math.sqrt(nope + rope_d)

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = x @ params["wdkv"].astype(x.dtype)                       # (B,S,r)
    c_kv = rmsnorm({"scale": params["kv_norm"]}, c_kv, cfg.norm_eps)
    k_rope = (x @ params["wkrope"].astype(x.dtype))[:, :, None, :]  # (B,S,1,rd)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]  # (B,S,rd)

    if cache is not None:
        # absorbed decode: q_lat = q_nope @ W_uk  -> score against c_kv cache
        cc = _cache_store(cache["c_kv"], c_kv, cache_index)
        cr = _cache_store(cache["k_rope"], k_rope, cache_index)
        cc = constrain(cc, "batch", "kv_seq", "qk_lora")
        cr = constrain(cr, "batch", "kv_seq", None)
        new_cache = {"c_kv": cc, "k_rope": cr}
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           params["wuk"].astype(jnp.float32))
        logits = (jnp.einsum("bshr,btr->bhst", q_lat, cc.astype(jnp.float32))
                  + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                               cr.astype(jnp.float32))) * scale
        Sk = cc.shape[1]
        valid = _cache_valid(cache_index, S, Sk, 2)
        logits = jnp.where(valid, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", w, cc.astype(jnp.float32))
        out = jnp.einsum("bshr,rhv->bshv", o_lat,
                         params["wuv"].astype(jnp.float32)).astype(x.dtype)
    else:
        new_cache = None
        k_nope = jnp.einsum("bsr,rhn->bshn", c_kv, params["wuk"].astype(x.dtype))
        v = jnp.einsum("bsr,rhv->bshv", c_kv, params["wuv"].astype(x.dtype))
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope_d))],
            axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        q_full = constrain(q_full, "batch", "seq", "heads", None)
        k_full = constrain(k_full, "batch", "seq", "heads", None)
        out = _chunked_attn(q_full, k_full, v, cfg.causal, 0)
    out = constrain(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshv,hvd->bsd", out, params["wo"].astype(x.dtype))
    return constrain(y, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, d_ff: int, variant: str = "swiglu"
             ) -> Dict[str, Param]:
    ks = jax.random.split(key, 3)
    p = {
        "wi": _dense_init(ks[0], (d, d_ff), ("embed", "ff")),
        "wo": _dense_init(ks[2], (d_ff, d), ("ff", "embed")),
    }
    if variant == "swiglu":
        p["wg"] = _dense_init(ks[1], (d, d_ff), ("embed", "ff"))
    return p


def mlp(params, x):
    if "wg" in params:  # SwiGLU
        h = jax.nn.silu(x @ params["wg"].astype(x.dtype)) * (
            x @ params["wi"].astype(x.dtype))
    else:               # 2-matrix GELU (starcoder2-style)
        h = jax.nn.gelu(x @ params["wi"].astype(x.dtype))
    h = constrain(h, "batch", "seq", "ff")
    return constrain(h @ params["wo"].astype(x.dtype), "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# MoE: grouped-capacity sort dispatch (static shapes, local per-group sort —
# no global collectives in the dispatch itself; expert FFNs are TP-sharded).
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig) -> Dict[str, Param]:
    mo = cfg.moe
    d, E, f = cfg.d_model, mo.n_experts, mo.expert_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), ("embed", "experts"),
                              scale=0.02),
        "wi": _dense_init(ks[1], (E, d, f), ("experts", "embed", "ff")),
        "wg": _dense_init(ks[2], (E, d, f), ("experts", "embed", "ff")),
        "wo": _dense_init(ks[3], (E, f, d), ("experts", "ff", "embed")),
    }
    if mo.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, mo.n_shared_experts * f)
    return p


def _group_dispatch(xg, eid, w, n_experts: int, cap: int):
    """xg:(g,d) eid,w:(g,k). Returns (buf (E*cap,d), combine metadata)."""
    g, k = eid.shape
    flat_e = eid.reshape(-1)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[sorted_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(g * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, n_experts * cap)  # drop row
    tok = order // k
    buf = jnp.zeros((n_experts * cap + 1, xg.shape[-1]), xg.dtype)
    buf = buf.at[dest].set(xg[tok])
    meta = (dest, tok, flat_w[order], keep)
    return buf[:-1], meta


def _group_combine(out_buf, meta, g: int, k: int, d: int):
    dest, tok, w_sorted, keep = meta
    padded = jnp.concatenate([out_buf, jnp.zeros((1, d), out_buf.dtype)])
    pair_out = padded[jnp.where(keep, dest, out_buf.shape[0])]
    y = jnp.zeros((g, d), out_buf.dtype)
    y = y.at[tok].add(pair_out * w_sorted[:, None].astype(out_buf.dtype))
    return y


def moe(params, cfg: ModelConfig, x, router_key=None):
    """x: (B,S,d) -> (y, aux_loss)."""
    mo = cfg.moe
    B, S, d = x.shape
    E, k = mo.n_experts, mo.top_k
    T = B * S
    g = min(mo.group_size, T)
    assert T % g == 0, (T, g)
    G = T // g
    cap = int(math.ceil(g * k / E * mo.capacity_factor))
    cap = max(8, min(cap + (-cap) % 8, g))

    xf = x.reshape(G, g, d)
    xf = constrain(xf, "moe_groups", None, "embed")
    logits = jnp.einsum("Ggd,de->Gge", xf, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_e = lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=2),
        axis=(0, 1)) / k
    aux = E * jnp.sum(me * ce) * mo.aux_loss_coef

    bufs, metas = jax.vmap(
        lambda xi, ei, wi: _group_dispatch(xi, ei, wi, E, cap))(xf, top_e, top_w)
    bufs = bufs.reshape(G, E, cap, d)
    # "experts" resolves to None (TP-inside-experts, megatron rules) or to
    # "model" (expert parallelism, EP rules) — the all-to-all appears here.
    bufs = constrain(bufs, "moe_groups", "experts", "expert_cap", "embed")
    h = jax.nn.silu(jnp.einsum("Gecd,edf->Gecf", bufs,
                               params["wg"].astype(x.dtype))) * \
        jnp.einsum("Gecd,edf->Gecf", bufs, params["wi"].astype(x.dtype))
    h = constrain(h, "moe_groups", "experts", "expert_cap", "ff")
    out_buf = jnp.einsum("Gecf,efd->Gecd", h, params["wo"].astype(x.dtype))
    out_buf = constrain(out_buf, "moe_groups", "experts", "expert_cap", "embed")

    y = jax.vmap(lambda ob, m: _group_combine(ob.reshape(E * cap, d), m, g, k, d)
                 )(out_buf, metas)
    y = y.reshape(B, S, d)
    if mo.n_shared_experts:
        y = y + mlp(params["shared"], x)
    return constrain(y, "batch", "seq", "embed"), aux
