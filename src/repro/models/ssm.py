"""Mamba2 / SSD mixer (arXiv:2405.21060) — chunked state-space-duality form.

The chunked algorithm is matmul-dominated (MXU-friendly): within-chunk output
is a masked (C B^T) X product, cross-chunk flow is a tiny associative scan over
per-chunk states. ``ssd`` below is the pure-jnp implementation that also serves
as the oracle for the Pallas kernel in kernels/ssd_scan.py.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models.layers import Param, _dense_init, _ones, _zeros, rmsnorm


def ssd(x, dt, A, B, C, chunk: int, initial_state=None, return_state=False):
    """SSD scan.

    x: (b, s, h, p)   dt: (b, s, h)   A: (h,) (negative)
    B, C: (b, s, g, n) with h % g == 0.
    Returns y: (b, s, h, p) [, final_state (b, h, n, p)].
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    da = dt * A[None, None, :]                       # (b,s,h)
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    dac = da.reshape(b, nc, chunk, h)
    Bc = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)  # (b,nc,L,h,n)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)

    cum = jnp.cumsum(dac, axis=2)                    # (b,nc,L,h)
    # --- intra-chunk (diagonal blocks) ---
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (b,nc,L,L,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: masked entries have seg>0 (can overflow and would leak
    # NaNs through the where-gradient)
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    scores = jnp.einsum("bclhn,bcmhn->bclmh", Cc, Bc) * decay \
        * dtc[:, :, None, :, :]                               # (b,nc,L,L,h)
    y_diag = jnp.einsum("bclmh,bcmhp->bclhp", scores, xc)

    # --- per-chunk states ---
    chunk_sum = cum[:, :, -1, :]                              # (b,nc,h)
    decay_out = jnp.exp(chunk_sum[:, :, None, :] - cum)       # (b,nc,L,h)
    states = jnp.einsum("bclhn,bclh,bclhp->bchnp",
                        Bc, decay_out * dtc, xc)              # (b,nc,h,n,p)

    # --- inter-chunk recurrence: S_c+1 = exp(sum_da_c) S_c + states_c ---
    if initial_state is None:
        initial_state = jnp.zeros((b, h, n, p), x.dtype)
    gammas = jnp.exp(chunk_sum)                               # (b,nc,h)

    def combine(e1, e2):
        g1, s1 = e1
        g2, s2 = e2
        return g1 * g2, s1 * g2[..., None, None] + s2

    gs, ss = lax.associative_scan(
        combine, (gammas, states.astype(jnp.float32)), axis=1)
    # prepend initial state: inclusive scan gives state AFTER each chunk;
    # we need the state BEFORE each chunk (exclusive) for the off-diag term.
    init32 = initial_state.astype(jnp.float32)
    prev = jnp.concatenate(
        [init32[:, None], ss[:, :-1] + (gs[:, :-1, :, None, None] * init32[:, None])],
        axis=1)                                               # (b,nc,h,n,p)
    final_state = (ss[:, -1] + gs[:, -1, :, None, None] * init32).astype(x.dtype)

    # --- off-diagonal contribution ---
    y_off = jnp.einsum("bclhn,bchnp,bclh->bclhp",
                       Cc.astype(jnp.float32), prev, jnp.exp(cum))
    y = (y_diag.astype(jnp.float32) + y_off).reshape(b, s, h, p).astype(x.dtype)
    if return_state:
        return y, final_state
    return y


def ssd_decode_step(state, x, dt, A, B, C):
    """Single-token recurrence. state:(b,h,n,p) x:(b,h,p) dt:(b,h) B,C:(b,g,n)."""
    b, h, p = x.shape
    g = B.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1)                  # (b,h,n)
    Ch = jnp.repeat(C, rep, axis=1)
    da = jnp.exp(dt * A[None, :])                    # (b,h)
    new_state = state * da[..., None, None] + \
        (dt[..., None] * Bh)[..., :, None] * x[..., None, :]  # (b,h,n,p)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return new_state.astype(state.dtype), y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def init_mamba2(key, cfg: ModelConfig) -> Dict[str, Param]:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 5)
    lo, hi = s.a_init_range
    a_init = jnp.log(jnp.linspace(lo, hi, nheads, dtype=jnp.float32))
    return {
        # order: [z (d_inner), x (d_inner), B (g*n), C (g*n), dt (nheads)]
        "in_proj": _dense_init(ks[0], (d, 2 * d_inner + 2 * s.n_groups * s.d_state
                                       + nheads), ("embed", "ssm_inner")),
        "conv_w": _dense_init(ks[1], (s.d_conv, conv_dim), (None, "conv_dim"),
                              scale=1.0 / math.sqrt(s.d_conv)),
        "conv_b": _zeros((conv_dim,), ("conv_dim",)),
        "A_log": Param(a_init, ("ssm_heads",)),
        "D": _ones((nheads,), ("ssm_heads",)),
        "dt_bias": _zeros((nheads,), ("ssm_heads",)),
        "norm": _ones((d_inner,), ("ssm_inner",)),
        "out_proj": _dense_init(ks[2], (d_inner, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, b, state: Optional[jnp.ndarray] = None):
    """x:(B,S,C) depthwise causal conv, kernel w:(K,C). state:(B,K-1,C)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out + b[None, None, :], new_state


def mamba2_block(params, cfg: ModelConfig, x,
                 state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                 use_kernel: bool = False):
    """x: (B,S,d). state: (conv_state (B,K-1,conv_dim), ssm_state (B,h,n,p)).

    Returns (y, new_state or None).
    """
    s = cfg.ssm
    B_, S, d = x.shape
    d_inner = s.expand * d
    nheads = d_inner // s.head_dim
    gn = s.n_groups * s.d_state

    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * gn]
    dt_raw = zxbcdt[..., -nheads:]
    z = constrain(z, "batch", "seq", "ssm_inner")
    xbc = constrain(xbc, "batch", "seq", "conv_dim")

    conv_state = state[0] if state is not None else None
    xbc, new_conv_state = _causal_conv(xbc, params["conv_w"].astype(x.dtype),
                                       params["conv_b"].astype(x.dtype),
                                       conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_inner]
    Bmat = xbc[..., d_inner:d_inner + gn].reshape(B_, S, s.n_groups, s.d_state)
    Cmat = xbc[..., d_inner + gn:].reshape(B_, S, s.n_groups, s.d_state)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(B_, S, nheads, s.head_dim)
    xh = constrain(xh, "batch", "seq", "ssm_heads", None)

    if state is not None and S == 1:
        ssm_state = state[1]
        new_ssm, yh = ssd_decode_step(ssm_state, xh[:, 0], dt[:, 0], A,
                                      Bmat[:, 0], Cmat[:, 0])
        y = yh[:, None]
        new_state = (new_conv_state, new_ssm)
    elif use_kernel:
        from repro.kernels import ops as kops
        y = kops.ssd_scan(xh, dt.astype(x.dtype), A, Bmat, Cmat,
                          chunk=s.chunk_size)
        new_state = None
    else:
        y = ssd(xh, dt.astype(jnp.float32), A,
                Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
                chunk=min(s.chunk_size, S))
        new_state = None

    y = y + xh * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B_, S, d_inner)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm"]}, y, cfg.norm_eps)
    out = y @ params["out_proj"].astype(x.dtype)
    return constrain(out, "batch", "seq", "embed"), new_state


def mamba2_state_shape(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return ((batch, s.d_conv - 1, conv_dim),
            (batch, nheads, s.d_state, s.head_dim))
