"""HuBERT-style encoder-only transformer. The wav2vec2 conv feature stem is a
STUB per the assignment: input_specs() supplies precomputed frame embeddings
(B, T, frontend_dim); here we project them, add a convolutional positional
embedding, and run bidirectional attention layers. Head predicts the masked
codebook targets (vocab=504)."""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models.transformer import (_layer_apply, _remat, _stack, init_layer,
                                      scan_layers)

_CONV_POS_K = 31


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 4)
    blocks = [init_layer(keys[i], cfg, dense_ffn=False)
              for i in range(cfg.n_layers)]
    return {
        "frontend_proj": L._dense_init(keys[-1], (cfg.frontend_dim, cfg.d_model),
                                       (None, "embed")),
        "pos_conv": L._dense_init(keys[-2], (_CONV_POS_K, cfg.d_model),
                                  (None, "embed"),
                                  scale=1.0 / math.sqrt(_CONV_POS_K)),
        "layers": _stack(blocks),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "head": L._dense_init(keys[-3], (cfg.d_model, cfg.vocab_size),
                              ("embed", "vocab")),
    }


def forward(params, cfg: ModelConfig, features, positions=None,
            input_embeds=None):
    """features: (B, T, frontend_dim) precomputed frame embeddings (stub)."""
    x = features.astype(cfg.dtype) @ params["frontend_proj"].astype(cfg.dtype)
    x = constrain(x, "batch", "seq", "embed")
    B, T, d = x.shape
    # depthwise "same" conv positional embedding
    w = params["pos_conv"].astype(x.dtype)
    half = _CONV_POS_K // 2
    xp = jnp.pad(x, ((0, 0), (half, half), (0, 0)))
    pos = sum(xp[:, i:i + T] * w[i][None, None, :] for i in range(_CONV_POS_K))
    x = x + jax.nn.gelu(pos)

    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def body(x, lp):
        x, aux, _ = _layer_apply(lp, cfg, x, positions, is_dense_ffn=False)
        return x, aux

    x, _ = scan_layers(body, x, params["layers"], cfg)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["head"].astype(cfg.dtype)
    return constrain(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)
