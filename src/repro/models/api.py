"""Unified model API: init / loss / prefill / decode + ShapeDtypeStruct input
specs for every (arch x shape) cell. This is the surface the launcher, dry-run,
tests and benchmarks program against.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import constrain
from repro.models import encoder, hybrid, ssm_lm, transformer
from repro.models import layers as L


def _module(cfg: ModelConfig):
    if cfg.family == "ssm":
        return ssm_lm
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "audio":
        return encoder
    return transformer  # dense | moe | vlm


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init(cfg: ModelConfig, key=None):
    """Returns (param_values, param_axes) pytrees."""
    key = key if key is not None else jax.random.PRNGKey(0)
    tree = _module(cfg).init_params(key, cfg)
    return L.split_params(tree)


def _shapes_and_axes(builder):
    """eval_shape a Param-tree builder without allocation; axes via side
    channel (they are static python metadata)."""
    box = {}

    def f():
        vals, axes = L.split_params(builder())
        box["axes"] = axes
        return vals

    shapes = jax.eval_shape(f)
    return shapes, box["axes"]


def param_axes(cfg: ModelConfig):
    """Axes pytree without materializing params."""
    return _shapes_and_axes(
        lambda: _module(cfg).init_params(jax.random.PRNGKey(0), cfg))[1]


def param_shapes(cfg: ModelConfig):
    return _shapes_and_axes(
        lambda: _module(cfg).init_params(jax.random.PRNGKey(0), cfg))[0]


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------
def cross_entropy(logits, labels):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    mod = _module(cfg)
    if cfg.family == "audio":
        logits, aux = mod.forward(params, cfg, batch["features"])
    elif cfg.family == "vlm":
        logits, aux = mod.forward(params, cfg, batch["tokens"],
                                  positions=batch.get("positions"))
    else:
        logits, aux = mod.forward(params, cfg, batch["tokens"])
    return cross_entropy(logits, batch["labels"]) + aux


def forward(params, cfg: ModelConfig, *args, **kw):
    return _module(cfg).forward(params, cfg, *args, **kw)


def prefill(params, cfg: ModelConfig, batch):
    """Forward returning logits only (inference prefill)."""
    if cfg.family == "audio":
        logits, _ = _module(cfg).forward(params, cfg, batch["features"])
    elif cfg.family == "vlm":
        logits, _ = _module(cfg).forward(params, cfg, batch["tokens"],
                                         positions=batch.get("positions"))
    else:
        logits, _ = _module(cfg).forward(params, cfg, batch["tokens"])
    return logits


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    """Returns (state_values, state_axes) for the decode carrier
    (KV cache / SSM state / both)."""
    if cfg.family == "ssm":
        tree = ssm_lm.init_state(cfg, batch, max_len, dtype)
    elif cfg.family == "hybrid":
        tree = hybrid.init_state(cfg, batch, max_len, dtype)
    elif cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode state")
    else:
        tree = transformer.init_cache(cfg, batch, max_len, dtype)
    return L.split_params(tree)


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16):
    builder = {
        "ssm": ssm_lm.init_state, "hybrid": hybrid.init_state,
    }.get(cfg.family, transformer.init_cache)
    return _shapes_and_axes(lambda: builder(cfg, batch, max_len, dtype))


def decode_step(params, cfg: ModelConfig, state, tokens, index):
    mod = _module(cfg)
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode step")
    return mod.decode_step(params, cfg, state, tokens, index)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation) per shape cell
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, shape: ShapeConfig
                ) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, tuple]]:
    """Train/prefill batch: (specs, logical_axes)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "audio":
        specs = {
            "features": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                             jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        axes = {"features": ("batch", "seq", None), "labels": ("batch", "seq")}
    elif cfg.family == "vlm":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "positions": jax.ShapeDtypeStruct((3, B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        axes = {"tokens": ("batch", "seq"), "positions": (None, "batch", "seq"),
                "labels": ("batch", "seq")}
    else:
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if shape.kind == "prefill":
        specs.pop("labels")
        axes.pop("labels")
    return specs, axes


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Decode step inputs: tokens (B,), index scalar."""
    B = shape.global_batch
    specs = {
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }
    axes = {"tokens": ("batch",), "index": ()}
    return specs, axes


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key=None,
               batch_override: Optional[int] = None,
               seq_override: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """Materialize a synthetic batch (small shapes / tests only)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    k1, k2 = jax.random.split(key)
    if cfg.family == "audio":
        return {
            "features": jax.random.normal(k1, (B, S, cfg.frontend_dim),
                                          jnp.bfloat16),
            "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
        }
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
        batch["positions"] = pos
    return batch
