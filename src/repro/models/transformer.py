"""Decoder-only transformer LM covering the dense / moe / vlm families.

Layers are scanned (stacked params, jax.lax.scan) so HLO size is O(1) in depth
— essential for the 62-compile dry-run sweep. Activation checkpointing policy
comes from cfg.remat.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _stack(trees):
    return jax.tree.map(lambda *xs: L.Param(
        jnp.stack([x.value for x in xs]), ("layers",) + xs[0].axes),
        *trees, is_leaf=L.is_param)


def init_layer(key, cfg: ModelConfig, dense_ffn: bool) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "ln2": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.mla is not None:
        p["attn"] = L.init_mla(k1, cfg)
    else:
        p["attn"] = L.init_attention(k1, cfg)
    if cfg.moe is not None and not dense_ffn:
        p["moe"] = L.init_moe(k2, cfg)
    else:
        d_ff = cfg.dense_d_ff if (dense_ffn and cfg.dense_d_ff) else cfg.d_ff
        p["mlp"] = L.init_mlp(k2, cfg.d_model, d_ff, cfg.mlp_variant)
    return p


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 3)
    n_dense = cfg.first_k_dense
    dense_layers = [init_layer(keys[i], cfg, dense_ffn=True)
                    for i in range(n_dense)]
    scanned = [init_layer(keys[n_dense + i], cfg, dense_ffn=False)
               for i in range(cfg.n_layers - n_dense)]
    p: Dict[str, Any] = {
        "embed": L._dense_init(keys[-1], (cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), scale=0.02),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "layers": _stack(scanned),
    }
    if dense_layers:
        p["dense_layers"] = _stack(dense_layers)
    if not cfg.tie_embeddings:
        p["lm_head"] = L._dense_init(keys[-2], (cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"))
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _layer_apply(lp, cfg: ModelConfig, x, positions, is_dense_ffn: bool,
                 cache=None, cache_index=None):
    attn_fn = L.mla_attention if cfg.mla is not None else L.attention
    h, new_cache = attn_fn(lp["attn"], cfg, L.rmsnorm(lp["ln1"], x, cfg.norm_eps),
                           positions, cache, cache_index)
    x = x + h
    ffn_in = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if "moe" in lp and not is_dense_ffn:
        y, aux = L.moe(lp["moe"], cfg, ffn_in)
    else:
        y, aux = L.mlp(lp["mlp"], ffn_in), jnp.zeros((), jnp.float32)
    return x + y, aux, new_cache


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def scan_layers(body, x, stacked, cfg: ModelConfig):
    """lax.scan over stacked layer params, or an unrolled python loop when
    cfg.unroll_layers (dry-run probes: makes XLA cost_analysis see each layer)."""
    if not cfg.unroll_layers:
        return lax.scan(_remat(body, cfg), x, stacked)
    n = jax.tree.leaves(stacked)[0].shape[0]
    rematted = _remat(body, cfg)
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda v: v[i], stacked)
        x, y = rematted(x, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return x, ys


def forward(params, cfg: ModelConfig, tokens, positions=None,
            input_embeds=None):
    """tokens: (B,S) int32 (or input_embeds (B,S,d) for stubbed frontends).
    positions: (B,S) or (3,B,S) for M-RoPE. Returns logits (B,S,V) and aux loss.
    """
    if input_embeds is not None:
        x = input_embeds.astype(cfg.dtype)
        B, S = x.shape[:2]
    else:
        B, S = tokens.shape
        x = params["embed"].astype(cfg.dtype)[tokens]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[None], (3, B, S))
    x = constrain(x, "batch", "seq", "embed")

    aux_total = jnp.zeros((), jnp.float32)
    if "dense_layers" in params:
        def dense_body(x, lp):
            x, aux, _ = _layer_apply(lp, cfg, x, positions, is_dense_ffn=True)
            return x, aux
        x, auxs = scan_layers(dense_body, x, params["dense_layers"], cfg)
        aux_total = aux_total + jnp.sum(auxs)

    def body(x, lp):
        x, aux, _ = _layer_apply(lp, cfg, x, positions, is_dense_ffn=False)
        return x, aux

    x, auxs = scan_layers(body, x, params["layers"], cfg)
    aux_total = aux_total + jnp.sum(auxs)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(cfg.dtype)
    return constrain(logits, "batch", "seq", "vocab"), aux_total


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    n_scan = cfg.n_layers - cfg.first_k_dense
    if cfg.mla is not None:
        m = cfg.mla
        mk = lambda n, *shape, axes: L.Param(  # noqa: E731
            jnp.zeros((n,) + shape, dtype), ("layers",) + axes)
        c: Dict[str, Any] = {"layers": {
            "c_kv": mk(n_scan, batch, max_len, m.kv_lora_rank,
                       axes=("batch", "kv_seq", "qk_lora")),
            "k_rope": mk(n_scan, batch, max_len, m.qk_rope_head_dim,
                         axes=("batch", "kv_seq", None)),
        }}
        if cfg.first_k_dense:
            c["dense_layers"] = {
                "c_kv": mk(cfg.first_k_dense, batch, max_len, m.kv_lora_rank,
                           axes=("batch", "kv_seq", "qk_lora")),
                "k_rope": mk(cfg.first_k_dense, batch, max_len,
                             m.qk_rope_head_dim, axes=("batch", "kv_seq", None)),
            }
        return c
    kv, hd = cfg.n_kv_heads, cfg.head_dim

    def mk(n):
        kv_dtype = jnp.int8 if cfg.kv_quant else dtype
        d = {
            "k": L.Param(jnp.zeros((n, batch, max_len, kv, hd), kv_dtype),
                         ("layers", "batch", "kv_seq", "kv_heads", None)),
            "v": L.Param(jnp.zeros((n, batch, max_len, kv, hd), kv_dtype),
                         ("layers", "batch", "kv_seq", "kv_heads", None)),
        }
        if cfg.kv_quant:
            d["k_scale"] = L.Param(
                jnp.zeros((n, batch, max_len, kv), jnp.float32),
                ("layers", "batch", "kv_seq", "kv_heads"))
            d["v_scale"] = L.Param(
                jnp.zeros((n, batch, max_len, kv), jnp.float32),
                ("layers", "batch", "kv_seq", "kv_heads"))
        return d

    c = {"layers": mk(n_scan)}
    if cfg.first_k_dense:
        c["dense_layers"] = mk(cfg.first_k_dense)
    return c


def decode_step(params, cfg: ModelConfig, cache, tokens, index):
    """One decode step. tokens: (B,) int32; index: scalar position, or a
    (B,) vector of per-row positions (continuous batching — each slot at
    its own depth). Returns (logits (B,V), new_cache)."""
    B = tokens.shape[0]
    x = params["embed"].astype(cfg.dtype)[tokens][:, None]  # (B,1,d)
    if jnp.ndim(index) == 0:
        pos = jnp.full((B, 1), index, jnp.int32)
    else:
        pos = index.astype(jnp.int32)[:, None]
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    x = constrain(x, "batch", None, "embed")

    def scan_group(x, group_params, group_cache, dense):
        def body(x, lp_and_cache):
            lp, lc = lp_and_cache
            x, _, new_c = _layer_apply(lp, cfg, x, pos, dense,
                                       cache=lc, cache_index=index)
            return x, new_c
        return scan_layers(body, x, (group_params, group_cache), cfg)

    new_cache: Dict[str, Any] = {}
    if "dense_layers" in params:
        x, nc = scan_group(x, params["dense_layers"], cache["dense_layers"], True)
        new_cache["dense_layers"] = nc
    x, nc = scan_group(x, params["layers"], cache["layers"], False)
    new_cache["layers"] = nc

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head.astype(cfg.dtype))[:, 0]
    return constrain(logits, "batch", "vocab"), new_cache
