"""Zamba2-style hybrid: Mamba2 backbone + ONE weight-shared attention+MLP block
invoked after every `shared_attn_every` mamba layers (weight reuse — the
Zamba2 trick that buys attention quality at ~1/6 the attention param cost).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import _stack, scan_layers


def _split_counts(cfg: ModelConfig) -> Tuple[int, int]:
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    rem = cfg.n_layers - n_groups * every
    return n_groups, rem


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    n_groups, rem = _split_counts(cfg)
    keys = jax.random.split(key, cfg.n_layers + 4)
    mamba = [
        {"ln": L.init_rmsnorm(cfg.d_model),
         "mixer": S.init_mamba2(keys[i], cfg)}
        for i in range(cfg.n_layers)
    ]
    grouped = _stack(mamba[: n_groups * cfg.shared_attn_every])
    # reshape leading (n_groups*every) -> (n_groups, every)
    grouped = jax.tree.map(
        lambda p: L.Param(p.value.reshape(
            (n_groups, cfg.shared_attn_every) + p.value.shape[1:]),
            ("groups",) + p.axes), grouped, is_leaf=L.is_param)
    p: Dict[str, Any] = {
        "embed": L._dense_init(keys[-1], (cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), scale=0.02),
        "mamba_groups": grouped,
        "shared_attn": {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(keys[-2], cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(keys[-3], cfg.d_model, cfg.d_ff),
        },
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "lm_head": L._dense_init(keys[-4], (cfg.d_model, cfg.vocab_size),
                                 ("embed", "vocab")),
    }
    if rem:
        p["mamba_tail"] = _stack(mamba[n_groups * cfg.shared_attn_every:])
    return p


def _mamba_layer(lp, cfg, x, state=None):
    h, new_state = S.mamba2_block(lp["mixer"], cfg,
                                  L.rmsnorm(lp["ln"], x, cfg.norm_eps),
                                  state=state, use_kernel=cfg.use_pallas)
    return x + h, new_state


def _shared_attn_apply(sp, cfg, x, positions, cache=None, cache_index=None):
    h, new_cache = L.attention(sp["attn"], cfg,
                               L.rmsnorm(sp["ln1"], x, cfg.norm_eps),
                               positions, cache, cache_index)
    x = x + h
    x = x + L.mlp(sp["mlp"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps))
    return x, new_cache


def forward(params, cfg: ModelConfig, tokens, positions=None,
            input_embeds=None):
    B, Sq = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = constrain(x, "batch", "seq", "embed")
    sp = params["shared_attn"]

    def group_body(x, gp):
        def inner(x, lp):
            x, _ = _mamba_layer(lp, cfg, x)
            return x, None
        x, _ = scan_layers(inner, x, gp, cfg)
        x, _ = _shared_attn_apply(sp, cfg, x, positions)
        return x, None

    x, _ = scan_layers(group_body, x, params["mamba_groups"], cfg)
    if "mamba_tail" in params:
        def inner(x, lp):
            x, _ = _mamba_layer(lp, cfg, x)
            return x, None
        x, _ = scan_layers(inner, x, params["mamba_tail"], cfg)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.dtype)
    return constrain(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_state(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    n_groups, rem = _split_counts(cfg)
    every = cfg.shared_attn_every
    conv_shape, ssm_shape = S.mamba2_state_shape(cfg, batch)
    mk_conv = lambda *lead: L.Param(  # noqa: E731
        jnp.zeros(lead + conv_shape, dtype),
        tuple(["layers"] * len(lead)) + ("batch", None, "conv_dim"))
    mk_ssm = lambda *lead: L.Param(  # noqa: E731
        jnp.zeros(lead + ssm_shape, dtype),
        tuple(["layers"] * len(lead)) + ("batch", "ssm_heads", "ssm_state", None))
    st: Dict[str, Any] = {
        "groups": {"conv": mk_conv(n_groups, every), "ssm": mk_ssm(n_groups, every)},
        "attn_cache": {
            "k": L.Param(jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads,
                                    cfg.head_dim), dtype),
                         ("layers", "batch", "kv_seq", "kv_heads", None)),
            "v": L.Param(jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads,
                                    cfg.head_dim), dtype),
                         ("layers", "batch", "kv_seq", "kv_heads", None)),
        },
    }
    if rem:
        st["tail"] = {"conv": mk_conv(rem), "ssm": mk_ssm(rem)}
    return st


def decode_step(params, cfg: ModelConfig, state, tokens, index):
    B = tokens.shape[0]
    x = params["embed"].astype(cfg.dtype)[tokens][:, None]
    if jnp.ndim(index) == 0:
        pos = jnp.full((B, 1), index, jnp.int32)
    else:
        pos = index.astype(jnp.int32)[:, None]
    sp = params["shared_attn"]

    def group_body(x, xs):
        gp, gst, gcache = xs

        def inner(x, xs2):
            lp, (cs, ss) = xs2
            x, new_st = _mamba_layer(lp, cfg, x, state=(cs, ss))
            return x, new_st
        x, new_states = scan_layers(inner, x, (gp, (gst["conv"], gst["ssm"])),
                                    cfg)
        x, new_cache = _shared_attn_apply(sp, cfg, x, pos, cache=gcache,
                                          cache_index=index)
        return x, (new_states, new_cache)

    x, (gstates, gcaches) = scan_layers(
        group_body, x, (params["mamba_groups"], state["groups"],
                        state["attn_cache"]), cfg)
    new_state: Dict[str, Any] = {
        "groups": {"conv": gstates[0], "ssm": gstates[1]},
        "attn_cache": gcaches,
    }
    if "mamba_tail" in params:
        def inner(x, xs2):
            lp, (cs, ss) = xs2
            x, new_st = _mamba_layer(lp, cfg, x, state=(cs, ss))
            return x, new_st
        x, tail_states = scan_layers(
            inner, x, (params["mamba_tail"],
                       (state["tail"]["conv"], state["tail"]["ssm"])), cfg)
        new_state["tail"] = {"conv": tail_states[0], "ssm": tail_states[1]}

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["lm_head"].astype(cfg.dtype))[:, 0]
    return constrain(logits, "batch", "vocab"), new_state
