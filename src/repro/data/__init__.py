from repro.data.pipeline import (  # noqa: F401
    CIFARLikeSource, SyntheticTokenSource, ShardedLoader,
)
