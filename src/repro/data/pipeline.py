"""Deterministic, shard-aware, RESUMABLE data pipeline.

Transient training needs the data stream to be a pure function of
(seed, step, shard) so that (a) a restored worker resumes exactly where the
checkpoint left off and (b) elastic membership changes redistribute shards
without duplicating or dropping data. State is a tiny dict stored in every
checkpoint's metadata.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SyntheticTokenSource:
    """Zipf-ish synthetic LM tokens: deterministic per (seed, step, shard)."""
    vocab_size: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int, shard: int, n_shards: int,
              batch_per_shard: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        # zipf-like marginal over the vocab, cheap to draw
        u = rng.random((batch_per_shard, self.seq_len + 1))
        toks = ((self.vocab_size ** u - 1.0)
                / (self.vocab_size - 1.0) * (self.vocab_size - 1)).astype(
            np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class CIFARLikeSource:
    """32x32x3 synthetic image classification stream (the paper's workload
    shape; CIFAR-10 itself is not bundled offline — training-speed
    measurements only need the shapes, §III-A)."""
    n_classes: int = 10
    seed: int = 0

    def batch(self, step: int, shard: int, n_shards: int,
              batch_per_shard: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed + 7, step, shard]))
        x = rng.normal(0.0, 1.0, (batch_per_shard, 32, 32, 3)).astype(
            np.float32)
        y = rng.integers(0, self.n_classes, batch_per_shard).astype(np.int32)
        return {"images": x, "labels": y}


@dataclasses.dataclass
class SyntheticAudioSource:
    """Frame-embedding stream for encoder (audio) archs: (features, labels)
    deterministic per (seed, step, shard). Stands in for precomputed
    HuBERT-style frontend frames."""
    frontend_dim: int
    vocab_size: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int, shard: int, n_shards: int,
              batch_per_shard: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed + 13, step, shard]))
        return {
            "features": rng.normal(
                0, 1, (batch_per_shard, self.seq_len, self.frontend_dim)
            ).astype(np.float32),
            "labels": rng.integers(
                0, self.vocab_size, (batch_per_shard, self.seq_len)
            ).astype(np.int32),
        }


def source_for_config(cfg, seq_len: int, seed: int = 0):
    """Pick the synthetic source matching a ModelConfig's input modality."""
    if cfg.family == "audio":
        return SyntheticAudioSource(cfg.frontend_dim, cfg.vocab_size,
                                    seq_len, seed=seed)
    return SyntheticTokenSource(cfg.vocab_size, seq_len, seed=seed)


class ShardedLoader:
    """Iterator facade with explicit state: (step,). Elastic-safe: shard
    count/batch come per-call so membership changes take effect next step."""

    def __init__(self, source, global_batch: int, start_step: int = 0):
        self.source = source
        self.global_batch = global_batch
        self.step = start_step

    def state(self) -> Dict[str, int]:
        return {"step": self.step, "global_batch": self.global_batch}

    @classmethod
    def from_state(cls, source, state: Dict[str, int]) -> "ShardedLoader":
        return cls(source, state["global_batch"], start_step=state["step"])

    def next_global(self, n_shards: int = 1) -> Dict[str, np.ndarray]:
        """Materialize the full global batch (concatenated shards)."""
        per = self.global_batch // max(1, n_shards)
        shards = [self.source.batch(self.step, s, n_shards, per)
                  for s in range(n_shards)]
        self.step += 1
        return {k: np.concatenate([sh[k] for sh in shards])
                for k in shards[0]}

    def next_shard(self, shard: int, n_shards: int) -> Dict[str, np.ndarray]:
        per = self.global_batch // max(1, n_shards)
        out = self.source.batch(self.step, shard, n_shards, per)
        self.step += 1
        return out
