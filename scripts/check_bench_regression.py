"""Perf regression gate over BENCH_mc.json (CI `perf` job).

Compares a freshly measured BENCH_mc.json against the committed baseline
(`benchmarks/BENCH_mc.baseline.json` — the generated root BENCH_mc.json
itself stays gitignored) and fails on a >20% slowdown of either gated
metric. CI runners differ wildly in absolute speed, so both gated
metrics are *relative* ones each run measures on its own box:

* `planner_grid.speedup` — batched `plan_launch` vs. the in-run pinned
  scalar loop (the PR 3 follow-up noted in ROADMAP.md);
* `batched_engine.speedup` — the lockstep ensemble engine vs. the
  event-loop oracle at n=1024 trajectories, which additionally must
  stay above an absolute floor (default 10x, the lockstep-engine PR's
  acceptance bar);
* `jit_engine.speedup` — the compiled `engine="jit"` program vs. the
  NumPy lockstep engine at n=65536 chaos trajectories, with its own
  absolute floor (default 5x, the jit-engine PR's acceptance bar).

Absolute `batched_s`/`jit_s` numbers are reported for context but never
gated.

    python scripts/check_bench_regression.py [--max-slowdown 0.2] \
        [--min-engine-speedup 10.0] [--min-jit-speedup 5.0] \
        [--baseline benchmarks/BENCH_mc.baseline.json] \
        [--current BENCH_mc.json]

Exit nonzero when a current speedup < (1 - max_slowdown) * its baseline,
or an engine speedup < its absolute floor.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load(path: str) -> dict:
    return json.loads(pathlib.Path(path).read_text(encoding="utf-8"))


def check(baseline: dict, current: dict, max_slowdown: float,
          min_engine_speedup: float = 10.0,
          min_jit_speedup: float = 5.0) -> list:
    errors = []
    base_grid = baseline.get("planner_grid", {})
    cur_grid = current.get("planner_grid", {})
    base_speedup = base_grid.get("speedup")
    cur_speedup = cur_grid.get("speedup")
    if base_speedup is None or cur_speedup is None:
        return ["planner_grid.speedup missing from baseline or current"]
    floor = (1.0 - max_slowdown) * base_speedup
    print(f"planner_grid: baseline speedup {base_speedup}x "
          f"(batched {base_grid.get('batched_s')}s), current "
          f"{cur_speedup}x (batched {cur_grid.get('batched_s')}s); "
          f"floor {floor:.1f}x")
    if cur_speedup < floor:
        errors.append(
            f"planner-grid regression: speedup {cur_speedup}x fell below "
            f"{floor:.1f}x (= {1 - max_slowdown:.0%} of the committed "
            f"{base_speedup}x baseline)")
    base_eng = baseline.get("batched_engine", {}).get("speedup")
    cur_eng = current.get("batched_engine", {}).get("speedup")
    if base_eng is None or cur_eng is None:
        errors.append(
            "batched_engine.speedup missing from baseline or current")
    else:
        eng_floor = max((1.0 - max_slowdown) * base_eng,
                        min_engine_speedup)
        print(f"batched_engine: baseline speedup {base_eng}x, current "
              f"{cur_eng}x "
              f"({current['batched_engine'].get('traj_per_s')} traj/s); "
              f"floor {eng_floor:.1f}x")
        if cur_eng < eng_floor:
            errors.append(
                f"batched-engine regression: speedup {cur_eng}x fell "
                f"below {eng_floor:.1f}x (max of {1 - max_slowdown:.0%} "
                f"of the committed {base_eng}x baseline and the "
                f"{min_engine_speedup}x absolute floor)")
    base_jit = baseline.get("jit_engine", {}).get("speedup")
    cur_jit = current.get("jit_engine", {}).get("speedup")
    if base_jit is None or cur_jit is None:
        errors.append(
            "jit_engine.speedup missing from baseline or current")
    else:
        jit_floor = max((1.0 - max_slowdown) * base_jit, min_jit_speedup)
        print(f"jit_engine: baseline speedup {base_jit}x, current "
              f"{cur_jit}x "
              f"({current['jit_engine'].get('traj_per_s')} traj/s on "
              f"{current['jit_engine'].get('devices')} device(s)); "
              f"floor {jit_floor:.1f}x")
        if cur_jit < jit_floor:
            errors.append(
                f"jit-engine regression: speedup {cur_jit}x fell below "
                f"{jit_floor:.1f}x (max of {1 - max_slowdown:.0%} of the "
                f"committed {base_jit}x baseline and the "
                f"{min_jit_speedup}x absolute floor)")
    ens_b = baseline.get("ensemble", {}).get("traj_per_s")
    ens_c = current.get("ensemble", {}).get("traj_per_s")
    if ens_b and ens_c:  # informational only: absolute, machine-dependent
        print(f"ensemble: baseline {ens_b} traj/s, current {ens_c} traj/s")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline",
                    default=str(ROOT / "benchmarks"
                                / "BENCH_mc.baseline.json"),
                    help="committed BENCH_mc.json snapshot")
    ap.add_argument("--current", default=str(ROOT / "BENCH_mc.json"),
                    help="freshly measured BENCH_mc.json")
    ap.add_argument("--max-slowdown", type=float, default=0.2,
                    help="allowed fractional speedup loss (default 0.2)")
    ap.add_argument("--min-engine-speedup", type=float, default=10.0,
                    help="absolute batched-vs-event floor at n=1024 "
                         "(default 10.0)")
    ap.add_argument("--min-jit-speedup", type=float, default=5.0,
                    help="absolute jit-vs-batched floor at n=65536 "
                         "chaos trajectories (default 5.0)")
    args = ap.parse_args(argv)
    errors = check(_load(args.baseline), _load(args.current),
                   args.max_slowdown, args.min_engine_speedup,
                   args.min_jit_speedup)
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    if not errors:
        print("perf gate OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
