"""Perf regression gate over BENCH_mc.json (CI `perf` job).

Compares a freshly measured BENCH_mc.json against the committed baseline
(`benchmarks/BENCH_mc.baseline.json` — the generated root BENCH_mc.json
itself stays gitignored) and fails on a >20% planner-grid slowdown (the
PR 3 follow-up noted in ROADMAP.md). CI runners differ wildly in absolute
speed, so the gated metric is the *relative* one each run measures
against its own pinned scalar baseline — `planner_grid.speedup` (batched
vs. in-run scalar): if the batched planner regresses, its speedup over
the frozen scalar loop drops on any machine. Absolute `batched_s` numbers
are reported for context but never gated.

    python scripts/check_bench_regression.py [--max-slowdown 0.2] \
        [--baseline benchmarks/BENCH_mc.baseline.json] \
        [--current BENCH_mc.json]

Exit nonzero when current speedup < (1 - max_slowdown) * baseline speedup.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load(path: str) -> dict:
    return json.loads(pathlib.Path(path).read_text(encoding="utf-8"))


def check(baseline: dict, current: dict, max_slowdown: float) -> list:
    errors = []
    base_grid = baseline.get("planner_grid", {})
    cur_grid = current.get("planner_grid", {})
    base_speedup = base_grid.get("speedup")
    cur_speedup = cur_grid.get("speedup")
    if base_speedup is None or cur_speedup is None:
        return ["planner_grid.speedup missing from baseline or current"]
    floor = (1.0 - max_slowdown) * base_speedup
    print(f"planner_grid: baseline speedup {base_speedup}x "
          f"(batched {base_grid.get('batched_s')}s), current "
          f"{cur_speedup}x (batched {cur_grid.get('batched_s')}s); "
          f"floor {floor:.1f}x")
    if cur_speedup < floor:
        errors.append(
            f"planner-grid regression: speedup {cur_speedup}x fell below "
            f"{floor:.1f}x (= {1 - max_slowdown:.0%} of the committed "
            f"{base_speedup}x baseline)")
    ens_b = baseline.get("ensemble", {}).get("traj_per_s")
    ens_c = current.get("ensemble", {}).get("traj_per_s")
    if ens_b and ens_c:  # informational only: absolute, machine-dependent
        print(f"ensemble: baseline {ens_b} traj/s, current {ens_c} traj/s")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline",
                    default=str(ROOT / "benchmarks"
                                / "BENCH_mc.baseline.json"),
                    help="committed BENCH_mc.json snapshot")
    ap.add_argument("--current", default=str(ROOT / "BENCH_mc.json"),
                    help="freshly measured BENCH_mc.json")
    ap.add_argument("--max-slowdown", type=float, default=0.2,
                    help="allowed fractional speedup loss (default 0.2)")
    args = ap.parse_args(argv)
    errors = check(_load(args.baseline), _load(args.current),
                   args.max_slowdown)
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    if not errors:
        print("perf gate OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
