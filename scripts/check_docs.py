"""Docs gate (CI `docs` job; tests/test_docs.py runs the link check).

Two checks over the repo's markdown tree:

1. **Links** — every intra-repo markdown link (`[x](path)`, relative, no
   scheme) must resolve to an existing file or directory, and every
   `docs/DESIGN.md §N` / `DESIGN.md §N` section citation in *source
   docstrings* must point at a section DESIGN.md actually numbers.
2. **Snippets** (`--exec`) — every ```python block in README.md runs
   as-is, in order, in one shared namespace — the doctest-style guarantee
   that the quickstart (`Session.from_arch(...).plan(...)`) works.

Exit nonzero on any failure, listing each one.

    PYTHONPATH=src python scripts/check_docs.py [--exec]
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys
from typing import List

ROOT = pathlib.Path(__file__).resolve().parent.parent

# [text](target) — excluding images; schemes and in-page anchors skipped
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
_SECTION_CITE_RE = re.compile(r"DESIGN\.md\s+§(\d+)")


def iter_markdown() -> List[pathlib.Path]:
    md = sorted(ROOT.glob("*.md")) + sorted((ROOT / "docs").glob("*.md"))
    return [p for p in md if p.is_file()]


def check_links() -> List[str]:
    errors = []
    for md in iter_markdown():
        text = md.read_text(encoding="utf-8")
        for target in _LINK_RE.findall(text):
            if "://" in target or target.startswith(("mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_section_citations() -> List[str]:
    design = ROOT / "docs" / "DESIGN.md"
    if not design.exists():
        return ["docs/DESIGN.md does not exist"]
    sections = set(re.findall(r"^##\s+§(\d+)", design.read_text(), re.M))
    errors = []
    for src_dir in ("src", "benchmarks", "tests", "scripts", "examples"):
        for py in sorted((ROOT / src_dir).rglob("*.py")):
            for n in _SECTION_CITE_RE.findall(py.read_text(encoding="utf-8")):
                if n not in sections:
                    errors.append(f"{py.relative_to(ROOT)}: cites "
                                  f"DESIGN.md §{n}, which does not exist "
                                  f"(have: {sorted(sections)})")
    return errors


def readme_snippets() -> List[str]:
    text = (ROOT / "README.md").read_text(encoding="utf-8")
    return re.findall(r"```python\n(.*?)```", text, re.S)


def exec_snippets() -> List[str]:
    ns: dict = {"__name__": "__readme__"}
    errors = []
    for i, snippet in enumerate(readme_snippets()):
        try:
            exec(compile(snippet, f"README.md#python-{i}", "exec"), ns)
        except Exception as e:  # noqa: BLE001 - report and continue
            errors.append(f"README.md python block {i} failed: "
                          f"{type(e).__name__}: {e}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--exec", action="store_true", dest="do_exec",
                    help="also execute README ```python blocks")
    args = ap.parse_args(argv)
    errors = check_links() + check_section_citations()
    if args.do_exec:
        errors += exec_snippets()
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    n_md = len(iter_markdown())
    n_sn = len(readme_snippets())
    print(f"checked {n_md} markdown files"
          + (f", executed {n_sn} README snippets" if args.do_exec else "")
          + f": {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
