#!/usr/bin/env python
"""Run the full (arch x shape) dry-run sweep as parallel subprocesses.

Each cell is an isolated process (jax device-count env must be set before
import; a crash in one cell cannot kill the sweep). Resumable: cells with an
existing artifact are skipped.

Usage: python scripts/run_dryrun_sweep.py [--jobs 3] [--mesh both]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts", "dryrun")

sys.path.insert(0, os.path.join(ROOT, "src"))
from repro.configs import ALL_SHAPES, ARCH_IDS, get_config, valid_cells  # noqa: E402


def cells():
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        valid = {s.name for s in valid_cells(cfg)}
        for s in ALL_SHAPES:
            out.append((arch, s.name, s.name in valid))
    return out


def run_one(arch: str, shape: str, mesh: str, timeout: int):
    path = os.path.join(ART, f"{arch}__{shape}.json")
    if os.path.exists(path):
        return arch, shape, "cached"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", path]
    t0 = time.time()
    try:
        p = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=timeout, cwd=ROOT)
        status = "ok" if p.returncode == 0 else "FAIL"
        if p.returncode != 0:
            with open(path + ".err", "w") as f:
                f.write(p.stdout[-5000:] + "\n--stderr--\n" + p.stderr[-10000:])
    except subprocess.TimeoutExpired:
        status = "TIMEOUT"
        with open(path + ".err", "w") as f:
            f.write("timeout\n")
    return arch, shape, f"{status} ({time.time()-t0:.0f}s)"


def main():
    from repro.launch.cli import make_parser
    ap = make_parser("run_dryrun_sweep",
                     "parallel (arch x shape) dry-run sweep, resumable")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--mesh", default="both")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()
    os.makedirs(ART, exist_ok=True)

    todo = cells()
    print(f"{len(todo)} cells total")
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = {}
        for arch, shape, valid in todo:
            if not valid:
                # still record the skip (spec: note skips)
                path = os.path.join(ART, f"{arch}__{shape}.json")
                if not os.path.exists(path):
                    with open(path, "w") as f:
                        json.dump([{"arch": arch, "shape": shape, "ok": False,
                                    "skipped": True,
                                    "reason": "inapplicable cell "
                                              "(docs/DESIGN.md §4)"}], f)
                print(f"SKIP {arch} {shape}")
                continue
            futs[ex.submit(run_one, arch, shape, args.mesh,
                           args.timeout)] = (arch, shape)
        for fut in as_completed(futs):
            arch, shape, status = fut.result()
            print(f"{arch:24s} {shape:12s} {status}", flush=True)


if __name__ == "__main__":
    main()
