#!/usr/bin/env python
"""Deprecation shim: the sweep driver now lives in `repro.launch.sweep`
(`python -m repro dryrun --sweep`). This wrapper keeps the old entry point
working for scripts that still call it."""
from __future__ import annotations

import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.launch import sweep  # noqa: E402

if __name__ == "__main__":
    # keep the historical default of writing under the repo root even when
    # invoked from elsewhere
    os.chdir(ROOT)
    sys.exit(sweep.main())
