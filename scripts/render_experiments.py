#!/usr/bin/env python
"""Render EXPERIMENTS.md sections from dry-run/hillclimb artifacts.

Usage: PYTHONPATH=src python scripts/render_experiments.py
Prints: §Dry-run summary table + §Roofline single-pod table + hillclimb rows.
"""
from __future__ import annotations

import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)

from benchmarks import roofline  # noqa: E402


def dryrun_section() -> str:
    rows = roofline.dryrun_status()
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    failed = [r for r in rows if r["status"] == "FAILED"]
    lines = [
        f"Compiled cells: {len(ok)} ok, {len(skipped)} skipped "
        f"(inapplicable per docs/DESIGN.md §4), {len(failed)} failed.",
        "",
        "| arch | shape | mesh | status | compile s | temp GB/device |",
        "|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (str(r["arch"]), str(r["shape"]),
                                         str(r["mesh"]))):
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                     f"| {r['status']} | {r['compile_s'] or '—'} "
                     f"| {r['temp_gb']:.1f} |")
    return "\n".join(lines)


def roofline_section() -> str:
    return roofline.markdown_table(roofline.run("16x16"))


def hillclimb_section() -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(ROOT, "artifacts", "hillclimb",
                                              "*.json"))):
        for r in json.load(open(path)):
            if not r.get("ok"):
                rows.append({"name": os.path.basename(path),
                             "error": True})
                continue
            t = r["roofline"]
            rows.append({
                "name": os.path.basename(path).replace(".json", ""),
                "arch": r["arch"], "rules": r["rules"],
                "mw": r.get("master_weights"), "remat": r.get("remat"),
                "compute_s": t["compute_s"], "memory_s": t["memory_s"],
                "collective_s": t["collective_s"],
                "bottleneck": r["bottleneck"],
                "frac": t["compute_s"] / max(t.values()),
            })
    lines = ["| variant | rules | mw | compute s | memory s | collective s |"
             " bottleneck | roofline frac |", "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("error"):
            lines.append(f"| {r['name']} | FAILED | | | | | | |")
            continue
        lines.append(f"| {r['name']} | {r['rules']} | {r['mw']} "
                     f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                     f"| {r['collective_s']:.3f} | "
                     f"{r['bottleneck'].replace('_s','')} | {r['frac']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    section = sys.argv[1] if len(sys.argv) > 1 else "all"
    if section in ("all", "dryrun"):
        print("## Dry-run\n")
        print(dryrun_section())
    if section in ("all", "roofline"):
        print("\n## Roofline (single-pod 16x16)\n")
        print(roofline_section())
    if section in ("all", "hillclimb"):
        print("\n## Hillclimb variants\n")
        print(hillclimb_section())
