"""Table III — individual worker step time vs cluster size/heterogeneity
(ResNet-32): flat until the PS saturates; heterogeneity doesn't slow peers.
Reproduced with the async-PS queueing model (core/ps_async.py).
"""
from __future__ import annotations

import numpy as np

from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.core.ps_async import ps_queue_sim
from repro.models import cnn

import jax


def model_bytes() -> float:
    return 4.0 * cnn.param_count(cnn.RESNET_32)


def n_tensors() -> int:
    tree = jax.eval_shape(lambda: cnn.init_params(jax.random.PRNGKey(0),
                                                  cnn.RESNET_32))
    return len(jax.tree.leaves(tree))


def run():
    gens = calibrate_generators()
    c_m = TABLE1_MODELS["resnet_32"]
    t = {g: gens[g].step_time(c_m) for g in ("k80", "p100", "v100")}
    mb = model_bytes()
    nt = n_tensors()
    clusters = {
        "(1,0,0)": ["k80"], "(2,0,0)": ["k80"] * 2, "(4,0,0)": ["k80"] * 4,
        "(8,0,0)": ["k80"] * 8,
        "(0,4,0)": ["p100"] * 4, "(0,8,0)": ["p100"] * 8,
        "(0,0,4)": ["v100"] * 4, "(0,0,8)": ["v100"] * 8,
        "(2,1,1)": ["k80", "k80", "p100", "v100"],
    }
    out = []
    for name, gpus in clusters.items():
        res = ps_queue_sim([t[g] for g in gpus], mb, n_ps=1, steps=300,
                           n_tensors=nt)
        for gpu in sorted(set(gpus)):
            idx = gpus.index(gpu)
            eff_ms = res.worker_step_time[idx] * 1000
            solo_ms = t[gpu] * 1000
            out.append({"name": f"table3/{name}/{gpu}",
                        "value": round(eff_ms, 2),
                        "derived": f"solo={solo_ms:.2f}ms "
                                   f"slowdown={eff_ms/solo_ms:.3f} "
                                   f"ps_util={res.ps_utilization:.2f}"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
