"""Fig 4 — cluster training speed vs #P100 workers for the four T2T models:
near-linear for ResNet-15; plateaus for ResNet-32 / Shake-Shake-small
(PS bottleneck); flat-low for Shake-Shake-Big (GPU-bound).
"""
from __future__ import annotations

from repro.core.perf_model.cluster_model import PSBottleneckModel, WorkerSpec, cluster_speed
from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.models import cnn

SPECS = {"resnet_15": cnn.RESNET_15, "resnet_32": cnn.RESNET_32,
         "shake_shake_small": cnn.SHAKE_SMALL, "shake_shake_big": cnn.SHAKE_BIG}


def run():
    import jax
    gens = calibrate_generators()
    out = []
    for model, c_m in TABLE1_MODELS.items():
        solo = 1.0 / gens["p100"].step_time(c_m)
        spec = SPECS[model]
        mb = 4.0 * cnn.param_count(spec)
        nt = len(jax.tree.leaves(jax.eval_shape(
            lambda s=spec: cnn.init_params(jax.random.PRNGKey(0), s))))
        ps = PSBottleneckModel(mb, n_ps=1, n_tensors=nt)
        for n in (1, 2, 4, 6, 8):
            sp = cluster_speed([WorkerSpec("p100", solo)] * n, ps)
            out.append({"name": f"fig4/{model}/p100x{n}",
                        "value": round(sp, 3),
                        "derived": f"linear={solo*n:.3f} "
                                   f"capped={sp < solo*n - 1e-9}"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
