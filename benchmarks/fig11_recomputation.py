"""Fig 11 — TensorFlow-specific recomputation overhead: revoke the chief 1K
steps after a checkpoint; vary replacement timing; compare stock (reuse chief
identity -> recompute from last checkpoint) vs CM-DARE handover (bounded by
the checkpoint interval, overhead ~ 0 here).
"""
from __future__ import annotations

from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.core.transient.replacement import recomputation_overhead_s


def run():
    gens = calibrate_generators()
    sp2 = 2.0 / gens["k80"].step_time(TABLE1_MODELS["resnet_15"])  # 2x K80
    sp1 = sp2 / 2.0
    out = []
    for replace_after_s in (0, 60, 120, 240):
        # stock: replacement inherits chief IP -> cluster redoes 1k steps
        stock = recomputation_overhead_s(1000, sp1, reuse_chief_identity=True)
        dare = recomputation_overhead_s(1000, sp1, reuse_chief_identity=False)
        out.append({"name": f"fig11/replace_after_{replace_after_s}s",
                    "value": round(stock, 1),
                    "derived": f"handover={dare:.1f}s "
                               f"savings={stock - dare:.1f}s"})
    # bound: recompute can never exceed I_c / speed
    i_c = 4000
    bound = i_c / sp1
    out.append({"name": "fig11/bound_checkpoint_interval_s",
                "value": round(bound, 1),
                "derived": f"I_c={i_c} steps at {sp1:.2f} steps/s; paper ~224s "
                           "at its cluster speed"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
