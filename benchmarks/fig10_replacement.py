"""Fig 10 — worker replacement overhead, cold vs warm.

Two parts: (a) the calibrated model for the paper's four CNNs; (b) a REAL
measurement on this host: cold = build params + jit train step from scratch
(fresh process semantics: cache cleared), warm = re-jit with params resident.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.transient.replacement import ReplacementModel
from repro.core.perf_model.speed_model import TABLE1_MODELS
from repro.models import cnn


def run():
    out = []
    m = ReplacementModel(seed=0)
    for model, c_m in TABLE1_MODELS.items():
        out.append({"name": f"fig10/model/{model}/cold",
                    "value": round(m.cold_start_s(c_m), 1),
                    "derived": f"warm={m.warm_start_s(c_m):.1f}s"})
    # real measurement (small CNN so it fits in benchmark time)
    spec = cnn.RESNET_15
    imgs = jnp.zeros((8, 32, 32, 3))
    labels = jnp.zeros((8,), jnp.int32)

    t0 = time.monotonic()
    params = cnn.init_params(jax.random.PRNGKey(0), spec)
    step = jax.jit(lambda p: cnn.loss_fn(p, spec, imgs, labels))
    step(params).block_until_ready()
    cold = time.monotonic() - t0

    t0 = time.monotonic()
    step(params).block_until_ready()  # warm: compiled + resident
    warm_exec = time.monotonic() - t0
    t0 = time.monotonic()
    step2 = jax.jit(lambda p: cnn.loss_fn(p, spec, imgs, labels))
    step2(params).block_until_ready()  # warm restart: re-trace, cache hits
    warm = time.monotonic() - t0

    out.append({"name": "fig10/real/resnet15_cold_s",
                "value": round(cold, 3),
                "derived": f"warm_restart={warm:.3f}s exec={warm_exec*1e3:.1f}ms "
                           f"cold>warm={int(cold > warm)}"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
