"""Beyond-paper: the same workload priced across three transient markets.

The paper's cost/overhead analysis (§V-§VI) is single-cloud; with the
`FleetProvider` layer the identical Eq (4) + fleet-simulation machinery
runs over GCP preemptible, AWS spot and Azure low-priority offerings, so
this benchmark answers the planning question the ROADMAP's provider item
poses: for a fixed training job, which market finishes it cheapest, and
what does the revocation/replacement overhead difference cost in time?

Per (provider, gpu): the §V-C planner's best (region, launch-hour) cell
(expected cost/time via Eq 4) and a fleet-simulation *ensemble*
(`FleetSim.run_many`, pre-drawn batched lifetimes) of that best cell —
mean plus p90 of realized cost/time/revocations.
"""
from __future__ import annotations

from benchmarks.fleet_common import (I_C, N_W, N_WORKERS, T_C,
                                     best_cell_ensemble)
from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.core.scheduler import plan_launch
from repro.providers import available_providers, get_provider


def run():
    gens = calibrate_generators()
    c_m = TABLE1_MODELS["resnet_32"]
    out = []
    for name in available_providers():
        prov = get_provider(name)
        for gpu in prov.gpus():
            if gpu not in gens:
                continue
            sp = 1.0 / gens[gpu].step_time(c_m)
            best, plans = plan_launch(gpu, N_WORKERS, sp, n_w=N_W, i_c=I_C,
                                      t_c=T_C, hours=[0, 6, 12, 18],
                                      provider=prov)
            st = best_cell_ensemble(prov, gpu, best.region, sp,
                                    float(best.launch_hour))
            out.append({
                "name": f"cross_provider/{name}/{gpu}x{N_WORKERS}",
                "value": round(best.expected_cost, 2),
                "derived": (
                    f"best={best.region}@{best.launch_hour:02d}h "
                    f"E[time]={best.expected_time_s / 3600:.2f}h "
                    f"E[rev]={best.expected_revocations:.2f}"
                    f"±{best.revocation_stderr:.2f}; simulated (n={st.n}) "
                    f"${st.cost_mean:.2f}/{st.time_mean_s / 3600:.2f}h "
                    f"p90 ${st.cost_p90:.2f}/{st.time_p90_s / 3600:.2f}h "
                    f"rev={st.revocations_mean:.1f} @ ${prov.price(gpu)}/h "
                    f"(best-cell expected cost $)"),
            })
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
