"""Beyond-paper: the same workload priced across three transient markets.

The paper's cost/overhead analysis (§V-§VI) is single-cloud; with the
`FleetProvider` layer the identical Eq (4) + fleet-simulation machinery
runs over GCP preemptible, AWS spot and Azure low-priority offerings, so
this benchmark answers the planning question the ROADMAP's provider item
poses: for a fixed training job, which market finishes it cheapest, and
what does the revocation/replacement overhead difference cost in time?

Per (provider, gpu): the §V-C planner's best (region, launch-hour) cell
(expected cost/time via Eq 4) and a 3-seed fleet-simulation average
(realized cost/time/revocations) of that best cell.
"""
from __future__ import annotations

import numpy as np

from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.core.scheduler import plan_launch
from repro.core.transient.fleet import FleetSim, SimWorker
from repro.models import cnn
from repro.providers import available_providers, get_provider

# ResNet-32 at 4 workers, sized so the ~4-8 h wall-clock actually exposes
# each market's revocation behavior (same workload for every provider).
N_W = 256_000
I_C = 4_000
T_C = 3.84
N_WORKERS = 4


def _simulate(provider, gpu: str, region: str, sp: float,
              launch_hour: float, seeds=(0, 1, 2)):
    c_m = TABLE1_MODELS["resnet_32"]
    times, costs, revs = [], [], []
    for s in seeds:
        workers = [SimWorker(i, gpu, region, sp) for i in range(N_WORKERS)]
        sim = FleetSim(workers, model_gflops=c_m,
                       model_bytes=4.0 * cnn.param_count(cnn.RESNET_32),
                       step_speed_of=lambda g: sp,
                       checkpoint_interval_steps=I_C, checkpoint_time_s=T_C,
                       seed=s, price_of={gpu: provider.price(gpu)},
                       provider=provider)
        res = sim.run(N_W, max_hours=100.0, start_hour=launch_hour)
        times.append(res.total_time_s)
        costs.append(res.monetary_cost)
        revs.append(res.revocations)
    return (float(np.mean(times)), float(np.mean(costs)),
            float(np.mean(revs)))


def run():
    gens = calibrate_generators()
    c_m = TABLE1_MODELS["resnet_32"]
    out = []
    for name in available_providers():
        prov = get_provider(name)
        for gpu in prov.gpus():
            if gpu not in gens:
                continue
            sp = 1.0 / gens[gpu].step_time(c_m)
            best, plans = plan_launch(gpu, N_WORKERS, sp, n_w=N_W, i_c=I_C,
                                      t_c=T_C, hours=[0, 6, 12, 18],
                                      provider=prov)
            t_sim, c_sim, r_sim = _simulate(prov, gpu, best.region, sp,
                                            float(best.launch_hour))
            out.append({
                "name": f"cross_provider/{name}/{gpu}x{N_WORKERS}",
                "value": round(best.expected_cost, 2),
                "derived": (
                    f"best={best.region}@{best.launch_hour:02d}h "
                    f"E[time]={best.expected_time_s / 3600:.2f}h "
                    f"E[rev]={best.expected_revocations:.2f}; simulated "
                    f"${c_sim:.2f}/{t_sim / 3600:.2f}h "
                    f"rev={r_sim:.1f} @ ${prov.price(gpu)}/h "
                    f"(best-cell expected cost $)"),
            })
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
