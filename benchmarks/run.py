"""Benchmark driver — one module per paper table/figure (+ the roofline).
Prints ``name,value,derived`` CSV rows; tee'd to bench_output.txt by CI.

PYTHONPATH=src python -m benchmarks.run [--only table2_speed_models,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "table1_speed",
    "fig2_stability",
    "fig3_correlation",
    "table2_speed_models",
    "table3_worker_speed",
    "fig4_cluster_scaling",
    "fig5_checkpoint",
    "table4_ckpt_models",
    "fig6_startup",
    "table5_revocations",
    "fig10_replacement",
    "fig11_recomputation",
    "eq4_endtoend",
    "fig12_bottleneck",
    "cost_savings",
    "scheduler_gains",
    "lm_speed_models",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,value,derived")
    failures = 0
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            if name == "roofline":
                rows = [{"name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                         "value": round(r.get("roofline_fraction", 0.0), 4),
                         "derived": (f"bottleneck={r.get('bottleneck')} "
                                     f"compute={r.get('compute_s', 0):.4f}s")}
                        for r in mod.run()
                        if not r.get("skipped") and not r.get("failed")]
            else:
                rows = mod.run()
            for r in rows:
                derived = str(r.get("derived", "")).replace(",", ";")
                print(f"{r['name']},{r['value']},{derived}", flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc(file=sys.stdout)
    if failures:
        print(f"# {failures} benchmark module(s) failed")
        sys.exit(1)


if __name__ == "__main__":
    main()
