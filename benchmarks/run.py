"""Benchmark driver — one module per paper table/figure (+ the roofline).
Prints ``name,value,derived`` CSV rows; tee'd to bench_output.txt by CI.

    PYTHONPATH=src python -m benchmarks.run [--only table2_speed_models,...]
    python -m repro bench --only table1_speed,fig2_stability

Exit status is nonzero when ANY selected module raises (or an --only name
is unknown), so CI can gate on it; per-module tracebacks go to stderr.
"""
from __future__ import annotations

import sys
import time
import traceback
from typing import List, Optional

MODULES = [
    "table1_speed",
    "fig2_stability",
    "fig3_correlation",
    "table2_speed_models",
    "table3_worker_speed",
    "fig4_cluster_scaling",
    "fig5_checkpoint",
    "table4_ckpt_models",
    "fig6_startup",
    "table5_revocations",
    "fig10_replacement",
    "fig11_recomputation",
    "eq4_endtoend",
    "fig12_bottleneck",
    "cost_savings",
    "scheduler_gains",
    "cross_provider",
    "mc_speed",
    "lm_speed_models",
    "chaos",
    "recalib",
    "serving",
    "roofline",
]


def _run_module(name: str) -> List[dict]:
    mod = __import__(f"benchmarks.{name}", fromlist=["run"])
    if name == "roofline":
        return [{"name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                 "value": round(r.get("roofline_fraction", 0.0), 4),
                 "derived": (f"bottleneck={r.get('bottleneck')} "
                             f"compute={r.get('compute_s', 0):.4f}s")}
                for r in mod.run()
                if not r.get("skipped") and not r.get("failed")]
    return mod.run()


def main(argv: Optional[List[str]] = None) -> int:
    # shared CLI helper (PYTHONPATH=src / pip install -e . both work)
    from repro.launch.cli import make_parser

    ap = make_parser("benchmarks.run", "paper table/figure benchmark driver")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of benchmark modules")
    ap.add_argument("--list", action="store_true",
                    help="list module names and exit")
    args = ap.parse_args(argv)
    if args.list:
        print("\n".join(MODULES))
        return 0
    only = [m for m in args.only.split(",") if m] if args.only else None
    unknown = sorted(set(only or []) - set(MODULES))
    if unknown:
        print(f"unknown benchmark module(s): {', '.join(unknown)}",
              file=sys.stderr)
        return 2
    selected = [m for m in MODULES if only is None or m in only]

    print("name,value,derived")
    failed: List[str] = []
    for name in selected:
        t0 = time.time()
        try:
            rows = _run_module(name)
            for r in rows:
                derived = str(r.get("derived", "")).replace(",", ";")
                print(f"{r['name']},{r['value']},{derived}", flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failed.append(name)
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        print(f"# {len(failed)}/{len(selected)} benchmark module(s) failed: "
              f"{', '.join(failed)}", flush=True)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
