"""Fig 2 — training-speed stability: REAL wall-clock training of a small CNN
on this host; coefficient of variation of windowed speeds should be small
post-warmup (paper: <= 0.02 on GPUs; CPU jitter is higher but bounded).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.profiler import PerformanceProfiler
from repro.data.pipeline import CIFARLikeSource
from repro.models import cnn


def run(steps: int = 30, batch: int = 16):
    spec = cnn.CNNSpec("bench_tiny", "resnet", 1, 8)
    params = cnn.init_params(jax.random.PRNGKey(0), spec)
    src = CIFARLikeSource()

    @jax.jit
    def train_step(p, images, labels):
        loss, g = jax.value_and_grad(
            lambda pp: cnn.loss_fn(pp, spec, images, labels))(p)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), loss

    prof = PerformanceProfiler(window=5, warmup_steps=5, warmup_seconds=0.5)
    for s in range(steps):
        b = src.batch(s, 0, 1, batch)
        params, loss = train_step(params, jnp.asarray(b["images"]),
                                  jnp.asarray(b["labels"]))
        loss.block_until_ready()
        prof.record(s)
    cov = prof.cov()
    return [{"name": "fig2/real_cpu_speed_steps_per_s",
             "value": round(prof.speed() or 0.0, 3),
             "derived": f"cov={cov if cov is not None else -1:.4f} "
                        f"(paper GPUs <=0.02; CPU jitter tolerated <0.5)"}]


if __name__ == "__main__":
    for r in run():
        print(r)
