"""Figs 6-7 — transient startup time: stage breakdown (provisioning/staging/
running) per GPU, transient vs on-demand, and post-revocation variance.
"""
from __future__ import annotations

import numpy as np

from repro.core.transient.startup import StartupModel


def run():
    m = StartupModel(seed=0)
    out = []
    for gpu in ("k80", "p100", "v100"):
        for transient in (True, False):
            s = [m.sample(gpu, transient)["total"] for _ in range(50)]
            kind = "transient" if transient else "ondemand"
            out.append({"name": f"fig6/{gpu}/{kind}",
                        "value": round(float(np.mean(s)), 2),
                        "derived": f"std={np.std(s):.2f} "
                                   f"under100s={int(np.mean(s) < 100)}"})
    # fig 7: immediate vs delayed request CoV after a revocation
    for gpu in ("k80", "p100", "v100"):
        imm = [m.sample(gpu, True, after_revocation=True)["total"]
               for _ in range(100)]
        dl = [m.sample(gpu, True, after_revocation=False)["total"]
              for _ in range(100)]
        cov_i = float(np.std(imm) / np.mean(imm))
        cov_d = float(np.std(dl) / np.mean(dl))
        out.append({"name": f"fig7/{gpu}/immediate_vs_delayed",
                    "value": round(cov_i / max(cov_d, 1e-9), 2),
                    "derived": f"cov_imm={cov_i:.3f} cov_delay={cov_d:.3f} "
                               f"mean_diff={abs(np.mean(imm)-np.mean(dl)):.1f}s"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
