"""Monte-Carlo engine throughput — the repo's first perf-trajectory
benchmark (docs/performance.md has the methodology and the JSON schema).

Two measurements, written to BENCH_mc.json at the repo root (CI's `perf`
job uploads it as an artifact):

* **planner grid** — the §V-C planner's default grid (all regions offering
  the GPU x 8 launch hours x 200 MC samples), timed twice: once through
  the *pinned scalar baseline* (the pre-vectorization per-sample loop,
  reproduced verbatim below: per-sample lifetime-model resolution plus a
  per-index diurnal-thinning rejection loop) and once through the batched
  `plan_launch`. The headline number is the speedup at equal sample
  counts.
* **simulation ensemble** — `FleetSim.run_many` trajectory throughput for
  a 4-worker V100 cluster, vs the pre-ensemble pattern of re-building a
  simulator per seed in a Python loop (what `benchmarks/cross_provider.py`
  did before the ensemble API).
* **batched engine** — the lockstep array engine vs the per-trajectory
  event loop at n=1024 trajectories of the same workload, both consuming
  identical `FleetDraws` streams so the comparison is work-for-work. The
  `speedup` here is the regression-gated metric (machine-normalized:
  both engines run on the same box) with a 10x absolute floor — the
  acceptance bar of the lockstep-engine PR.
* **jit engine** — the compiled `engine="jit"` program vs the NumPy
  lockstep engine at n=65536 trajectories of a chaos mega-ensemble
  (`regional_wave`), shared draws, raw array stats on both sides,
  steady-state (compile/pool residency excluded). Regression-gated like
  the batched entry, with a 5x absolute floor — the acceptance bar of
  the jit-engine PR.
"""
from __future__ import annotations

import json
import math
import pathlib
import time
from typing import List

import numpy as np

from repro.core.perf_model.cluster_model import (Eq4Inputs, WorkerSpec,
                                                 cluster_speed,
                                                 predict_total_time)
from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.core.scheduler import plan_launch
from repro.core.transient.fleet import FleetSim, SimWorker
from repro.core.transient.replacement import ReplacementModel
from repro.core.transient.revocation import (MAX_LIFETIME_H,
                                             _diurnal_weight)
from repro.core.transient.startup import StartupModel
from repro.providers import get_provider

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = ROOT / "BENCH_mc.json"

# The default planner-grid workload (matches scheduler_gains.py).
N_W = 256_000
I_C = 4_000
T_C = 3.84
N_WORKERS = 4
SAMPLES = 200
HOURS = [0, 3, 6, 9, 12, 15, 18, 21]
ENSEMBLE_N = 64
BATCHED_N = 1024
JIT_N = 65536
JIT_SCENARIO = "regional_wave"


# ------------------------------------------------- pinned scalar baseline
def reference_scalar_lifetime(m, rng: np.random.Generator,
                              start_hour: float = 0.0) -> float:
    """One lifetime from the pre-vectorization `LifetimeModel.sample`
    loop, reproduced verbatim (per-index rejection, up to 64 rounds).
    Kept here — not in the library — as the frozen baseline every future
    BENCH_mc.json entry is measured against, and as the reference
    distribution for the sampler-parity tests."""
    u = rng.uniform(size=1)
    out = np.full(1, np.inf)
    revoked = u < m.p24
    uu = rng.uniform(size=1)
    raw24 = 1.0 - math.exp(-((MAX_LIFETIME_H / m.lam) ** m.k))
    t = m.lam * (-np.log(1.0 - uu * raw24)) ** (1.0 / m.k)
    for i in np.where(revoked)[0]:
        accepted = False
        for _ in range(64):
            w = float(_diurnal_weight(m.gpu, start_hour + t[i]))
            if rng.uniform() < w / 2.5:
                accepted = True
                break
            uu_i = rng.uniform()
            t[i] = m.lam * (-np.log(1.0 - uu_i * raw24)) ** (1.0 / m.k)
        if not accepted and float(_diurnal_weight(
                m.gpu, start_hour + t[i])) == 0.0:
            t[i] += 4.0
        out[i] = min(t[i], MAX_LIFETIME_H)
    return float(out[0])


def scalar_expected_revocations(prov, region: str, gpu: str,
                                start_hour: float, run_hours: float,
                                n_workers: int, samples: int,
                                seed: int) -> float:
    """Pre-PR `expected_revocations_mc`: one model resolution and one
    scalar rejection loop per sample."""
    rng = np.random.default_rng(seed)
    horizon = min(run_hours, prov.max_lifetime_hours)
    hits = 0
    for _ in range(samples):
        model = prov.lifetime_model(region, gpu)   # re-resolved per sample
        lt = reference_scalar_lifetime(model, rng, start_hour)
        if math.isfinite(lt) and lt <= horizon:
            hits += 1
    return n_workers * hits / samples


def scalar_plan_grid(gpu: str, n_workers: int, worker_speed: float,
                     n_w: int, i_c: int, t_c: float, hours: List[int],
                     seed: int, prov) -> List[dict]:
    """Pre-PR `plan_launch` (compute-only MC horizon, scalar MC)."""
    startup = StartupModel(seed, prov)
    repl = ReplacementModel(seed, prov)
    price = prov.price(gpu)
    sp = cluster_speed([WorkerSpec(gpu, worker_speed)] * n_workers)
    base_hours = n_w / sp / 3600.0
    t_p = startup.mean_total(gpu)
    t_s = repl.cold_start_s(1.54)
    plans = []
    for region in prov.regions_offering(gpu):
        for h in hours:
            n_r = scalar_expected_revocations(prov, region, gpu, float(h),
                                              base_hours, n_workers,
                                              SAMPLES, seed)
            probs = [n_r / n_workers] * n_workers
            t = predict_total_time(sp, Eq4Inputs(n_w, i_c, t_c, t_p, t_s,
                                                 probs))
            cost = (t / 3600.0) * n_workers * price \
                + n_r * (t_p / 3600.0) * price
            plans.append({"region": region, "hour": h, "cost": cost})
    return plans


# ------------------------------------------------------------ measurement
def _best_of(fn, reps: int = 3) -> float:
    best = math.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_planner_grid(gpu: str = "v100") -> dict:
    prov = get_provider("gcp")
    gens = calibrate_generators()
    sp = 1.0 / gens[gpu].step_time(TABLE1_MODELS["resnet_32"])
    n_cells = len(prov.regions_offering(gpu)) * len(HOURS)
    scalar_s = _best_of(lambda: scalar_plan_grid(
        gpu, N_WORKERS, sp, N_W, I_C, T_C, HOURS, 0, prov))
    batched_s = _best_of(lambda: plan_launch(
        gpu, N_WORKERS, sp, n_w=N_W, i_c=I_C, t_c=T_C, hours=HOURS,
        seed=0, provider=prov, samples=SAMPLES))
    return {
        "gpu": gpu, "cells": n_cells, "samples_per_cell": SAMPLES,
        "scalar_s": round(scalar_s, 4), "batched_s": round(batched_s, 4),
        "speedup": round(scalar_s / batched_s, 1),
        "cells_per_s": round(n_cells / batched_s, 1),
    }


def bench_ensemble(n: int = ENSEMBLE_N) -> dict:
    gens = calibrate_generators()
    c_m = TABLE1_MODELS["resnet_32"]
    sp = 1.0 / gens["v100"].step_time(c_m)
    steps = 100_000

    def mk(seed):
        workers = [SimWorker(i, "v100", "us-central1", sp)
                   for i in range(N_WORKERS)]
        return FleetSim(workers, model_gflops=c_m, model_bytes=1.87e6,
                        step_speed_of=lambda g: sp,
                        checkpoint_interval_steps=I_C, checkpoint_time_s=T_C,
                        seed=seed, price_of={"v100": 0.74})

    t0 = time.perf_counter()
    ens = mk(0).run_many(steps, n, max_hours=100.0)
    batched_s = time.perf_counter() - t0
    # the pre-ensemble pattern: one simulator re-built and run per seed
    t0 = time.perf_counter()
    for s in range(n):
        mk(s).run(steps, max_hours=100.0)
    loop_s = time.perf_counter() - t0
    return {
        "trajectories": n, "steps": steps,
        "batched_s": round(batched_s, 4), "loop_s": round(loop_s, 4),
        "traj_per_s": round(n / batched_s, 1),
        "time_p50_s": round(ens.stats.time_p50_s, 1),
        "time_p90_s": round(ens.stats.time_p90_s, 1),
        "revocations_mean": round(ens.stats.revocations_mean, 2),
    }


def bench_batched_engine(n: int = BATCHED_N) -> dict:
    """Lockstep array engine vs the event-loop oracle, work-for-work
    (shared `FleetDraws`), at ensemble scale — the regression-gated
    hot path of the lockstep-engine PR."""
    gens = calibrate_generators()
    c_m = TABLE1_MODELS["resnet_32"]
    sp = 1.0 / gens["v100"].step_time(c_m)
    steps = 100_000

    def mk():
        workers = [SimWorker(i, "v100", "us-central1", sp)
                   for i in range(N_WORKERS)]
        return FleetSim(workers, model_gflops=c_m, model_bytes=1.87e6,
                        step_speed_of=lambda g: sp,
                        checkpoint_interval_steps=I_C, checkpoint_time_s=T_C,
                        seed=0, price_of={"v100": 0.74})

    batched_s = _best_of(lambda: mk().run_many(steps, n, max_hours=100.0,
                                               engine="batched"))
    event_s = _best_of(lambda: mk().run_many(steps, n, max_hours=100.0,
                                             engine="event"), reps=2)
    return {
        "trajectories": n, "steps": steps,
        "batched_s": round(batched_s, 4), "event_s": round(event_s, 4),
        "traj_per_s": round(n / batched_s, 1),
        "event_traj_per_s": round(n / event_s, 1),
        "speedup": round(event_s / batched_s, 1),
    }


def bench_jit_engine(n: int = JIT_N) -> dict:
    """Compiled jit engine vs the NumPy lockstep engine, work-for-work
    (shared `FleetDraws`, `raw=True` array stats on both sides so neither
    pays the 65k-`SimResult` construction) on a chaos mega-ensemble —
    the workload the jit engine exists for. A chaos timeline's fault
    windows are *global* event stops: every trajectory processes every
    boundary, which defeats the NumPy engine's shrinking active set and
    leaves it re-walking full-width rounds under the per-round Python
    transform overhead, while the compiled `lax.while_loop` fuses them.
    Parity is asserted in-bench (identical revocation counts) so the
    timed programs provably do the same work. Engine warm-up (XLA
    compilation, device pool residency, FleetDraws level materialization)
    happens before timing: the measurement is steady-state re-scoring
    throughput, the planner-loop regime (docs/performance.md)."""
    import jax

    from repro.api.session import Session
    from repro.chaos.scenarios import get_scenario
    from repro.core.transient.fleet_batched import FleetDraws, run_batched
    from repro.core.transient.fleet_jit import run_jit

    sc = get_scenario(JIT_SCENARIO)
    ses = Session.from_arch("qwen3-1.7b", smoke=True)
    sim, n_steps = ses._fleet_sim(
        n_workers=sc.n_workers, gpu=sc.gpu, region=sc.region,
        steps=sc.total_steps, seed=0, handover=sc.handover,
        provider=sc.provider)
    sim.chaos = sc.timeline(sim._roster, seed=0)
    draws = FleetDraws(sim, n, 0.0)
    args = (n_steps, n, sc.max_hours, 0.0)
    rb = run_batched(sim, *args, draws=draws, raw=True)
    rj = run_jit(sim, *args, draws=draws, raw=True)
    if not (rb["revocations"] == rj["revocations"]).all():
        raise AssertionError(
            "engine parity violated inside bench_jit_engine — the timed "
            "engines are not doing identical work")
    batched_s = _best_of(lambda: run_batched(sim, *args, draws=draws,
                                             raw=True), reps=2)
    jit_s = _best_of(lambda: run_jit(sim, *args, draws=draws, raw=True))
    return {
        "trajectories": n, "scenario": JIT_SCENARIO, "steps": n_steps,
        "devices": len(jax.devices()),
        "batched_s": round(batched_s, 4), "jit_s": round(jit_s, 4),
        "traj_per_s": round(n / jit_s, 1),
        "speedup": round(batched_s / jit_s, 1),
    }


def run():
    grid = bench_planner_grid()
    ens = bench_ensemble()
    eng = bench_batched_engine()
    jit = bench_jit_engine()
    payload = {
        "schema": 2,
        "planner_grid": grid,
        "ensemble": ens,
        "batched_engine": eng,
        "jit_engine": jit,
    }
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return [
        {"name": f"mc_speed/planner_grid/{grid['gpu']}",
         "value": grid["speedup"],
         "derived": (f"{grid['cells']} cells x {grid['samples_per_cell']} "
                     f"samples: scalar {grid['scalar_s']}s -> batched "
                     f"{grid['batched_s']}s ({grid['cells_per_s']} cells/s; "
                     f"speedup x)")},
        {"name": "mc_speed/ensemble/v100x4",
         "value": ens["traj_per_s"],
         "derived": (f"{ens['trajectories']} trajectories in "
                     f"{ens['batched_s']}s (loop: {ens['loop_s']}s); "
                     f"p50={ens['time_p50_s']}s p90={ens['time_p90_s']}s "
                     f"E[rev]={ens['revocations_mean']} (traj/s)")},
        {"name": f"mc_speed/batched_engine/v100x4/n{eng['trajectories']}",
         "value": eng["speedup"],
         "derived": (f"{eng['trajectories']} trajectories: event "
                     f"{eng['event_s']}s ({eng['event_traj_per_s']} traj/s)"
                     f" -> batched {eng['batched_s']}s "
                     f"({eng['traj_per_s']} traj/s) (speedup x)")},
        {"name": (f"mc_speed/jit_engine/{jit['scenario']}/"
                  f"n{jit['trajectories']}"),
         "value": jit["speedup"],
         "derived": (f"{jit['trajectories']} chaos trajectories on "
                     f"{jit['devices']} device(s): batched "
                     f"{jit['batched_s']}s -> jit {jit['jit_s']}s "
                     f"({jit['traj_per_s']} traj/s) (speedup x)")},
    ]


if __name__ == "__main__":
    for r in run():
        print(r)
    print(f"wrote {OUT_PATH}")
