"""Table V + Figs 8-9 — revocation characterization from the calibrated
fleet sampler: 12 non-consecutive days of batch requests per (region, GPU);
revocation rates, mean-time-to-revocation, and the diurnal histogram.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.transient.revocation import (REGION_GPU_PARAMS, TABLE5_RATES,
                                             RevocationSampler)


def run():
    out = []
    samp = RevocationSampler(seed=7)
    rates_err = []
    for (region, gpu), paper_rate in sorted(TABLE5_RATES.items()):
        if paper_rate is None:
            continue
        n = 30 * 12  # 30 servers per batch x 12 days
        lts = [samp.lifetime(region, gpu, start_hour=(d * 7) % 24)
               for d in range(n)]
        revoked = [t for t in lts if math.isfinite(t)]
        rate = len(revoked) / n
        mttr = float(np.mean(revoked)) if revoked else float("nan")
        rates_err.append(abs(rate - paper_rate))
        out.append({"name": f"table5/{region}/{gpu}",
                    "value": round(rate, 4),
                    "derived": f"paper={paper_rate:.4f} mttr={mttr:.1f}h "
                               f"model_mttr="
                               f"{REGION_GPU_PARAMS[(region,gpu)].mean_time_to_revocation():.1f}h"})
    out.append({"name": "table5/mean_abs_rate_error",
                "value": round(float(np.mean(rates_err)), 4),
                "derived": "vs paper Table V"})
    # fig 9: no V100 revocations between 4PM and 8PM local
    v100 = REGION_GPU_PARAMS[("us-central1", "v100")]
    rng = np.random.default_rng(3)
    hours = []
    for _ in range(400):
        start = rng.uniform(0, 24)
        t = v100.sample(rng, 1, start_hour=start)[0]
        if math.isfinite(t):
            hours.append((start + t) % 24)  # absolute local hour of revocation
    quiet = sum(1 for h in hours if 16 <= h < 20)
    out.append({"name": "fig9/v100_quiet_window_revocations",
                "value": quiet, "derived": "expected ~0 in 4PM-8PM"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
